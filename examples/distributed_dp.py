"""Explicit-SPMD data-parallel training via the DistributedInterface
(paper §4.1.3 / A.4.1): shard_map training step with bucketed, optionally
int8-compressed (error-feedback) gradient all-reduce.

Spawns itself with 8 fake host devices when run on 1 device.

Run:  PYTHONPATH=src python examples/distributed_dp.py
"""

import os
import subprocess
import sys


def _worker():
    import jax

    import repro
    from repro.core.distributed import ShardMapBackend, init_distributed
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    dist = init_distributed(ShardMapBackend("data"))

    with repro.session(mesh=mesh, batch_axes=("data",),
                       tag="distributed_dp"):
        _train(mesh, dist)


def _train(mesh, dist):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.distributed import GradientSynchronizer, GradSyncConfig

    d, classes = 32, 4
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (d, classes)) * 0.1,
              "b": jnp.zeros((classes,))}

    rng = np.random.default_rng(0)
    centers = rng.standard_normal((classes, d)) * 2
    ys = rng.integers(0, classes, 1024)
    xs = (centers[ys] + rng.standard_normal((1024, d))).astype(np.float32)

    for compress in ("none", "int8"):
        sync = GradientSynchronizer(dist, GradSyncConfig(compress=compress))

        def local_loss(p, x, y):
            logits = x @ p["w"] + p["b"]
            return -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(logits), y[:, None], 1))

        def step(p, ef, x, y):
            # per-shard gradient, then interface-level all-reduce
            loss, grads = jax.value_and_grad(local_loss)(p, x, y)
            grads, ef = sync(grads, ef)
            new_p = jax.tree.map(lambda w, g: w - 0.5 * g, p, grads)
            return new_p, ef, jax.lax.pmean(loss, "data")

        ef0 = sync.init_state(params)
        sharded_step = jax.jit(shard_map(
            step, mesh=mesh,
            in_specs=(P(), jax.tree.map(lambda _: P(), ef0), P("data"),
                      P("data")),
            out_specs=(P(), jax.tree.map(lambda _: P(), ef0), P()),
            check_vma=False))

        p, ef = params, ef0
        losses = []
        for i in range(30):
            x = jnp.asarray(xs[(i * 256) % 768:(i * 256) % 768 + 256])
            y = jnp.asarray(ys[(i * 256) % 768:(i * 256) % 768 + 256])
            p, ef, loss = sharded_step(p, ef, x, y)
            losses.append(float(loss))
        print(f"[distributed_dp] compress={compress:5s} "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f} on "
              f"{dist.__class__.__name__} world={len(jax.devices())}")
        assert losses[-1] < losses[0] * 0.5
    print("distributed_dp OK")


def main():
    import jax

    if len(jax.devices()) >= 8:
        _worker()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    env["REPRO_DP_WORKER"] = "1"
    r = subprocess.run([sys.executable, __file__], env=env)
    sys.exit(r.returncode)


if __name__ == "__main__":
    if os.environ.get("REPRO_DP_WORKER"):
        _worker()
    else:
        main()
