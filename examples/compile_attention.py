"""Attention pattern matching smoke: plain ops → one generated kernel.

Attention written against ``repro.core.tensor.ops`` — matmul, transpose,
scale, shifted softmax, matmul — is compiled through the graph-IR
pipeline.  The ``attention`` matcher pass recognizes the
``softmax(QK^T * scale)V`` subgraph, claims it as a sink-cone cluster,
and lowers it onto the parameterized flash-attention template: the whole
pattern runs as exactly one generated Pallas kernel (interpret mode
off-TPU) instead of one dispatch per op.  The script asserts the single
kernel, checks compiled ≈ eager, and prints the labeled IR plus the
per-pass stats — CI runs it as a smoke test.

Run:  PYTHONPATH=src python examples/compile_attention.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.compiler import CompilerPolicy, trace
from repro.core.tensor import ops
from repro.core.tensor.lazy_backend import LazyBackend


def attention(q, k, v, scale):
    """softmax(QK^T * scale) V in plain ops, [BH, S, D] operands."""
    s = ops.matmul(q, ops.transpose(k, (0, 2, 1)))
    s = ops.mul(s, ops.full_like(s, scale))
    m = ops.max(s, axis=-1, keepdims=True)
    e = ops.exp(ops.sub(s, ops.stop_gradient(m)))
    p = ops.div(e, ops.sum(e, axis=-1, keepdims=True))
    return ops.matmul(p, v)


def main():
    bh, s, d = 4, 128, 64
    scale = 1.0 / (d ** 0.5)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (bh, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (bh, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (bh, s, d), jnp.float32)

    # eager reference: one XLA dispatch per op
    want = np.asarray(attention(q, k, v, scale))

    # show the captured IR: the matcher labels the claimed cluster
    lb = LazyBackend()
    with repro.session(backend=lb):
        g, _ = trace([attention(lb._lift(q), lb._lift(k),
                                lb._lift(v), scale)])
    from repro.compiler.passes import PassManager
    PassManager.from_policy(CompilerPolicy()).run(g)
    print("optimized IR (attention cluster claimed by the matcher):")
    print(g.dump())
    print()

    compiled = repro.compile(lambda a, b, c: attention(a, b, c, scale))
    got = np.asarray(compiled(q, k, v))
    exe = compiled.last_executable
    print("pipeline:", [st.describe() for st in exe.report])
    print(f"lowered to {exe.n_dispatches} dispatch(es), "
          f"{exe.n_kernels} generated Pallas kernel(s), "
          f"clusters: {exe.describe()['clusters']}")

    kinds = [c["kind"] for c in exe.describe()["clusters"]]
    assert exe.n_dispatches == 1 and exe.n_kernels == 1, \
        "attention pattern must lower to exactly one generated kernel"
    assert kinds == ["attention"], f"expected one attention cluster: {kinds}"
    assert "(attention)" in g.dump(), "dump() must label the cluster kind"
    # the template's online softmax reassociates the normalizer, so the
    # comparison is allclose, not bitwise (see tests/test_fusion_extended.py)
    np.testing.assert_allclose(got, want, rtol=3e-6, atol=2e-6)

    # sigmoid attention matches the same template, mode="sigmoid"
    def sig_attn(x):
        sc = ops.matmul(x, ops.transpose(x, (0, 2, 1)))
        ones = ops.full_like(sc, 1.0)
        p = ops.div(ones, ops.add(ones, ops.exp(ops.neg(sc))))
        return ops.matmul(p, x)

    sig = repro.compile(sig_attn)
    got_sig = np.asarray(sig(q))
    assert sig.last_executable.n_kernels == 1
    want_sig = np.asarray(jnp.einsum(
        "bqk,bkd->bqd",
        jax.nn.sigmoid(jnp.einsum("bqd,bkd->bqk", q, q)), q))
    np.testing.assert_allclose(got_sig, want_sig, rtol=3e-6, atol=2e-6)

    print("OK: softmax + sigmoid attention each lowered to one generated "
          "kernel, numerics agree with eager")


if __name__ == "__main__":
    main()
