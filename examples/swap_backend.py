"""§5.2.4 demo: swap the source of truth for primitive tensor ops and the
ENTIRE stack — core layers, tape autograd, and the production model zoo —
picks up the new implementation with zero call-site changes.

Three swaps:
 1. an instrumented backend that counts every add/matmul,
 2. the deferred/fusing backend (ArrayFire-JIT analog),
 3. the Pallas-kernel backend (hand-written MXU matmul kernel).

Run:  PYTHONPATH=src python examples/swap_backend.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.tensor import (JnpBackend, ops, register_backend,
                               use_backend)
from repro.models import build_model


class CountingBackend(JnpBackend):
    name = "counting"

    def __init__(self):
        self.counts = {}

    def _bump(self, op):
        self.counts[op] = self.counts.get(op, 0) + 1

    def add(self, lhs, rhs):
        self._bump("add")
        return super().add(lhs, rhs)

    def matmul(self, lhs, rhs):
        self._bump("matmul")
        return super().matmul(lhs, rhs)

    def dot_general(self, lhs, rhs, dimension_numbers,
                    preferred_element_type):
        self._bump("dot_general")
        return super().dot_general(lhs, rhs, dimension_numbers,
                                   preferred_element_type)


def main():
    register_backend("counting", CountingBackend)
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 16), jnp.int32)

    # 1. instrumented swap: every dispatch in a 16B-class MoE+MLA model
    #    (reduced) flows through the custom backend
    with use_backend("counting") as cb:
        logits, _, _ = model.forward(params, toks)
    print("[swap 1] counting backend saw:", dict(sorted(cb.counts.items())))
    assert cb.counts.get("dot_general", 0) > 10

    # 2. deferred/fusing backend under the core API
    with use_backend("lazy") as lb:
        x = ops.full((64, 64), 1.3)
        y = ops.tanh(ops.add(ops.mul(x, x), x))
        val = ops.materialize(y)
        print(f"[swap 2] lazy: {lb.nodes_built} nodes deferred, "
              f"{lb.materialize_calls} fused materialization(s), "
              f"val[0,0]={float(val[0,0]):.4f}")

    # 3. Pallas-kernel backend: matmuls now run the hand-written MXU
    #    kernel (interpret mode on CPU)
    with use_backend("pallas") as pb:
        a = jnp.ones((128, 128), jnp.float32)
        out = ops.matmul(a, a)
        print(f"[swap 3] pallas backend: {pb.kernel_calls} kernel call(s), "
              f"result[0,0]={float(out[0,0])}")
    assert float(out[0, 0]) == 128.0
    print("swap_backend OK")


if __name__ == "__main__":
    main()
