"""§5.2.4 demo: swap the source of truth for primitive tensor ops and the
ENTIRE stack — core layers, tape autograd, and the production model zoo —
picks up the new implementation with zero call-site changes.

The swap rides the unified runtime Session (``repro.session``), the one
composable context for backend + mesh + kernel overrides + precision:

 1. an instrumented backend that counts every add/matmul,
 2. the deferred/fusing backend (ArrayFire-JIT analog),
 3. the Pallas-kernel backend (hand-written MXU matmul kernel),
 4. a kernel-level override: inject just a custom matmul — no backend
    subclass needed — via ``session(kernels={"matmul": fn})``.

Run:  PYTHONPATH=src python examples/swap_backend.py
"""

import jax
import jax.numpy as jnp

import repro
from repro.configs.base import get_config
from repro.core.tensor import JnpBackend, ops, register_backend
from repro.models import build_model


class CountingBackend(JnpBackend):
    name = "counting"

    def __init__(self):
        self.counts = {}

    def _bump(self, op):
        self.counts[op] = self.counts.get(op, 0) + 1

    def add(self, lhs, rhs):
        self._bump("add")
        return super().add(lhs, rhs)

    def matmul(self, lhs, rhs):
        self._bump("matmul")
        return super().matmul(lhs, rhs)

    def dot_general(self, lhs, rhs, dimension_numbers,
                    preferred_element_type):
        self._bump("dot_general")
        return super().dot_general(lhs, rhs, dimension_numbers,
                                   preferred_element_type)


def main():
    register_backend("counting", CountingBackend)
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 16), jnp.int32)

    # 1. instrumented swap: every dispatch in a 16B-class MoE+MLA model
    #    (reduced) flows through the custom backend
    with repro.session(backend="counting", tag="instrumented") as sess:
        cb = sess.backend_instance()
        logits, _, _ = model.forward(params, toks)
        print("[swap 1] session:", sess.describe()["backend"],
              "tag:", sess.describe()["tag"])
    print("[swap 1] counting backend saw:", dict(sorted(cb.counts.items())))
    assert cb.counts.get("dot_general", 0) > 10

    # 2. deferred/fusing backend under the core API
    with repro.session(backend="lazy") as sess:
        lb = sess.backend_instance()
        x = ops.full((64, 64), 1.3)
        y = ops.tanh(ops.add(ops.mul(x, x), x))
        val = ops.materialize(y)
        print(f"[swap 2] lazy: {lb.nodes_built} nodes deferred, "
              f"{lb.materialize_calls} fused materialization(s), "
              f"val[0,0]={float(val[0,0]):.4f}")

    # 3. Pallas-kernel backend: matmuls now run the hand-written MXU
    #    kernel (interpret mode on CPU)
    with repro.session(backend="pallas") as sess:
        pb = sess.backend_instance()
        a = jnp.ones((128, 128), jnp.float32)
        out = ops.matmul(a, a)
        print(f"[swap 3] pallas backend: {pb.kernel_calls} kernel call(s), "
              f"result[0,0]={float(out[0,0])}")
    assert float(out[0, 0]) == 128.0

    # 4. finer-grained than a backend: override ONE kernel for a scope
    calls = []

    def traced_matmul(lhs, rhs):
        calls.append((lhs.shape, rhs.shape))
        return jnp.matmul(lhs, rhs)

    with repro.session(kernels={"matmul": traced_matmul}):
        a = jnp.ones((32, 32))
        ops.matmul(a, a)
    print(f"[swap 4] kernel override intercepted {len(calls)} matmul(s): "
          f"{calls}")
    assert calls == [((32, 32), (32, 32))]
    print("swap_backend OK")


if __name__ == "__main__":
    main()
