"""Multi-replica serving example: the serve() stream front door over two
engine replicas with prefix-affinity routing and a prefix-sharing paged
KV cache — requests with a shared system prompt arrive over time, land
on the replica that already cached their prefix, and skip its prefill.

Run:  PYTHONPATH=src python examples/serve_router.py
"""

import time

import jax

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving import Request, Router, ServeEngine, timed_stream

# a shared "system prompt" every request starts with, plus unique tails —
# the shape of real chat serving, and the case prefix sharing targets
SYSTEM = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7, 9, 3]
TAILS = [[23, 8], [46, 2, 6], [43, 38, 32], [7, 9, 50],
         [28, 8, 41, 9], [16, 39, 9], [37, 51], [5, 8, 20, 9]]


def _requests():
    return [Request(uid=uid, prompt=SYSTEM + tail, max_new_tokens=10)
            for uid, tail in enumerate(TAILS)]


def main():
    # codeqwen has no sliding-window layers, so it supports prefix
    # sharing end to end (window models degrade silently to no sharing)
    cfg = get_config("codeqwen1.5-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = ServingPolicy(cache="paged", block_size=8, prefill_chunk=8,
                           prefix=True, routing="prefix_affinity")

    # reference: every request through one engine, submitted up front
    with repro.session(tag="serve_router:single"):
        single = ServeEngine(model, params, batch_slots=4, max_seq=64,
                             policy=policy)
    for req in _requests():
        single.submit(req)
    ref = {r.uid: r.generated for r in single.run_until_done()}

    # routed: the same requests arrive over time (2 per tick) as a
    # stream through serve() across two replicas
    with repro.session(tag="serve_router:routed"):
        router = Router([ServeEngine(model, params, batch_slots=4,
                                     max_seq=64, policy=policy)
                         for _ in range(2)])
    trace = [(uid // 2, req) for uid, req in enumerate(_requests())]
    t0 = time.time()
    done = list(router.serve(timed_stream(trace)))
    dt = time.time() - t0
    out = {r.uid: r.generated for r in done}

    toks = sum(len(g) for g in out.values())
    desc = router.describe()
    saved = sum(e.prefill_tokens_saved for e in router.engines)
    print(f"[serve_router] {len(done)} requests, {toks} tokens in "
          f"{dt:.2f}s across {desc['replicas']} replicas "
          f"({desc['routing']} routing, {desc['steps']} lockstep steps)")
    print(f"[serve_router] placement: {desc['placement']} | "
          f"prefill tokens saved by sharing: {saved}")
    print(f"[serve_router] replica 0 serving provenance: "
          f"{desc['engines'][0]['session']['serving']}")

    # routed multi-replica decoding is token-for-token identical to the
    # single engine, and the shared system prompt actually saved prefill
    assert out == ref, "routed/single-engine divergence!"
    assert saved > 0, "prefix sharing saved no prefill tokens"
    print("serve_router OK")


if __name__ == "__main__":
    main()
