"""Batched serving example: continuous batching over cache slots with the
ServeEngine — multiple requests, slot recycling, greedy decoding, and the
paged KV-cache runtime (block-table cache + chunked prefill + pluggable
scheduler) against the dense compatibility path.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = get_config("gemma3-27b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # one session = the whole serving scenario (backend, precision,
    # kernel overrides, ServingPolicy); the engine snapshots it so
    # describe() records exactly what ran
    with repro.session(tag="serve_lm:gemma3-27b-reduced:dense"):
        dense = ServeEngine(model, params, batch_slots=4, max_seq=64,
                            policy=ServingPolicy(cache="dense",
                                                 prefill_chunk=8))
        out_dense = _drive(dense, "dense")

    with repro.session(
            serving=ServingPolicy(cache="paged", block_size=8,
                                  scheduler="sjf", prefill_chunk=8),
            tag="serve_lm:gemma3-27b-reduced:paged"):
        paged = ServeEngine(model, params, batch_slots=4, max_seq=64)
        print(f"[serve_lm] paged scenario: "
              f"{paged.session.describe()['serving']}")
        out_paged = _drive(paged, "paged")

    # paged serving is token-for-token identical to the dense engine
    assert out_dense == out_paged, "paged/dense divergence!"
    print(f"[serve_lm] paged block pool: {paged.kv.describe()}")
    print("serve_lm OK")


def _drive(engine, label):
    prompts = [[3, 1, 4, 1, 5], [9, 2, 6], [5, 3, 5, 8, 9, 7],
               [2, 7, 1, 8], [2, 8, 1], [8, 2, 8, 4]]
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=12))

    t0 = time.time()
    done = engine.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={r.prompt} -> {r.generated}")
    print(f"[serve_lm:{label}] {len(done)} requests, {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s) over {engine.steps} engine "
          f"steps (batched: {toks/engine.steps:.2f} tok/step; "
          f"{engine.prefill_calls} prefill + {engine.decode_calls} decode "
          f"jitted calls)")
    assert len(done) == len(prompts)
    return {r.uid: r.generated for r in done}


if __name__ == "__main__":
    main()
