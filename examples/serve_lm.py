"""Batched serving example: continuous batching over cache slots with the
ServeEngine — multiple requests, slot recycling, greedy decoding.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine


def main():
    # one session = the whole serving scenario (backend, precision,
    # kernel overrides); the engine snapshots it for provenance
    with repro.session(tag="serve_lm:gemma3-27b-reduced") as sess:
        cfg = get_config("gemma3-27b", reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_slots=4, max_seq=64)
        print(f"[serve_lm] session: {engine.session.describe()}")
        return _drive(engine)


def _drive(engine):
    prompts = [[3, 1, 4, 1, 5], [9, 2, 6], [5, 3, 5, 8, 9, 7],
               [2, 7, 1, 8], [2, 8, 1], [8, 2, 8, 4]]
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=12))

    t0 = time.time()
    done = engine.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt={r.prompt} -> {r.generated}")
    print(f"[serve_lm] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) over {engine.steps} engine steps "
          f"(batched: {toks/engine.steps:.2f} tok/step)")
    assert len(done) == len(prompts)
    print("serve_lm OK")


if __name__ == "__main__":
    main()
