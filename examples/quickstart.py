"""Quickstart: the paper's end-to-end example (Appendix A.4.3),
MNIST-flavored with synthetic data — Sequential model, SGD, loss/error
meters, train + eval loops.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import nn, optim
from repro.core.autograd import Variable, noGrad
from repro.core.data import BatchDataset, TensorDataset


def load_dataset(seed=0, n=2048, image_dim=12, classes=10):
    """Synthetic 'digits': class-dependent blobs on an image grid."""
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, classes, n)
    xs = rng.standard_normal((n, image_dim, image_dim, 1)) * 0.3
    for i, y in enumerate(ys):
        r, c = divmod(int(y), 4)
        xs[i, 2 + 2 * r: 5 + 2 * r, 2 + 2 * c: 5 + 2 * c, 0] += 1.5
    return xs.astype(np.float32), ys.astype(np.int32)


def eval_loop(model, dataset):
    loss_meter, err_meter, n = 0.0, 0.0, 0
    model.eval()
    for bx, by in dataset:
        inputs = noGrad(jnp.asarray(bx))
        output = model(inputs)
        pred = jnp.argmax(output.tensor(), axis=-1)
        err_meter += float(jnp.sum(pred != jnp.asarray(by)))
        loss = nn.categoricalCrossEntropy(output, noGrad(jnp.asarray(by)))
        loss_meter += float(loss.tensor()) * len(by)
        n += len(by)
    model.train()
    return loss_meter / n, 100.0 * err_meter / n


def main():
    # the session is the one knob for the whole run; "jnp" is the default
    # backend — swap it (e.g. "lazy") and the entire loop follows
    with repro.session(backend="jnp", tag="quickstart"):
        _run()


def _run():
    image_dim, classes, batch_size = 12, 10, 64
    xs, ys = load_dataset()
    val_x, val_y = xs[:256], ys[:256]
    train_x, train_y = xs[256:], ys[256:]
    trainset = BatchDataset(TensorDataset([train_x, train_y]), batch_size)
    valset = BatchDataset(TensorDataset([val_x, val_y]), batch_size)

    model = nn.Sequential(
        nn.Conv2D(1, 8, 3, 3), nn.ReLU(), nn.Pool2D(2, 2, 2, 2),
        nn.Conv2D(8, 16, 3, 3), nn.ReLU(), nn.Pool2D(2, 2, 2, 2),
        nn.View((-1, 3 * 3 * 16)),
        nn.Linear(3 * 3 * 16, 64), nn.ReLU(), nn.Dropout(0.1),
        nn.Linear(64, classes), nn.LogSoftmax())

    opt = optim.SGDOptimizer(model.params(), lr=0.1, momentum=0.9)
    for epoch in range(4):
        train_loss, nb = 0.0, 0
        for bx, by in trainset:
            inputs = noGrad(jnp.asarray(bx))
            output = model(inputs)
            target = noGrad(jnp.asarray(by))
            loss = nn.categoricalCrossEntropy(output, target)
            train_loss += float(loss.tensor())
            nb += 1
            loss.backward()
            opt.step()
            opt.zeroGrad()
        val_loss, val_err = eval_loop(model, valset)
        print(f"Epoch {epoch}: Avg Train Loss: {train_loss/nb:.4f} "
              f"Validation Loss: {val_loss:.4f} "
              f"Validation Error (%): {val_err:.2f}")
    assert val_err < 20.0, "training failed"
    print("quickstart OK")


if __name__ == "__main__":
    main()
