"""``repro.compile`` smoke: trace → passes → Pallas cluster lowering.

A function written against ``repro.core.tensor.ops`` is compiled through
the graph-IR pipeline (paper §4.1.1's ArrayFire-JIT story as a first-class
subsystem): the call is traced into an explicit ``Graph``, optimized by
CSE / constant folding / DCE / elementwise fusion, and the fused clusters
run as *generated* Pallas kernels (interpret mode off-TPU).  The script
asserts compiled == eager bit-for-bit and prints the captured IR plus the
per-pass stats — CI runs it as a smoke test.

Run:  PYTHONPATH=src python examples/compile_fn.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.compiler import CompilerPolicy, trace
from repro.core.tensor import ops
from repro.core.tensor.lazy_backend import LazyBackend


def gelu_residual(x, w):
    """A small fused-friendly block: matmul + exact gelu + gated residual.

    (The gate's ``tanh`` between the ``mul`` and the residual ``add`` also
    keeps the graph FMA-contraction-free, so compiled == eager holds
    *bit-for-bit* — see tests/test_compiler.py for the general ulp story.)
    """
    h = ops.matmul(x, w)
    g = ops.gelu(h)
    # the same subexpression twice — CSE folds it back to one
    scale = ops.add(ops.tanh(h), ops.tanh(h))
    return ops.add(ops.tanh(ops.mul(g, scale)), h)


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64), jnp.float32)

    # eager reference: one XLA dispatch per op
    want = np.asarray(gelu_residual(x, w))

    # show the captured IR for the same computation
    lb = LazyBackend()
    with repro.session(backend=lb):
        g, _ = trace([gelu_residual(lb._lift(x), lb._lift(w))])
    print("captured IR (pre-optimization):")
    print(g.dump())
    print()

    compiled = repro.compile(gelu_residual)
    got = np.asarray(compiled(x, w))
    exe = compiled.last_executable
    print("pipeline:", [s.describe() for s in exe.report])
    print(f"lowered to {exe.n_dispatches} dispatch(es), "
          f"{exe.n_kernels} generated Pallas kernel(s)")

    np.testing.assert_array_equal(got, want)
    assert compiled.trace_count == 1
    compiled(x, w)                      # same signature: replay, no retrace
    assert compiled.trace_count == 1, "second call must hit the cache"
    assert exe.n_dispatches < sum(
        1 for u in g.order if g.nodes[u].op != "input"), \
        "pipeline should dispatch fewer calls than ops traced"

    # the session's CompilerPolicy swaps the pipeline without touching fn
    with repro.session(compiler=CompilerPolicy.legacy()):
        legacy = repro.compile(gelu_residual)
        np.testing.assert_array_equal(np.asarray(legacy(x, w)), want)
        assert legacy.last_executable.n_kernels == 0

    print("OK: compiled == eager (bit-for-bit), cache hit on 2nd call, "
          "legacy pipeline agrees")


if __name__ == "__main__":
    main()
