"""Speculative decoding example: n-gram self-drafting with wide verify
and block-table rollback on the paged KV cache — proposals the target
rejects are rolled back by truncating the slot's block table, and greedy
output is token-for-token identical to one-token decode.

Run:  PYTHONPATH=src python examples/serve_spec.py
"""

import time

import jax

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving import Request, ServeEngine

PROMPTS = [[3, 1, 4, 1, 5, 9, 2, 6], [5, 3, 5, 8, 9],
           [7, 9, 50, 28, 8, 41], [16, 39, 9, 37, 51, 5, 8]]


def _requests():
    return [Request(uid=uid, prompt=list(p), max_new_tokens=24)
            for uid, p in enumerate(PROMPTS)]


def _drive(engine):
    for req in _requests():
        engine.submit(req)
    t0 = time.time()
    done = engine.run_until_done()
    return {r.uid: r.generated for r in done}, time.time() - t0


def main():
    # codeqwen has no sliding-window layers, so the paged cache can
    # rewind — the requirement for speculative rollback
    cfg = get_config("codeqwen1.5-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = dict(cache="paged", block_size=8, prefill_chunk=8)

    # reference: plain one-token greedy decode
    with repro.session(tag="serve_spec:plain"):
        plain = ServeEngine(model, params, batch_slots=4, max_seq=64,
                            policy=ServingPolicy(**base))
    ref, t_plain = _drive(plain)

    # speculative: n-gram self-drafting, k=4 proposals per verify round
    spec_policy = ServingPolicy(**base, speculative=dict(
        enabled=True, k=4, draft="ngram", ngram=3))
    with repro.session(tag="serve_spec:spec"):
        spec = ServeEngine(model, params, batch_slots=4, max_seq=64,
                           policy=spec_policy)
    out, t_spec = _drive(spec)

    desc = spec.describe()["speculative"]
    kv = spec.describe()["kv_cache"]
    toks = sum(len(g) for g in out.values())
    print(f"[serve_spec] {len(out)} requests, {toks} tokens | "
          f"{desc['verify_calls']} wide-verify calls vs "
          f"{plain.decode_calls} one-token decode calls")
    print(f"[serve_spec] accepted/step {desc['accepted_per_step']} "
          f"(accepted {desc['accepted_tokens']}, rejected "
          f"{desc['rejected_tokens']}), rollback freed "
          f"{kv['rollback_blocks_freed']} blocks | speedup "
          f"{t_plain / max(t_spec, 1e-9):.2f}x")
    print(f"[serve_spec] serving provenance: "
          f"{spec.session.describe()['serving']['speculative']}")

    # the acceptance rule guarantees identity regardless of draft quality
    assert out == ref, "speculative/plain decode divergence!"
    assert desc["verify_calls"] > 0, "speculative path never engaged"
    assert spec.kv.blocks_in_use == 0, "speculative decode leaked blocks"
    print("serve_spec OK")


if __name__ == "__main__":
    main()
