"""End-to-end driver: train a language model for a few hundred steps with
the production substrate — real data pipeline (packing, shuffling,
prefetch), AdamW + cosine schedule, gradient clipping, checkpointing with
resume, straggler monitoring.

Default is a CI-sized run (~45s); pass ``--preset 100m --steps 300`` for
the full-size variant on capable hardware (same code path).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import tempfile

import numpy as np

import repro
from repro.configs.base import get_config
from repro.launch.train import make_batches
from repro.models import build_model, tree_params_count
from repro.training.train_loop import TrainConfig, train
import jax


PRESETS = {
    # (arch, reduced, batch, seq, overrides)
    "ci": ("codeqwen1.5-7b", True, 8, 64, dict(n_layers=2, d_model=128,
                                               n_heads=4, n_kv_heads=4,
                                               d_ff=256)),
    "20m": ("codeqwen1.5-7b", True, 8, 128, dict(n_layers=6, d_model=384,
                                                 n_heads=6, n_kv_heads=6,
                                                 d_ff=1024)),
    "100m": ("codeqwen1.5-7b", True, 8, 256, dict(n_layers=12, d_model=768,
                                                  n_heads=12, n_kv_heads=12,
                                                  d_ff=2048,
                                                  vocab_size=8192)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    arch, reduced, batch, seq, overrides = PRESETS[args.preset]
    with repro.session(tag=f"train_lm:{args.preset}"):
        cfg = get_config(arch, reduced=reduced, **overrides)
        model = build_model(cfg)
        n = tree_params_count(model.abstract_params())
        print(f"[train_lm] preset={args.preset} params={n/1e6:.1f}M "
              f"batch={batch} seq={seq} steps={args.steps}")

        params = model.init(jax.random.PRNGKey(0))
        ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
        tcfg = TrainConfig(steps=args.steps, base_lr=3e-3,
                           warmup=max(5, args.steps // 20),
                           checkpoint_dir=ckpt_dir, checkpoint_every=100)
        batches = make_batches(cfg, batch, seq, args.steps)
        params, history = train(model, params, batches, tcfg)
    first = np.mean([h["loss"] for h in history[:10]])
    last = np.mean([h["loss"] for h in history[-10:]])
    tput = batch * seq / np.median([h["sec"] for h in history[5:]])
    print(f"[train_lm] loss {first:.3f} -> {last:.3f}; "
          f"{tput:,.0f} tokens/s; checkpoints in {ckpt_dir}")
    assert last < first, "loss did not decrease"
    print("train_lm OK")


if __name__ == "__main__":
    main()
