"""Paper Table 2 analog: build-time → compile-time adaptation.

In C++ the modifiability cost is recompiling the framework; in JAX it is
re-tracing + re-lowering + XLA-compiling after a change.  We measure:

  cold     — first jit of a training step (trace+lower+compile)
  incremental — re-jit after a "source change" (new function object with a
                changed constant → full retrace+recompile), the analog of
                touching one file
  cached   — dispatch cost when nothing changed (jit cache hit)

The paper's claim (orders-of-magnitude cheaper iteration than monolithic
frameworks) maps to: incremental ≈ cold ≪ a monolithic rebuild, and
cached ≈ microseconds.

Second section: **static-analysis overhead**.  ``repro.compile`` runs the
``repro.analysis`` suite at the session's check level; this measures the
graph-pipeline compile (trace → passes → verify → lower) at every level
over a representative elementwise program.  The contract asserted in CI
(``--quick``): ``default`` adds < 5% over ``off`` — always-on
verification must be effectively free.

``--quick`` shrinks repetitions and skips the jit section (the XLA
compile dominates CI minutes and says nothing about analysis cost);
``--out PATH`` writes a JSON artifact.
"""

from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

import bench_artifact


def bench_jit_adaptation() -> list[tuple[str, float, str]]:
    from repro.configs.base import get_config
    from repro.core.optim import AdamW
    from repro.models import build_model
    from repro.training.train_loop import TrainConfig, make_step_fn

    cfg = get_config("gemma3-27b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    tcfg = TrainConfig(steps=10, base_lr=1e-3, warmup=1)

    t0 = time.perf_counter()
    step = jax.jit(make_step_fn(model, opt, tcfg))
    out = step(params, opt_state, jnp.int32(0), batch)
    jax.block_until_ready(out[2]["loss"])
    cold = time.perf_counter() - t0

    # "incremental rebuild": change one constant in the step function
    t0 = time.perf_counter()
    tcfg2 = TrainConfig(steps=10, base_lr=2e-3, warmup=1)
    step2 = jax.jit(make_step_fn(model, opt, tcfg2))
    out = step2(params, opt_state, jnp.int32(0), batch)
    jax.block_until_ready(out[2]["loss"])
    incremental = time.perf_counter() - t0

    # cache hit dispatch
    t0 = time.perf_counter()
    for _ in range(20):
        out = step2(params, opt_state, jnp.int32(1), batch)
    jax.block_until_ready(out[2]["loss"])
    cached = (time.perf_counter() - t0) / 20

    return [
        ("compile_cold_s", cold, "trace+lower+XLA compile of train step"),
        ("compile_incremental_s", incremental,
         f"{incremental/cold:.2f}x of cold (paper: 0.6min vs 34min "
         "from-scratch)"),
        ("compile_cached_step_s", cached, "jit cache-hit dispatch+run"),
    ]


def _analysis_workload(ops, x):
    """A representative fusable elementwise program (~10 graph nodes)."""
    y = ops.mul(ops.add(x, x), ops.tanh(x))
    y = ops.add(ops.sqrt(ops.abs(y)), ops.neg(x))
    y = ops.mul(ops.exp(ops.neg(ops.abs(y))), y)
    return ops.sum(y, axis=None, keepdims=False)


def bench_analysis_overhead(reps: int) -> dict:
    """Median graph-pipeline compile time per check level.

    Each repetition builds a fresh CompiledFunction so every call is a
    full trace → passes (→ verify) → analyze → lower; the run itself is
    excluded from nothing (it is identical across levels and small).
    """
    import repro
    from repro.core.tensor import ops

    x = jnp.linspace(-2.0, 2.0, 64 * 64).reshape(64, 64)
    times: dict[str, float] = {}
    for level in ("off", "default", "strict"):
        samples = []
        for _ in range(reps):
            f = repro.compile(lambda a: _analysis_workload(ops, a),
                              check=level)
            t0 = time.perf_counter()
            out = f(x)
            jax.block_until_ready(out)
            samples.append(time.perf_counter() - t0)
        times[level] = statistics.median(samples)
    off = times["off"]
    return {
        "reps": reps,
        "median_s": times,
        "overhead": {lvl: times[lvl] / off - 1.0 for lvl in times},
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: skip the jit section, fewer reps, and "
                    "assert the default-level overhead contract (<5%%)")
    ap.add_argument("--reps", type=int, default=None,
                    help="repetitions per analysis level")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write a JSON artifact to PATH")
    args = ap.parse_args(argv)

    result: dict = {"bench": "compile"}

    if not args.quick:
        rows = bench_jit_adaptation()
        result["jit_adaptation"] = {n: {"seconds": v, "note": d}
                                    for n, v, d in rows}
        for name, val, derived in rows:
            print(f"{name},{val*1e6:.1f},{derived}")

    reps = args.reps or (9 if args.quick else 15)
    ana = bench_analysis_overhead(reps)
    result["analysis_overhead"] = ana
    for lvl, t in ana["median_s"].items():
        print(f"analysis_compile_{lvl}_s,{t*1e6:.1f},"
              f"overhead {ana['overhead'][lvl]*100:+.1f}% vs off")

    if args.out:
        result.pop("bench", None)
        bench_artifact.emit("compile", result, out=args.out,
                            quick=args.quick, echo=False)

    if args.quick:
        # the CI contract: always-on verification is effectively free
        overhead = ana["overhead"]["default"]
        if overhead >= 0.05:
            print(f"FAIL default-level analysis adds {overhead*100:.1f}% "
                  "to compile time (budget: 5%)")
            return 1
        print(f"ok: default-level analysis adds {overhead*100:.1f}% "
              "(< 5% budget)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
