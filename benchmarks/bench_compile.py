"""Paper Table 2 analog: build-time → compile-time adaptation.

In C++ the modifiability cost is recompiling the framework; in JAX it is
re-tracing + re-lowering + XLA-compiling after a change.  We measure:

  cold     — first jit of a training step (trace+lower+compile)
  incremental — re-jit after a "source change" (new function object with a
                changed constant → full retrace+recompile), the analog of
                touching one file
  cached   — dispatch cost when nothing changed (jit cache hit)

The paper's claim (orders-of-magnitude cheaper iteration than monolithic
frameworks) maps to: incremental ≈ cold ≪ a monolithic rebuild, and
cached ≈ microseconds.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.optim import AdamW
from repro.models import build_model
from repro.training.train_loop import TrainConfig, make_step_fn


def run() -> list[tuple[str, float, str]]:
    cfg = get_config("gemma3-27b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    tcfg = TrainConfig(steps=10, base_lr=1e-3, warmup=1)

    t0 = time.perf_counter()
    step = jax.jit(make_step_fn(model, opt, tcfg))
    out = step(params, opt_state, jnp.int32(0), batch)
    jax.block_until_ready(out[2]["loss"])
    cold = time.perf_counter() - t0

    # "incremental rebuild": change one constant in the step function
    t0 = time.perf_counter()
    tcfg2 = TrainConfig(steps=10, base_lr=2e-3, warmup=1)
    step2 = jax.jit(make_step_fn(model, opt, tcfg2))
    out = step2(params, opt_state, jnp.int32(0), batch)
    jax.block_until_ready(out[2]["loss"])
    incremental = time.perf_counter() - t0

    # cache hit dispatch
    t0 = time.perf_counter()
    for _ in range(20):
        out = step2(params, opt_state, jnp.int32(1), batch)
    jax.block_until_ready(out[2]["loss"])
    cached = (time.perf_counter() - t0) / 20

    return [
        ("compile_cold_s", cold, "trace+lower+XLA compile of train step"),
        ("compile_incremental_s", incremental,
         f"{incremental/cold:.2f}x of cold (paper: 0.6min vs 34min "
         "from-scratch)"),
        ("compile_cached_step_s", cached, "jit cache-hit dispatch+run"),
    ]


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val*1e6:.1f},{derived}")
