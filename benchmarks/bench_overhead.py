"""Paper Table 3 analog: end-to-end overhead on four model families.

The paper benchmarks 100 iterations of forward+backward(+update) and shows
Flashlight's framework tax is low.  Off-GPU we can't reproduce absolute
V100 numbers, so the reproduction compares *our stack against raw JAX on
identical math*: ours/tape (core Module+Variable+tape autograd, jit'd),
ours/prod (functional substrate + jax.grad), and a hand-written raw-JAX
baseline.  Overhead% = (ours - raw) / raw.  The paper's claim maps to
overhead ≈ 0 (everything jit-compiles to the same XLA program).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import bench_artifact
import repro
from repro import obs
from repro.core import autograd as ag
from repro.core import nn
from repro.core.autograd import functions as F

ITERS = 100


def _bench(fn, *args, iters=ITERS, warmup=5):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = obs.now()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return obs.now() - t0


# -------------------------------------------------------- model definitions

def make_cnn_pair(key):
    """AlexNet-flavor small CNN (conv/pool/linear)."""
    b, hw, c, classes = 8, 16, 3, 10
    x = jax.random.normal(key, (b, hw, hw, c))
    y = jnp.arange(b) % classes

    model = nn.Sequential(
        nn.Conv2D(c, 16, 3, 3, key=jax.random.PRNGKey(1)),
        nn.ReLU(), nn.Pool2D(2, 2, 2, 2),
        nn.Conv2D(16, 32, 3, 3, key=jax.random.PRNGKey(2)),
        nn.ReLU(), nn.Pool2D(2, 2, 2, 2),
        nn.View((b, 4 * 4 * 32)),
        nn.Linear(4 * 4 * 32, classes, key=jax.random.PRNGKey(3)))
    params0 = model.param_pytree()
    names = list(params0)

    def tape_step(params, xx, yy):
        # imperative paper-style step, traced under jit: rebind module
        # params to the traced values, build the tape, walk it backward
        model.set_param_pytree(params)
        model.zero_grad()
        out = model(ag.Variable(xx))
        loss = nn.categoricalCrossEntropy(out, ag.Variable(yy))
        loss.backward()
        named = dict(model.named_params())
        new_params = {k: params[k] - 0.01 * named[k].grad for k in params}
        return loss.data, new_params

    w = {k: params0[k] for k in names}

    def raw_loss(params, xx, yy):
        h = jax.lax.conv_general_dilated(
            xx, params["m0.weight"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["m0.bias"]
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        h = jax.lax.conv_general_dilated(
            h, params["m3.weight"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["m3.bias"]
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        h = h.reshape(b, -1)
        logits = h @ params["m7.weight"] + params["m7.bias"]
        return -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                             yy[:, None], 1))

    def raw_step(params, xx, yy):
        loss, grads = jax.value_and_grad(raw_loss)(params, xx, yy)
        return loss, jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)

    return (tape_step, raw_step, (w, x, y))


def _tape_transformer(key, b, s, d, heads, layers, vocab):
    blocks = [nn.TransformerBlock(d, heads,
                                  key=jax.random.fold_in(key, i))
              for i in range(layers)]
    emb = nn.Embedding(vocab, d, key=jax.random.fold_in(key, 99))
    head = nn.Linear(d, vocab, key=jax.random.fold_in(key, 100))
    container = nn.Container(emb, *blocks, head)

    params0 = container.param_pytree()

    def tape_step(params, toks, labels):
        container.set_param_pytree(params)
        container.zero_grad()
        h = emb(toks)
        for blk in blocks:
            h = blk(h)
        logits = head(h)
        loss = nn.categoricalCrossEntropy(
            F.reshape(logits, (b * s, vocab)),
            ag.Variable(labels.reshape(-1)))
        loss.backward()
        named = dict(container.named_params())
        new_params = {k: params[k] - 0.01 * named[k].grad for k in params}
        return loss.data, new_params

    return container, tape_step, params0


def _raw_transformer_step(b, s, d, heads, layers, vocab):
    hd = d // heads

    def fwd(params, toks):
        h = params["emb"][toks]
        for i in range(layers):
            p = params[f"l{i}"]
            ln = (h - h.mean(-1, keepdims=True)) / jnp.sqrt(
                h.var(-1, keepdims=True) + 1e-5)
            ln = ln * p["ln1_w"] + p["ln1_b"]
            q = (ln @ p["wq"] + p["bq"]).reshape(b, s, heads, hd)
            k = (ln @ p["wk"] + p["bk"]).reshape(b, s, heads, hd)
            v = (ln @ p["wv"] + p["bv"]).reshape(b, s, heads, hd)
            sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
            w = jax.nn.softmax(sc, -1)
            o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, d)
            h = h + (o @ p["wo"] + p["bo"])
            ln = (h - h.mean(-1, keepdims=True)) / jnp.sqrt(
                h.var(-1, keepdims=True) + 1e-5)
            ln = ln * p["ln2_w"] + p["ln2_b"]
            h = h + (jax.nn.gelu(ln @ p["w1"] + p["b1"],
                                 approximate=False) @ p["w2"] + p["b2"])
        return h @ params["head_w"] + params["head_b"]

    def loss(params, toks, labels):
        logits = fwd(params, toks).reshape(b * s, vocab)
        return -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), labels.reshape(-1)[:, None], 1))

    def step(params, toks, labels):
        l, g = jax.value_and_grad(loss)(params, toks, labels)
        return l, jax.tree.map(lambda p, gg: p - 0.01 * gg, params, g)

    return step


def _map_tape_to_raw(params0, layers):
    out = {"emb": params0["m0.weight"]}
    for i in range(layers):
        pre = f"m{i+1}."
        out[f"l{i}"] = {
            "ln1_w": params0[pre + "ln1.weight"],
            "ln1_b": params0[pre + "ln1.bias"],
            "wq": params0[pre + "attn.wq.weight"],
            "bq": params0[pre + "attn.wq.bias"],
            "wk": params0[pre + "attn.wk.weight"],
            "bk": params0[pre + "attn.wk.bias"],
            "wv": params0[pre + "attn.wv.weight"],
            "bv": params0[pre + "attn.wv.bias"],
            "wo": params0[pre + "attn.wo.weight"],
            "bo": params0[pre + "attn.wo.bias"],
            "ln2_w": params0[pre + "ln2.weight"],
            "ln2_b": params0[pre + "ln2.bias"],
            "w1": params0[pre + "ff1.weight"],
            "b1": params0[pre + "ff1.bias"],
            "w2": params0[pre + "ff2.weight"],
            "b2": params0[pre + "ff2.bias"],
        }
    n = layers + 1
    out["head_w"] = params0[f"m{n}.weight"]
    out["head_b"] = params0[f"m{n}.bias"]
    return out


def make_transformer_pair(key, b=4, s=64, d=64, heads=4, layers=2,
                          vocab=256):
    """BERT-like / ViT-like / ASR-transformer-like share this skeleton."""
    _, tape_step, params0 = _tape_transformer(key, b, s, d, heads, layers,
                                              vocab)
    raw_step = _raw_transformer_step(b, s, d, heads, layers, vocab)
    raw_params = _map_tape_to_raw(params0, layers)
    toks = jax.random.randint(key, (b, s), 0, vocab)
    labels = jnp.roll(toks, -1, 1)
    return tape_step, raw_step, params0, raw_params, (toks, labels)


def run() -> list[tuple[str, float, str]]:
    # benchmark provenance: the whole comparison runs under one session
    # whose describe() snapshot names the configuration being measured
    with repro.session(backend="jnp", tag="bench_overhead") as sess:
        rows = _run(key=jax.random.PRNGKey(0))
    rows.append(("overhead_session", 0.0, str(sess.describe())))
    return rows


def _run(key) -> list[tuple[str, float, str]]:
    rows = []

    # CNN family
    tape_step, raw_step, (w, x, y) = make_cnn_pair(key)
    t_tape = _bench(jax.jit(tape_step), w, x, y)
    t_raw = _bench(jax.jit(raw_step), w, x, y)
    rows.append(("overhead_cnn_tape_s100", t_tape,
                 f"overhead={100*(t_tape-t_raw)/t_raw:+.1f}%"))
    rows.append(("overhead_cnn_rawjax_s100", t_raw, "baseline"))

    # transformer families at three shapes (BERT-like / ViT-like / ASR-like)
    for name, shape in [("bert_like", dict(b=4, s=64, d=64, heads=4,
                                           layers=2, vocab=256)),
                        ("vit_like", dict(b=2, s=196, d=64, heads=4,
                                          layers=2, vocab=128)),
                        ("asr_tr_like", dict(b=2, s=128, d=96, heads=6,
                                             layers=3, vocab=64))]:
        tape_step, raw_step, p_tape, p_raw, (toks, labels) = \
            make_transformer_pair(key, **shape)
        # verify identical math before timing
        l_t, _ = jax.jit(tape_step)(p_tape, toks, labels)
        l_r, _ = jax.jit(raw_step)(p_raw, toks, labels)
        assert abs(float(l_t) - float(l_r)) < 1e-3, (float(l_t), float(l_r))
        t_tape = _bench(jax.jit(tape_step), p_tape, toks, labels)
        t_raw = _bench(jax.jit(raw_step), p_raw, toks, labels)
        rows.append((f"overhead_{name}_tape_s100", t_tape,
                     f"overhead={100*(t_tape-t_raw)/t_raw:+.1f}%"))
        rows.append((f"overhead_{name}_rawjax_s100", t_raw, "baseline"))
    return rows


# ------------------------------------------------------ observability tax

def run_obs_overhead(reps: int = 3) -> dict:
    """Serving throughput with observability off vs on, same engine code.

    Three engines decode the same workload: ``baseline`` and ``off``
    are both obs-disabled (the instrumented code path with every hook
    behind its ``tracer is None`` guard — identical, so their spread is
    the measurement noise floor), ``on`` records the full trace.  Each
    engine warms its jit caches untimed, then the reps interleave
    across engines so drift hits all three equally.  Min-of-reps is the
    estimator.  Also microbenchmarks the disabled-path guard
    (``obs.get_tracer()`` with obs off) to show the per-site cost.
    """
    from bench_serving import _fresh, drive, make_workload
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.runtime import ServingPolicy
    from repro.serving import ServeEngine

    # large enough that a decode step costs ~ms: the contract compares
    # per-step instrumentation (µs scale) against real model work, not
    # against an empty loop
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=4,
                     d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                     vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = ServingPolicy(cache="paged", block_size=8, prefill_chunk=8)
    workload = make_workload(6, 16, seed=5)
    warmup = make_workload(2, 4, seed=6)
    tokens = None

    def make_engine(obs_on: bool) -> ServeEngine:
        mode = "on" if obs_on else "off"
        with repro.session(obs=obs_on, tag=f"bench_overhead:obs-{mode}"):
            eng = ServeEngine(model, params, batch_slots=2, max_seq=64,
                              policy=policy)
        drive(eng, _fresh(warmup))        # jit caches are per-engine:
        drive(eng, _fresh(workload))      # compile + settle, untimed
        return eng

    engines = {"baseline": make_engine(False),
               "off": make_engine(False),
               "on": make_engine(True)}
    times: dict[str, list[float]] = {k: [] for k in engines}
    for _ in range(reps):
        for name, eng in engines.items():           # interleaved reps
            done, wall = drive(eng, _fresh(workload))
            times[name].append(wall)
            got = {r.uid: list(r.generated) for r in done}
            assert tokens is None or got == tokens, \
                f"{name} decoded different tokens"
            tokens = got
    best = {k: min(v) for k, v in times.items()}

    n = 100_000
    with repro.session():
        t0 = obs.now()
        for _ in range(n):
            obs.get_tracer()
        guard_us = (obs.now() - t0) / n * 1e6

    off_vs_base = best["off"] / best["baseline"]
    on_vs_off = best["on"] / best["off"]
    for name in ("baseline", "off", "on"):
        print(f"obs_serving_{name}_s,{best[name]*1e6:.1f},"
              f"min of {reps} reps")
    print(f"obs_disabled_guard_us,{guard_us:.3f},per get_tracer() call")
    print(f"obs off-vs-baseline {100*(off_vs_base-1):+.1f}% (noise floor), "
          f"on-vs-off {100*(on_vs_off-1):+.1f}%")
    return {"reps": reps, "times_s": times, "min_s": best,
            "disabled_guard_us": round(guard_us, 3),
            "off_vs_baseline": round(off_vs_base, 4),
            "on_vs_off": round(on_vs_off, 4)}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: skip the model-family table and assert "
                    "the observability overhead contract")
    ap.add_argument("--reps", type=int, default=None,
                    help="interleaved reps per obs mode")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write a JSON artifact to PATH")
    args = ap.parse_args(argv)

    result: dict = {}
    if not args.quick:
        rows = run()
        for name, val, derived in rows:
            print(f"{name},{val*1e6/ITERS:.1f},{derived}")
        result["families"] = {n: {"seconds": v, "note": d}
                              for n, v, d in rows}

    ob = run_obs_overhead(reps=args.reps or 5)
    result["obs_overhead"] = ob
    bench_artifact.emit("overhead", result, out=args.out, quick=args.quick,
                        echo=False)

    if args.quick:
        # the CI contract: instrumentation behind a disabled policy is
        # noise (off == baseline code-path-for-code-path), and recording
        # the full trace costs < 5% serving throughput
        if not (0.95 <= ob["off_vs_baseline"] <= 1.05):
            print(f"FAIL obs-off run differs from baseline by "
                  f"{100*(ob['off_vs_baseline']-1):+.1f}% (budget ±5%)")
            return 1
        if ob["on_vs_off"] > 1.05:
            print(f"FAIL obs-on tracing costs "
                  f"{100*(ob['on_vs_off']-1):+.1f}% serving throughput "
                  "(budget 5%)")
            return 1
        print(f"ok: obs-off indistinguishable from baseline "
              f"({100*(ob['off_vs_baseline']-1):+.1f}%), obs-on costs "
              f"{100*(ob['on_vs_off']-1):+.1f}% (budget 5%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
