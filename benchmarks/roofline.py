"""§Roofline report: three-term roofline per (arch × shape × mesh) from the
dry-run artifacts.

Terms (TPU v5e constants; per-chip since post-SPMD HLO is per-device):
  compute_s    = HLO_dot_FLOPs / 197e12          (bf16 MXU peak)
  memory_s     = HBM_traffic / 819e9             (HBM bandwidth)
  collective_s = collective_wire_bytes / 50e9    (per-link ICI)

HLO_dot_FLOPs and collective bytes are loop-corrected (launch/
hlo_analysis.py — XLA's cost_analysis counts scan bodies once; we multiply
by known_trip_count).  HBM_traffic is modeled as
argument_bytes + output_bytes + 2·temp_bytes (every temp written+read
once) — a fusion-independent lower-bound proxy, documented in
EXPERIMENTS.md.

Derived metrics:
  bound_s            = max(term)          (perfect-overlap step-time bound)
  useful_s           = MODEL_FLOPS / (chips · peak)
  roofline_fraction  = useful_s / bound_s (MFU at the modeled bound — the
                       §Perf score)
  flops_ratio        = MODEL_FLOPS / (chips · HLO_FLOPs)  (remat/redundancy
                       waste detector)
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK = 197e12
HBM = 819e9
ICI = 50e9

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh: str, variant: str) -> list[dict]:
    d = ART / mesh / variant
    if not d.exists():
        return []
    return [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]


def roofline_row(rec: dict) -> dict | None:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    chips = rec["chips"]
    ha = rec.get("hlo_analysis") or {}
    flops = ha.get("dot_flops") or rec["cost"].get("flops", 0)
    mem = rec["memory"]
    traffic = (mem.get("argument_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               + 2 * mem.get("temp_size_in_bytes", 0))
    coll = ha.get("collective_total_bytes",
                  rec.get("collectives", {}).get("total_bytes", 0))
    compute_s = flops / PEAK
    memory_s = traffic / HBM
    collective_s = coll / ICI
    bound = max(compute_s, memory_s, collective_s, 1e-12)
    useful_s = rec["model_flops"] / (chips * PEAK)
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    hbm_per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec["variant"], "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bound_s": bound,
        "useful_s": useful_s,
        "roofline_fraction": useful_s / bound,
        "flops_ratio": rec["model_flops"] / (chips * flops + 1e-9),
        "dominant": dominant,
        "hbm_gib_per_dev": hbm_per_dev / 2**30,
        "over_hbm_budget": hbm_per_dev > 16 * 2**30,
        "model_flops": rec["model_flops"],
        "hlo_flops_per_dev": flops,
        "collective_bytes": ha.get("collective_bytes", {}),
        "lever": _lever(dominant, rec),
    }


def _lever(dominant: str, rec: dict) -> str:
    kind = "train" if rec["shape"].startswith("train") else \
        ("decode" if "decode" in rec["shape"] or "500k" in rec["shape"]
         else "prefill")
    if dominant == "compute":
        return ("reduce recompute (remat policy) and redundant einsum "
                "transposes; raise per-dot tile efficiency")
    if dominant == "memory":
        if kind == "prefill":
            return ("blockwise attention: kill the O(S^2) scores buffer; "
                    "chunked CE for big-vocab logits")
        if kind == "decode":
            return ("shard the KV cache across more axes; shrink cache "
                    "dtype; batch more decode slots per chip")
        return ("activation-checkpoint policy (dots) + chunked CE to cut "
                "temp traffic")
    return ("replace all-gathers with flash-decoding partial-stat combine "
            "/ overlap grad all-reduce with backward (bucketed sync)")


def table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | bound_s "
           "| dominant | MFU@bound | 6ND/HLO | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        flag = " ⚠" if r["over_hbm_budget"] else ""
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bound_s']:.3e} | {r['dominant']} | "
            f"{r['roofline_fraction']*100:.1f}% | {r['flops_ratio']:.2f} | "
            f"{r['hbm_gib_per_dev']:.1f}{flag} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod_16x16")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = [r for r in (roofline_row(c)
                        for c in load_cells(args.mesh, args.variant)) if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(table(rows))
    skipped = [c for c in load_cells(args.mesh, args.variant)
               if c.get("skipped")]
    for c in skipped:
        print(f"skipped: {c['arch']} {c['shape']} — {c['skip_reason']}")
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
