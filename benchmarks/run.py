"""Benchmark harness: one module per paper table / case study.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline table (§Roofline,
from dry-run artifacts) is appended when artifacts exist.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_beamsearch, bench_compile,
                            bench_complexity, bench_fragmentation,
                            bench_fusion, bench_overhead)

    suites = [
        ("Table 1 (complexity)", bench_complexity.run, 1.0),
        ("Table 2 (compile/iteration time)", bench_compile.run, 1e6),
        ("Table 3 (overhead)", bench_overhead.run, 1e6 / 100),
        ("Case 5.2.1 (beam search tape)", bench_beamsearch.run, 1.0),
        ("Case 5.2.2 (fragmentation)", bench_fragmentation.run, 1.0),
        ("Fusion (deferred backend)", bench_fusion.run, 1e6),
    ]
    failures = 0
    for title, fn, scale in suites:
        print(f"# {title}")
        try:
            for name, val, derived in fn():
                print(f"{name},{val*scale:.2f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
        print()

    # §Roofline summary from dry-run artifacts (if present)
    try:
        from benchmarks import roofline

        rows = [r for r in (roofline.roofline_row(c) for c in
                            roofline.load_cells("single_pod_16x16",
                                                "baseline")) if r]
        if rows:
            print("# Roofline (single pod, baseline) — "
                  "MFU@bound per live cell")
            for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
                print(f"roofline_{r['arch']}_{r['shape']},"
                      f"{r['bound_s']*1e6:.1f},"
                      f"MFU@bound={r['roofline_fraction']*100:.1f}% "
                      f"dominant={r['dominant']}")
    except Exception:  # noqa: BLE001
        traceback.print_exc()

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
