"""Paper Table 1 analog: framework complexity metrics.

Reports our op surface / LOC / per-function operator counts next to the
paper's published PyTorch & TensorFlow numbers (reference values from the
paper's Table 1; we cannot re-measure those here).
"""

from __future__ import annotations

from pathlib import Path

from repro.core.tensor import TensorBackend

ROOT = Path(__file__).resolve().parents[1]

PAPER = {
    "pytorch": {"loc": 1_798_292, "ops": 2166, "add": 55, "conv": 85,
                "sum": 25},
    "tensorflow": {"loc": 1_306_159, "ops": 1423, "add": 20, "conv": 30,
                   "sum": 10},
    "flashlight": {"loc": 27_173, "ops": 60, "add": 1, "conv": 2, "sum": 1},
}


def count_loc(subdir: str = "src/repro") -> int:
    total = 0
    for p in (ROOT / subdir).rglob("*.py"):
        total += sum(1 for line in p.read_text().splitlines()
                     if line.strip() and not line.strip().startswith("#"))
    return total


def run() -> list[tuple[str, float, str]]:
    prims = TensorBackend.primitive_ops()
    n_ops = len(prims)
    loc_all = count_loc("src/repro")
    loc_core = count_loc("src/repro/core")
    n_add = prims.count("add")
    n_conv = sum(1 for p in prims if p.startswith("conv"))
    n_sum = prims.count("sum")
    rows = [
        ("complexity_op_surface", float(n_ops),
         f"paper: fl={PAPER['flashlight']['ops']} "
         f"pt={PAPER['pytorch']['ops']} tf={PAPER['tensorflow']['ops']}"),
        ("complexity_loc_total", float(loc_all),
         f"paper fl=27173; pt=1.8M tf=1.3M"),
        ("complexity_loc_core", float(loc_core),
         "tensor+autograd+nn+optim+memory+dist+data"),
        ("complexity_ops_performing_add", float(n_add),
         f"paper: fl=1 pt=55 tf=20"),
        ("complexity_ops_performing_conv", float(n_conv),
         f"paper: fl=2 pt=85 tf=30"),
        ("complexity_ops_performing_sum", float(n_sum),
         f"paper: fl=1 pt=25 tf=10"),
    ]
    return rows


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val:.0f},{derived}")
