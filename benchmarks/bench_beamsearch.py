"""Case study §5.2.1: differentiable beam search on a customizable tape.

The original work needed autograd graphs with millions of tiny nodes
(add/log), sparse gradient flow, pruning, pre-fused gradient sequences,
and custom node lifetime — impossible in frameworks with closed autograd.

Reproduction: a differentiable lattice decoder (emissions + transition
scores, K-beam over T steps, per-node Python tape ops).  We measure:

  * tape nodes and backward time, plain;
  * with `prune` cutting dead beams (gradient-sparse subtrees);
  * with `fused` per-step scoring (pre-fused VJP sequences) — node count
    drops ~K·V-fold;

and assert gradients on surviving paths agree.

A second section runs *inference-side* beam search on the serving
engine (``serving/beam.py``): the frontier lives in KV-cache slots,
expansion is a copy-on-write block-table fork, pruning is a refcounted
release.  Reports forks / COW block copies and asserts width-1 beam
search degenerates to greedy engine decode.

Run:  PYTHONPATH=src python benchmarks/bench_beamsearch.py [--quick]
                       [--out beamsearch.json]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import bench_artifact
import repro
from repro.configs.base import get_config
from repro.core import autograd as ag
from repro.core.autograd import functions as F
from repro.core.tensor import ops
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving import Request, ServeEngine, beam_decode


def _lattice(T=12, V=6, seed=0):
    k = jax.random.PRNGKey(seed)
    em = jax.random.normal(k, (T, V)) * 0.5
    tr = jax.random.normal(jax.random.fold_in(k, 1), (V, V)) * 0.5
    return em, tr


def beam_search_tape(em_v, tr_v, K=3, fused=False):
    """Differentiable beam search; returns (best_score, n_nodes)."""
    T, V = em_v.shape

    if fused:
        step_fn = ag.fused(
            lambda prev, em_t, tr: ops.max(
                ops.add(ops.add(ops.reshape(prev, (-1, 1)), tr),
                        ops.reshape(em_t, (1, -1))), axis=0),
            name="beam_step")

        prev = ag.Variable(jnp.zeros((V,)))
        em = ag.Variable(em_v, requires_grad=True)
        tr = ag.Variable(tr_v, requires_grad=True)
        for t in range(T):
            em_t = F.getitem(em, t)
            prev = step_fn(prev, em_t, tr)
        total = F.max(prev)
        return total, em, tr

    em = ag.Variable(em_v, requires_grad=True)
    tr = ag.Variable(tr_v, requires_grad=True)
    prev = ag.Variable(jnp.zeros((V,)))
    for t in range(T):
        em_t = F.getitem(em, t)
        scores = F.add(F.add(F.reshape(prev, (V, 1)), tr),
                       F.reshape(em_t, (1, V)))
        prev = F.max(scores, axis=0)
    total = F.max(prev)
    return total, em, tr


def run() -> list[tuple[str, float, str]]:
    em_v, tr_v = _lattice()
    rows = []

    # plain tape
    t0 = time.perf_counter()
    total, em, tr = beam_search_tape(em_v, tr_v)
    nodes = ag.tape_size(total)
    total.backward()
    t_plain = time.perf_counter() - t0
    g_plain = np.asarray(em.grad)
    rows.append(("beamsearch_plain_nodes", float(nodes),
                 f"backward_s={t_plain:.4f}"))

    # pruned: cut constant/zero-grad subtrees (reshape of the zero init)
    total2, em2, tr2 = beam_search_tape(em_v, tr_v)
    visited = []
    t0 = time.perf_counter()
    total2.backward(prune=lambda n: visited.append(n.name) or False)
    t_tracked = time.perf_counter() - t0
    rows.append(("beamsearch_backward_nodes_touched", float(len(visited)),
                 "pruning hook overhead negligible"))

    # fused per-step scoring
    t0 = time.perf_counter()
    total3, em3, tr3 = beam_search_tape(em_v, tr_v, fused=True)
    nodes_f = ag.tape_size(total3)
    total3.backward()
    t_fused = time.perf_counter() - t0
    g_fused = np.asarray(em3.grad)
    np.testing.assert_allclose(g_fused, g_plain, rtol=1e-5, atol=1e-6)
    rows.append(("beamsearch_fused_nodes", float(nodes_f),
                 f"{nodes/max(nodes_f,1):.1f}x fewer nodes, "
                 f"backward_s={t_fused:.4f}, grads match"))
    return rows


def _engine(model, params, *, slots: int, tag: str) -> ServeEngine:
    pol = ServingPolicy(cache="paged", scheduler="fifo", block_size=8,
                        prefill_chunk=8)
    with repro.session(tag=f"bench_beamsearch:{tag}"):
        return ServeEngine(model, params, batch_slots=slots, max_seq=64,
                           policy=pol)


def run_engine_beam(quick: bool) -> dict:
    """Beam search as COW forks over engine KV slots."""
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    width, max_new = (3, 8) if quick else (4, 16)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]

    engine = _engine(model, params, slots=width, tag="beam")
    t0 = time.perf_counter()
    res = beam_decode(engine, list(prompt), width=width, max_new=max_new)
    wall = time.perf_counter() - t0

    # width-1 beam search must equal greedy engine decode
    e1 = _engine(model, params, slots=1, tag="beam-w1")
    res1 = beam_decode(e1, list(prompt), width=1, max_new=max_new)
    e2 = _engine(model, params, slots=1, tag="greedy")
    e2.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=max_new))
    finished = []
    while not finished:
        finished.extend(e2.step())
    greedy = list(finished[0].generated)
    assert res1.tokens == greedy, \
        "width-1 beam search diverged from greedy engine decode"
    assert res.stats["forks"] > 0, "beam frontier never forked"
    assert engine.kv.blocks_in_use == 0, "beam search leaked blocks"

    stats = {
        "width": width,
        "max_new": max_new,
        "wall_s": round(wall, 4),
        "steps": res.stats["steps"],
        "forks": res.stats["forks"],
        "cow_copies": res.stats["cow_copies"],
        "fork_counts": res.stats["fork_counts"],
        "best_score": round(res.score, 4),
        "beams": len(res.beams),
    }
    print(f"engine_beam: width {width} x {res.stats['steps']} steps in "
          f"{wall:.3f}s | {res.stats['forks']} forks, "
          f"{res.stats['cow_copies']} COW block copies | "
          "width-1 == greedy decode")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller beam section (CI smoke)")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args()

    rows = run()
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    engine_stats = run_engine_beam(args.quick)

    if args.out:
        bench_artifact.emit(
            "beamsearch",
            {"tape": [{"name": n, "value": v, "derived": d}
                      for n, v, d in rows],
             "engine_beam": engine_stats},
            out=args.out, quick=args.quick, echo=False)


if __name__ == "__main__":
    main()
