"""Case study §5.2.1: differentiable beam search on a customizable tape.

The original work needed autograd graphs with millions of tiny nodes
(add/log), sparse gradient flow, pruning, pre-fused gradient sequences,
and custom node lifetime — impossible in frameworks with closed autograd.

Reproduction: a differentiable lattice decoder (emissions + transition
scores, K-beam over T steps, per-node Python tape ops).  We measure:

  * tape nodes and backward time, plain;
  * with `prune` cutting dead beams (gradient-sparse subtrees);
  * with `fused` per-step scoring (pre-fused VJP sequences) — node count
    drops ~K·V-fold;

and assert gradients on surviving paths agree.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autograd as ag
from repro.core.autograd import functions as F
from repro.core.tensor import ops


def _lattice(T=12, V=6, seed=0):
    k = jax.random.PRNGKey(seed)
    em = jax.random.normal(k, (T, V)) * 0.5
    tr = jax.random.normal(jax.random.fold_in(k, 1), (V, V)) * 0.5
    return em, tr


def beam_search_tape(em_v, tr_v, K=3, fused=False):
    """Differentiable beam search; returns (best_score, n_nodes)."""
    T, V = em_v.shape

    if fused:
        step_fn = ag.fused(
            lambda prev, em_t, tr: ops.max(
                ops.add(ops.add(ops.reshape(prev, (-1, 1)), tr),
                        ops.reshape(em_t, (1, -1))), axis=0),
            name="beam_step")

        prev = ag.Variable(jnp.zeros((V,)))
        em = ag.Variable(em_v, requires_grad=True)
        tr = ag.Variable(tr_v, requires_grad=True)
        for t in range(T):
            em_t = F.getitem(em, t)
            prev = step_fn(prev, em_t, tr)
        total = F.max(prev)
        return total, em, tr

    em = ag.Variable(em_v, requires_grad=True)
    tr = ag.Variable(tr_v, requires_grad=True)
    prev = ag.Variable(jnp.zeros((V,)))
    for t in range(T):
        em_t = F.getitem(em, t)
        scores = F.add(F.add(F.reshape(prev, (V, 1)), tr),
                       F.reshape(em_t, (1, V)))
        prev = F.max(scores, axis=0)
    total = F.max(prev)
    return total, em, tr


def run() -> list[tuple[str, float, str]]:
    em_v, tr_v = _lattice()
    rows = []

    # plain tape
    t0 = time.perf_counter()
    total, em, tr = beam_search_tape(em_v, tr_v)
    nodes = ag.tape_size(total)
    total.backward()
    t_plain = time.perf_counter() - t0
    g_plain = np.asarray(em.grad)
    rows.append(("beamsearch_plain_nodes", float(nodes),
                 f"backward_s={t_plain:.4f}"))

    # pruned: cut constant/zero-grad subtrees (reshape of the zero init)
    total2, em2, tr2 = beam_search_tape(em_v, tr_v)
    visited = []
    t0 = time.perf_counter()
    total2.backward(prune=lambda n: visited.append(n.name) or False)
    t_tracked = time.perf_counter() - t0
    rows.append(("beamsearch_backward_nodes_touched", float(len(visited)),
                 "pruning hook overhead negligible"))

    # fused per-step scoring
    t0 = time.perf_counter()
    total3, em3, tr3 = beam_search_tape(em_v, tr_v, fused=True)
    nodes_f = ag.tape_size(total3)
    total3.backward()
    t_fused = time.perf_counter() - t0
    g_fused = np.asarray(em3.grad)
    np.testing.assert_allclose(g_fused, g_plain, rtol=1e-5, atol=1e-6)
    rows.append(("beamsearch_fused_nodes", float(nodes_f),
                 f"{nodes/max(nodes_f,1):.1f}x fewer nodes, "
                 f"backward_s={t_fused:.4f}, grads match"))
    return rows


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val:.1f},{derived}")
