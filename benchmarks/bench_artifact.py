"""Shared JSON artifact emission for ``benchmarks/bench_*.py``.

Every benchmark used to hand-roll its own ``json.dumps`` + ``--out``
handling; :func:`emit` is the single version of that.  On top of the
benchmark's own payload it embeds

* ``session`` — :meth:`repro.runtime.Session.describe` provenance for
  the session the benchmark ran under (policies, backend, obs state),
* ``metrics`` — the session tracer's metrics snapshot (counters /
  gauges / histogram summaries), when observability is enabled,

so a CI artifact is self-describing: the numbers and the exact
configuration that produced them travel together.
"""

from __future__ import annotations

import json
from typing import Any


def emit(bench: str, payload: dict[str, Any], *, out: str | None = None,
         quick: bool = False, session: Any = None,
         echo: bool = True) -> dict[str, Any]:
    """Assemble, print, and optionally write one benchmark artifact.

    ``session`` defaults to the current ambient session; pass the
    session the benchmark actually ran under when it differs (e.g. the
    bench opened its own ``repro.session(...)`` block).  Returns the
    assembled dict (handy for in-process assertions).
    """
    import repro
    from repro import obs

    sess = session if session is not None else repro.current_session()
    obj: dict[str, Any] = {"bench": bench, "quick": quick, **payload,
                           "session": sess.describe()}
    tracer = obs.get_tracer(sess)
    if tracer is not None:
        obj["metrics"] = tracer.metrics.snapshot()
    blob = json.dumps(obj, indent=2, default=str)
    if echo or not out:
        print(blob)
    if out:
        with open(out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {out}")
    return obj
