"""Deferred-execution fusion win (the ArrayFire-JIT reproduction, Fig. 2),
now measured through the ``repro.compiler`` pipeline.

Elementwise chains, three ways:
 * eager       — one XLA dispatch per op;
 * lazy legacy — the pre-compiler lazy path (empty pipeline): the graph
   is captured but evaluated node-at-a-time, one dispatch per node;
 * compiled    — the full pipeline (cse / fold / dce / fuse) with Pallas
   cluster lowering: CSE+fusion collapse the chain into generated cluster
   kernels, and the program cache reuses them across materializations.

Reported per scenario: wall time, dispatched-call counts, generated-kernel
counts, and per-pass node reductions (the PassManager's own stats).

Run:  PYTHONPATH=src python benchmarks/bench_fusion.py [--quick]
                       [--out fusion.json] [--n-ops 16] [--iters 20]

The JSON output is uploaded as a CI artifact (next to bench_serving's)
to start a compiler-perf trajectory across PRs.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

import repro
from repro.core.tensor import ops
from repro.runtime import CompilerPolicy


def _chain(x, n):
    for _ in range(n):
        x = ops.mul(ops.add(x, x), ops.full_like(x, 0.5))
        x = ops.tanh(x)
    return x


def _time(fn, iters):
    out = fn()                       # warm up (trace/compile/jit)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def bench(n_ops: int = 16, iters: int = 20, side: int = 256) -> dict:
    x = jnp.ones((side, side))

    t_eager, ref = _time(lambda: _chain(x, n_ops), iters)

    def lazy_run(policy):
        lb_holder = {}

        def run():
            with repro.session(backend="lazy", compiler=policy,
                               tag="bench_fusion") as sess:
                lb = sess.backend_instance()
                lb_holder["lb"] = lb
                return ops.materialize(_chain(x, n_ops))

        t, out = _time(run, iters)
        lb = lb_holder["lb"]
        return t, out, lb.last_compile_report, lb

    t_legacy, out_legacy, rep_legacy, _ = lazy_run(CompilerPolicy.legacy())
    t_comp, out_comp, rep_comp, lb = lazy_run(CompilerPolicy())

    import numpy as np
    exact = bool((np.asarray(out_comp) == np.asarray(ref)).all())

    passes = {p["pass"]: {"nodes_before": p["nodes"][0],
                          "nodes_after": p["nodes"][1],
                          "removed": p["nodes"][0] - p["nodes"][1],
                          **{k: v for k, v in p.items()
                             if k not in ("pass", "nodes", "edges")}}
              for p in rep_comp["passes"]}
    return {
        "n_ops": 3 * n_ops,
        "shape": [side, side],
        "eager_s": t_eager,
        "lazy_legacy_s": t_legacy,
        "compiled_s": t_comp,
        "speedup_vs_eager": t_eager / t_comp,
        "speedup_vs_legacy": t_legacy / t_comp,
        "legacy_dispatches": rep_legacy["dispatches"],
        "compiled_dispatches": rep_comp["dispatches"],
        "pallas_kernels": rep_comp["pallas_kernels"],
        "program_cache_hits": lb.program_cache_hits,
        "numerics_exact_vs_eager": exact,
        "passes": passes,
    }


def run() -> list[tuple[str, float, str]]:
    """CSV rows for benchmarks/run.py."""
    r = bench()
    pass_txt = " ".join(
        f"{name}:{p['nodes_before']}->{p['nodes_after']}"
        for name, p in r["passes"].items())
    return [
        ("fusion_eager_chain_s", r["eager_s"],
         f"{r['n_ops']} dispatches per chain"),
        ("fusion_lazy_legacy_chain_s", r["lazy_legacy_s"],
         f"{r['legacy_dispatches']} dispatches (node-at-a-time)"),
        ("fusion_compiled_chain_s", r["compiled_s"],
         f"{r['compiled_dispatches']} dispatch(es), "
         f"{r['pallas_kernels']} generated kernel(s); "
         f"passes[{pass_txt}]; "
         f"exact={r['numerics_exact_vs_eager']}; "
         f"speedup vs eager={r['speedup_vs_eager']:.2f}x "
         f"legacy={r['speedup_vs_legacy']:.2f}x"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small chain / few iters; emit JSON for CI")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--n-ops", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()

    n_ops = args.n_ops or 16
    iters = args.iters or (5 if args.quick else 20)
    side = 128 if args.quick else 256
    result = bench(n_ops=n_ops, iters=iters, side=side)
    payload = {"bench": "fusion", "quick": args.quick, **result}
    blob = json.dumps(payload, indent=2, default=str)
    print(blob)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)
    assert result["numerics_exact_vs_eager"], "compiled != eager"
    assert result["compiled_dispatches"] <= 2, \
        "pipeline failed to collapse the chain"


if __name__ == "__main__":
    main()
