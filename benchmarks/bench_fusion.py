"""Deferred-execution fusion win (the ArrayFire-JIT reproduction, Fig. 2).

Elementwise chains: eager mode dispatches one XLA call per op; the lazy
backend builds the graph and evaluates the whole pending subgraph in one
materialization.  We report dispatch counts and wall time per chain.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro
from repro.core.tensor import ops


def _chain(x, n):
    for i in range(n):
        x = ops.mul(ops.add(x, x), ops.full_like(x, 0.5))
        x = ops.tanh(x)
    return x


def run() -> list[tuple[str, float, str]]:
    rows = []
    x = jnp.ones((256, 256))
    n = 16

    # eager
    out = _chain(x, n)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(20):
        out = _chain(x, n)
    jax.block_until_ready(out)
    t_eager = (time.perf_counter() - t0) / 20

    # lazy: one materialization per chain, via a session-scoped swap
    with repro.session(backend="lazy", tag="bench_fusion") as sess:
        lb = sess.backend_instance()
        out = ops.materialize(_chain(x, n))
        n0, m0 = lb.nodes_built, lb.materialize_calls
        t0 = time.perf_counter()
        for _ in range(20):
            out = ops.materialize(_chain(x, n))
        jax.block_until_ready(out)
        t_lazy = (time.perf_counter() - t0) / 20
        built = lb.nodes_built - n0
        mats = lb.materialize_calls - m0

    rows.append(("fusion_eager_chain_s", t_eager,
                 f"{3*n} dispatches per chain"))
    rows.append(("fusion_lazy_chain_s", t_lazy,
                 f"{built//20} nodes -> {mats//20} materialization(s); "
                 f"speedup={t_eager/t_lazy:.2f}x"))
    return rows


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val*1e6:.1f},{derived}")
