"""Deferred-execution fusion win (the ArrayFire-JIT reproduction, Fig. 2),
now measured through the ``repro.compiler`` pipeline.

Three sections:

 * chain     — elementwise chains: eager (one XLA dispatch per op) vs the
   legacy lazy path (node-at-a-time) vs the full pipeline (CSE + fusion
   collapse the chain into generated cluster kernels);
 * attention — plain-ops ``softmax(QK^T * scale)V`` variants through the
   attention matcher: the generated template kernel vs the hand-written
   ``kernels.flash_attention`` vs the unfused per-op path (kernel counts
   + steady-state wall time; the template must stay within 1.25x of the
   hand-written kernel);
 * epilogue  — ``gelu(x @ w + b)``: the fused matmul-epilogue kernel (one
   dispatch) vs the unfused per-op path (>= 3 dispatches).

Reported per scenario: wall time, dispatched-call counts, generated-kernel
counts, and per-pass node reductions (the PassManager's own stats).

Run:  PYTHONPATH=src python benchmarks/bench_fusion.py [--quick]
                       [--out fusion.json] [--n-ops 16] [--iters 20]

The JSON output is uploaded as a CI artifact (next to bench_serving's)
to start a compiler-perf trajectory across PRs.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

import bench_artifact
import repro
from repro.core.tensor import ops
from repro.runtime import CompilerPolicy


def _chain(x, n):
    for _ in range(n):
        x = ops.mul(ops.add(x, x), ops.full_like(x, 0.5))
        x = ops.tanh(x)
    return x


def _time(fn, iters, repeat: int = 1):
    """Mean seconds per call, min over ``repeat`` measurement blocks."""
    out = fn()                       # warm up (trace/compile/jit)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best, out


def bench(n_ops: int = 16, iters: int = 20, side: int = 256) -> dict:
    x = jnp.ones((side, side))

    t_eager, ref = _time(lambda: _chain(x, n_ops), iters)

    def lazy_run(policy):
        lb_holder = {}

        def run():
            with repro.session(backend="lazy", compiler=policy,
                               tag="bench_fusion") as sess:
                lb = sess.backend_instance()
                lb_holder["lb"] = lb
                return ops.materialize(_chain(x, n_ops))

        t, out = _time(run, iters)
        lb = lb_holder["lb"]
        return t, out, lb.last_compile_report, lb

    t_legacy, out_legacy, rep_legacy, _ = lazy_run(CompilerPolicy.legacy())
    t_comp, out_comp, rep_comp, lb = lazy_run(CompilerPolicy())

    import numpy as np
    exact = bool((np.asarray(out_comp) == np.asarray(ref)).all())

    passes = {p["pass"]: {"nodes_before": p["nodes"][0],
                          "nodes_after": p["nodes"][1],
                          "removed": p["nodes"][0] - p["nodes"][1],
                          **{k: v for k, v in p.items()
                             if k not in ("pass", "nodes", "edges")}}
              for p in rep_comp["passes"]}
    return {
        "n_ops": 3 * n_ops,
        "shape": [side, side],
        "eager_s": t_eager,
        "lazy_legacy_s": t_legacy,
        "compiled_s": t_comp,
        "speedup_vs_eager": t_eager / t_comp,
        "speedup_vs_legacy": t_legacy / t_comp,
        "legacy_dispatches": rep_legacy["dispatches"],
        "compiled_dispatches": rep_comp["dispatches"],
        "pallas_kernels": rep_comp["pallas_kernels"],
        "program_cache_hits": lb.program_cache_hits,
        "numerics_exact_vs_eager": exact,
        "passes": passes,
    }


def _attn_program(q, k, v, scale):
    s0 = ops.matmul(q, ops.transpose(k, (0, 2, 1)))
    s = ops.mul(s0, ops.full_like(s0, scale))
    m = ops.max(s, axis=-1, keepdims=True)
    e = ops.exp(ops.sub(s, ops.stop_gradient(m)))
    p = ops.div(e, ops.sum(e, axis=-1, keepdims=True))
    return ops.matmul(p, v)


def bench_attention(iters: int = 10, b: int = 1, h: int = 4, s: int = 256,
                    d: int = 64) -> dict:
    """Generated attention template vs hand-written flash_attention vs
    the unfused per-op path, at [B*H, S, D]."""
    from repro.kernels.flash_attention import flash_attention

    scale = 1.0 / (d ** 0.5)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    bh = b * h
    q = jax.random.normal(keys[0], (bh, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (bh, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (bh, s, d), jnp.float32)
    interpret = jax.default_backend() != "tpu"

    # generated: plain ops through the attention matcher -> one template
    compiled = repro.compile(lambda a, b_, c: _attn_program(a, b_, c, scale))
    t_template, out_t = _time(lambda: compiled(q, k, v), iters, repeat=3)
    exe = compiled.last_executable
    kinds = [c["kind"] for c in exe.describe()["clusters"]]

    # hand-written flash kernel on the same problem ([B, H, S, D] layout)
    q4, k4, v4 = (t.reshape(b, h, s, d) for t in (q, k, v))
    flash = jax.jit(functools.partial(
        flash_attention, causal=False, scale=scale, interpret=interpret))
    t_flash, out_f = _time(lambda: flash(q4, k4, v4), iters, repeat=3)

    # unfused: the legacy per-op path over the same program
    legacy = repro.compile(policy=CompilerPolicy.legacy())(
        lambda a, b_, c: _attn_program(a, b_, c, scale))
    t_unfused, _ = _time(lambda: legacy(q, k, v), iters, repeat=3)

    import numpy as np
    err = float(np.max(np.abs(np.asarray(out_t)
                              - np.asarray(out_f).reshape(bh, s, d))))
    return {
        "shape_bhsd": [b, h, s, d],
        "template_s": t_template,
        "flash_attention_s": t_flash,
        "unfused_s": t_unfused,
        "template_vs_flash_ratio": t_template / t_flash,
        "speedup_vs_unfused": t_unfused / t_template,
        "generated_dispatches": exe.n_dispatches,
        "generated_kernels": exe.n_kernels,
        "cluster_kinds": kinds,
        "unfused_dispatches": legacy.last_executable.n_dispatches,
        "template_vs_flash_max_abs_err": err,
    }


def bench_epilogue(iters: int = 10, m: int = 256, k: int = 256,
                   n: int = 256) -> dict:
    """``gelu(x @ w + b)``: fused matmul-epilogue kernel vs per-op."""
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32) * (k ** -0.5)
    bias = jax.random.normal(keys[2], (n,), jnp.float32)

    def f(x, w, bias):
        return ops.gelu(ops.add(ops.matmul(x, w), bias))

    fused = repro.compile(f)
    t_fused, out_fused = _time(lambda: fused(x, w, bias), iters, repeat=3)
    exe = fused.last_executable
    legacy = repro.compile(policy=CompilerPolicy.legacy())(f)
    t_unfused, out_ref = _time(lambda: legacy(x, w, bias), iters, repeat=3)

    import numpy as np
    err = float(np.max(np.abs(np.asarray(out_fused) - np.asarray(out_ref))))
    return {
        "shape_mkn": [m, k, n],
        "fused_s": t_fused,
        "unfused_s": t_unfused,
        "speedup_vs_unfused": t_unfused / t_fused,
        "fused_dispatches": exe.n_dispatches,
        "fused_kernels": exe.n_kernels,
        "cluster_kinds": [c["kind"] for c in exe.describe()["clusters"]],
        "unfused_dispatches": legacy.last_executable.n_dispatches,
        "max_abs_err_vs_unfused": err,
    }


def run() -> list[tuple[str, float, str]]:
    """CSV rows for benchmarks/run.py."""
    r = bench()
    a = bench_attention()
    e = bench_epilogue()
    pass_txt = " ".join(
        f"{name}:{p['nodes_before']}->{p['nodes_after']}"
        for name, p in r["passes"].items())
    return [
        ("fusion_eager_chain_s", r["eager_s"],
         f"{r['n_ops']} dispatches per chain"),
        ("fusion_lazy_legacy_chain_s", r["lazy_legacy_s"],
         f"{r['legacy_dispatches']} dispatches (node-at-a-time)"),
        ("fusion_compiled_chain_s", r["compiled_s"],
         f"{r['compiled_dispatches']} dispatch(es), "
         f"{r['pallas_kernels']} generated kernel(s); "
         f"passes[{pass_txt}]; "
         f"exact={r['numerics_exact_vs_eager']}; "
         f"speedup vs eager={r['speedup_vs_eager']:.2f}x "
         f"legacy={r['speedup_vs_legacy']:.2f}x"),
        ("fusion_attention_template_s", a["template_s"],
         f"{a['generated_kernels']} generated kernel(s) vs hand-written "
         f"{a['template_vs_flash_ratio']:.2f}x, unfused "
         f"{a['unfused_dispatches']} dispatches"),
        ("fusion_epilogue_fused_s", e["fused_s"],
         f"{e['unfused_dispatches']} dispatches -> "
         f"{e['fused_dispatches']}; speedup "
         f"{e['speedup_vs_unfused']:.2f}x"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small chain / few iters; emit JSON for CI")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--n-ops", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()

    n_ops = args.n_ops or 16
    iters = args.iters or (5 if args.quick else 20)
    side = 128 if args.quick else 256
    result = bench(n_ops=n_ops, iters=iters, side=side)
    attn = bench_attention(iters=iters)
    epi = bench_epilogue(iters=iters)
    bench_artifact.emit("fusion",
                        {**result, "attention": attn, "epilogue": epi},
                        out=args.out, quick=args.quick)
    assert result["numerics_exact_vs_eager"], "compiled != eager"
    assert result["compiled_dispatches"] <= 2, \
        "pipeline failed to collapse the chain"
    assert attn["generated_dispatches"] == 1 \
        and attn["generated_kernels"] == 1 \
        and attn["cluster_kinds"] == ["attention"], \
        "attention matcher failed to produce one generated kernel"
    assert attn["template_vs_flash_ratio"] <= 1.25, \
        (f"generated template {attn['template_vs_flash_ratio']:.2f}x "
         "slower than hand-written flash_attention (budget 1.25x)")
    assert epi["unfused_dispatches"] >= 3 and epi["fused_dispatches"] == 1, \
        "epilogue fusion failed to collapse matmul+bias+gelu"


if __name__ == "__main__":
    main()
