"""Serving benchmark: staggered mixed-length arrivals through the
ServeEngine, dense vs paged KV cache, per scheduler.

Measures, per scenario:
 * tokens/s (decode throughput over the whole trace),
 * time-to-first-token (mean/p-max over requests, submit -> first token),
 * jitted calls: decode steps and prefill calls per admission — the
   chunked-prefill claim is visible here: the legacy path pays
   O(prompt_len) one-token decodes per admission, the chunked path
   O(prompt_len / chunk),
 * preemptions and block-pool stats (paged scenarios),
 * full Session/ServingPolicy provenance via ``engine.describe()``.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py [--quick]
                       [--out serving.json] [--arch codeqwen1.5-7b]

The JSON output is uploaded as a CI artifact (see .github/workflows)
to start a serving-perf trajectory across PRs.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving import Request, ServeEngine


def make_workload(n_requests: int, max_new: int, seed: int = 0):
    """Mixed-length prompts with staggered arrival steps."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        length = int(rng.integers(3, 28))
        prompt = [int(t) for t in rng.integers(1, 60, size=length)]
        arrival = int(rng.integers(0, 3)) + 2 * uid   # staggered stream
        reqs.append((arrival, Request(uid=uid, prompt=prompt,
                                      max_new_tokens=max_new,
                                      priority=int(rng.integers(0, 3)))))
    return sorted(reqs, key=lambda ar: ar[0])


def drive(engine: ServeEngine, workload, max_steps: int = 5000):
    """Submit requests at their arrival step; run to completion."""
    pending = list(workload)
    done = []
    t0 = time.time()
    for step in range(max_steps):
        while pending and pending[0][0] <= step:
            engine.submit(pending.pop(0)[1])
        done.extend(engine.step())
        if not pending and not engine.active and not engine.waiting:
            break
    wall = time.time() - t0
    return done, wall


def run_scenario(name: str, model, params, policy: ServingPolicy, *,
                 slots: int, max_seq: int, workload) -> dict:
    with repro.session(tag=f"bench_serving:{name}"):
        engine = ServeEngine(model, params, batch_slots=slots,
                             max_seq=max_seq, policy=policy)
    # copy the workload so every scenario decodes the same requests
    fresh = [(a, Request(uid=r.uid, prompt=list(r.prompt),
                         max_new_tokens=r.max_new_tokens,
                         priority=r.priority))
             for a, r in workload]
    done, wall = drive(engine, fresh)
    toks = sum(len(r.generated) for r in done)
    ttfts = [r.first_token_time - r.submit_time for r in done
             if r.first_token_time is not None]
    admissions = len(done) + engine.preemptions
    prompt_tokens = sum(len(r.prompt) for _, r in workload)
    out = {
        "scenario": name,
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1) if wall > 0 else None,
        "ttft_mean_s": round(float(np.mean(ttfts)), 4) if ttfts else None,
        "ttft_max_s": round(float(np.max(ttfts)), 4) if ttfts else None,
        "decode_calls": engine.decode_calls,
        "prefill_calls": engine.prefill_calls,
        "prefill_calls_per_admission":
            round(engine.prefill_calls / max(1, admissions), 2),
        "prompt_tokens": prompt_tokens,
        "preemptions": engine.preemptions,
        "provenance": engine.describe(),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small model + short trace (CI smoke)")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    if args.quick:
        overrides = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=64)
    cfg = get_config(args.arch, reduced=True, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req = args.requests or (6 if args.quick else 12)
    max_new = args.max_new or (6 if args.quick else 16)
    workload = make_workload(n_req, max_new)
    chunk = 8

    # ~half the blocks a full complement of slots could want: the
    # priority scenario exercises evict + requeue under real pressure
    tight_pool = 2 * args.slots + 1
    scenarios = [
        ("dense-fifo-legacy-prefill",
         ServingPolicy(cache="dense", scheduler="fifo", prefill_chunk=0)),
        ("dense-fifo-chunked",
         ServingPolicy(cache="dense", scheduler="fifo",
                       prefill_chunk=chunk)),
        ("paged-fifo",
         ServingPolicy(cache="paged", scheduler="fifo", block_size=16,
                       prefill_chunk=chunk)),
        ("paged-sjf",
         ServingPolicy(cache="paged", scheduler="sjf", block_size=16,
                       prefill_chunk=chunk)),
        ("paged-priority-tight-pool",
         ServingPolicy(cache="paged", scheduler="priority", block_size=16,
                       num_blocks=tight_pool, prefill_chunk=chunk)),
    ]

    results = []
    for name, policy in scenarios:
        res = run_scenario(name, model, params, policy, slots=args.slots,
                           max_seq=args.max_seq, workload=workload)
        results.append(res)
        print(f"[{name:>28s}] {res['tokens']:4d} tok in "
              f"{res['wall_s']:7.2f}s = {res['tok_per_s']:8.1f} tok/s | "
              f"ttft {res['ttft_mean_s']}s | "
              f"prefill calls/admission {res['prefill_calls_per_admission']}"
              f" | preempt {res['preemptions']}")

    legacy = results[0]
    chunked = results[1]
    print(f"\nchunked prefill: {chunked['prefill_calls']} jitted prefill "
          f"calls vs {legacy['prefill_calls']} legacy one-token calls "
          f"({legacy['prefill_calls'] / max(1, chunked['prefill_calls']):.1f}"
          f"x fewer compiled-call dispatches per admission stream)")

    payload = {"arch": cfg.name, "quick": args.quick, "slots": args.slots,
               "max_seq": args.max_seq, "prefill_chunk": chunk,
               "results": results}
    blob = json.dumps(payload, indent=2, default=str)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)
        print(f"\nwrote {args.out}")
    else:
        print(blob)


if __name__ == "__main__":
    main()
