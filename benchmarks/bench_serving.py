"""Serving benchmark: staggered mixed-length arrivals through the
ServeEngine, dense vs paged KV cache, per scheduler — plus a
prefix-sharing section under a Poisson arrival trace.

Measures, per scenario:
 * tokens/s (decode throughput over the whole trace),
 * time-to-first-token (mean/p-max over requests, submit -> first token),
 * jitted calls: decode steps and prefill calls per admission — the
   chunked-prefill claim is visible here: the legacy path pays
   O(prompt_len) one-token decodes per admission, the chunked path
   O(prompt_len / chunk),
 * preemptions and block-pool stats (paged scenarios),
 * full Session/ServingPolicy provenance via ``engine.describe()``.

The sharing section drives N requests with a common 32-token system
prompt (Poisson arrivals by default, ``--trace staggered`` for the
legacy stream) through sharing-off vs sharing-on paged engines and a
2-replica prefix-affinity router, reports prefill-tokens-saved and
follower TTFT, and *asserts* the decoded tokens are identical.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py [--quick]
                       [--out serving.json] [--arch codeqwen1.5-7b]
                       [--trace poisson|staggered]

The JSON output is uploaded as a CI artifact (see .github/workflows)
to start a serving-perf trajectory across PRs.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

import bench_artifact
import repro
from repro import obs
from repro.configs.base import get_config
from repro.core.tensor import ops
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving import FixedProposer, Request, Router, ServeEngine


def make_workload(n_requests: int, max_new: int, seed: int = 0):
    """Mixed-length prompts with staggered arrival steps."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        length = int(rng.integers(3, 28))
        prompt = [int(t) for t in rng.integers(1, 60, size=length)]
        arrival = int(rng.integers(0, 3)) + 2 * uid   # staggered stream
        reqs.append((arrival, Request(uid=uid, prompt=prompt,
                                      max_new_tokens=max_new,
                                      priority=int(rng.integers(0, 3)))))
    return sorted(reqs, key=lambda ar: ar[0])


def make_shared_workload(n_requests: int, max_new: int, *,
                         shared_len: int = 32, trace: str = "poisson",
                         rate: float = 0.5, seed: int = 7):
    """N requests sharing a ``shared_len``-token system prompt.

    Every prompt is the same system prefix plus a short unique tail, so
    a prefix-sharing cache prefills the system prompt once and maps it
    into every follower.  Arrivals are a Poisson process (exponential
    inter-arrival gaps, ``rate`` requests per engine step) by default,
    or the legacy staggered stream with ``trace="staggered"``.
    """
    rng = np.random.default_rng(seed)
    system = [int(t) for t in rng.integers(1, 60, size=shared_len)]
    if trace == "poisson":
        gaps = rng.exponential(scale=1.0 / rate, size=n_requests)
        arrivals = np.floor(np.cumsum(gaps)).astype(int)
    elif trace == "staggered":
        arrivals = np.array([2 * uid for uid in range(n_requests)])
    else:
        raise ValueError(f"unknown trace {trace!r}")
    reqs = []
    for uid in range(n_requests):
        tail = [int(t) for t in rng.integers(1, 60,
                                             size=int(rng.integers(4, 9)))]
        reqs.append((int(arrivals[uid]),
                     Request(uid=uid, prompt=system + tail,
                             max_new_tokens=max_new)))
    return sorted(reqs, key=lambda ar: ar[0])


def drive(engine: ServeEngine, workload, max_steps: int = 5000):
    """Submit requests at their arrival step; run to completion."""
    pending = list(workload)
    done = []
    t0 = obs.now()
    for step in range(max_steps):
        while pending and pending[0][0] <= step:
            engine.submit(pending.pop(0)[1])
        done.extend(engine.step())
        if not pending and not engine.active and not engine.waiting:
            break
    wall = obs.now() - t0
    return done, wall


def _fresh(workload):
    """Copy a workload so every scenario decodes the same requests."""
    return [(a, Request(uid=r.uid, prompt=list(r.prompt),
                        max_new_tokens=r.max_new_tokens,
                        priority=r.priority))
            for a, r in workload]


def run_scenario(name: str, model, params, policy: ServingPolicy, *,
                 slots: int, max_seq: int, workload) -> dict:
    with repro.session(tag=f"bench_serving:{name}"):
        engine = ServeEngine(model, params, batch_slots=slots,
                             max_seq=max_seq, policy=policy)
    done, wall = drive(engine, _fresh(workload))
    toks = sum(len(r.generated) for r in done)
    ttfts = [r.first_token_time - r.submit_time for r in done
             if r.first_token_time is not None]
    admissions = len(done) + engine.preemptions
    prompt_tokens = sum(len(r.prompt) for _, r in workload)
    out = {
        "scenario": name,
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1) if wall > 0 else None,
        "ttft_mean_s": round(float(np.mean(ttfts)), 4) if ttfts else None,
        "ttft_max_s": round(float(np.max(ttfts)), 4) if ttfts else None,
        "decode_calls": engine.decode_calls,
        "prefill_calls": engine.prefill_calls,
        "prefill_calls_per_admission":
            round(engine.prefill_calls / max(1, admissions), 2),
        "prompt_tokens": prompt_tokens,
        "preemptions": engine.preemptions,
        "provenance": engine.describe(),
    }
    return out


def run_sharing_scenario(name: str, model, params, policy: ServingPolicy, *,
                         slots: int, max_seq: int, workload,
                         replicas: int = 1) -> tuple[dict, dict]:
    """Drive the shared-prompt trace; return (stats, tokens-by-uid).

    Tracks TTFT per request so the leader (first arrival, pays the full
    system-prompt prefill) can be separated from the followers (whose
    prefill the sharing cache shortens).  With ``replicas > 1`` the same
    trace goes through a :class:`Router` instead of a single engine.
    """
    with repro.session(tag=f"bench_serving:{name}"):
        engines = [ServeEngine(model, params, batch_slots=slots,
                               max_seq=max_seq, policy=policy)
                   for _ in range(replicas)]
    fresh = _fresh(workload)
    if replicas == 1:
        done, wall = drive(engines[0], fresh)
    else:
        router = Router(engines)
        pending = list(fresh)
        done = []
        t0 = obs.now()
        for step in range(5000):
            while pending and pending[0][0] <= step:
                router.submit(pending.pop(0)[1])
            done.extend(router.step())
            if not pending and not any(e.active or e.waiting
                                       for e in engines):
                break
        wall = obs.now() - t0
    toks = sum(len(r.generated) for r in done)
    leader_uid = fresh[0][1].uid
    ttft = {r.uid: r.first_token_time - r.submit_time for r in done
            if r.first_token_time is not None}
    follower = [t for uid, t in ttft.items() if uid != leader_uid]
    saved = sum(e.prefill_tokens_saved for e in engines)
    stats = {
        "scenario": name,
        "requests": len(done),
        "replicas": replicas,
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1) if wall > 0 else None,
        "ttft_mean_s": (round(float(np.mean(list(ttft.values()))), 4)
                        if ttft else None),
        "ttft_follower_mean_s": (round(float(np.mean(follower)), 4)
                                 if follower else None),
        "prefill_calls": sum(e.prefill_calls for e in engines),
        "prefill_tokens_saved": saved,
        "shared_admissions": sum(e.shared_admissions for e in engines),
        "preemptions": sum(e.preemptions for e in engines),
        "provenance": engines[0].describe(),
    }
    return stats, {r.uid: list(r.generated) for r in done}


def run_sharing_section(model, params, *, slots: int, max_seq: int,
                        n_req: int, max_new: int, trace: str,
                        chunk: int) -> dict:
    """Sharing-off vs sharing-on vs routed, same shared-prompt trace.

    Asserts the decoded tokens are identical across all three paths and
    that sharing actually saved prefill work — the bench doubles as the
    acceptance check for the prefix-sharing serving stack.
    """
    workload = make_shared_workload(n_req, max_new, trace=trace)
    base = dict(cache="paged", scheduler="fifo", block_size=8,
                prefill_chunk=chunk)
    runs = [
        ("shared-prompt-sharing-off", ServingPolicy(**base), 1),
        ("shared-prompt-sharing-on",
         ServingPolicy(**base, prefix=True), 1),
        ("shared-prompt-router-2x",
         ServingPolicy(**base, prefix=True, routing="prefix_affinity"), 2),
    ]
    results, tokens = [], {}
    for name, policy, replicas in runs:
        stats, gen = run_sharing_scenario(
            name, model, params, policy, slots=slots, max_seq=max_seq,
            workload=workload, replicas=replicas)
        results.append(stats)
        tokens[name] = gen
        print(f"[{name:>28s}] {stats['tokens']:4d} tok at "
              f"{stats['tok_per_s']:8.1f} tok/s | "
              f"ttft {stats['ttft_mean_s']}s "
              f"(followers {stats['ttft_follower_mean_s']}s) | "
              f"prefill saved {stats['prefill_tokens_saved']} tok | "
              f"shared admissions {stats['shared_admissions']}")
    off, on, routed = results
    gen_off = tokens["shared-prompt-sharing-off"]
    for other in ("shared-prompt-sharing-on", "shared-prompt-router-2x"):
        assert tokens[other] == gen_off, \
            f"{other} decoded different tokens than sharing-off"
    assert off["prefill_tokens_saved"] == 0
    assert on["prefill_tokens_saved"] > 0, \
        "sharing-on saved no prefill tokens on a shared-prompt trace"
    assert routed["prefill_tokens_saved"] > 0
    print(f"\nprefix sharing: {on['prefill_tokens_saved']} prefill tokens "
          f"saved across {on['shared_admissions']} shared admissions; "
          f"follower ttft {off['ttft_follower_mean_s']}s -> "
          f"{on['ttft_follower_mean_s']}s; decoded tokens identical "
          "across sharing-off / sharing-on / routed")
    return {"trace": trace, "shared_prompt_tokens": 32,
            "requests": n_req, "results": results}


def run_obs_section(model, params, *, slots: int, max_seq: int,
                    n_req: int, max_new: int, chunk: int,
                    trace_path: str) -> dict:
    """Drive one paged scenario with observability on; export the trace.

    The same run exercises all three instrumented layers — the serving
    engine (request lifecycle spans/instants), the paged KV cache's
    memory telemetry bridge (``mem.alloc``/``mem.free``,
    ``kv.grow``), and the graph compiler (a small ``repro.compile``
    function called twice: trace/pass/lower spans on the miss, a
    program-cache-hit counter on the replay).  Asserts:

    * the exported JSON passes the Chrome trace-event schema validator
      (i.e. Perfetto will load it),
    * span/instant names from all three layers are present, and
    * TTFT / inter-token percentiles computed from the trace by
      ``repro.obs.summarize`` match the benchmark's own numbers
      (``Request`` timestamps) within 1%.
    """
    from repro.obs import save_trace, validate_chrome_trace
    from repro.obs.summarize import summarize

    workload = make_workload(n_req, max_new, seed=3)
    policy = ServingPolicy(cache="paged", scheduler="fifo", block_size=8,
                           prefill_chunk=chunk)

    @repro.compile
    def poly(x, y):
        return ops.tanh(ops.add(ops.mul(x, y), x))

    with repro.session(obs=True, tag="bench_serving:obs") as sess:
        a = np.linspace(-1.0, 1.0, 4096, dtype=np.float32)
        poly(a, a)                       # compiler layer: trace + lower
        poly(a + 1.0, a - 1.0)           # program-cache hit
        engine = ServeEngine(model, params, batch_slots=slots,
                             max_seq=max_seq, policy=policy)
        done, wall = drive(engine, _fresh(workload))
        tracer = obs.get_tracer(sess)

    assert tracer is not None, "session(obs=True) produced no tracer"
    trace = save_trace(tracer, trace_path)
    errors = validate_chrome_trace(trace)
    assert not errors, f"exported trace fails schema validation: {errors}"

    span_names = {s.name for s in tracer.spans}
    inst_names = {i.name for i in tracer.instants}
    for want in ("serve.step", "serve.decode_step",        # serving
                 "kv.grow",                                # memory
                 "compiler.trace", "compiler.lower"):      # compiler
        assert want in span_names, f"missing span {want!r} in trace"
    for want in ("request.submit", "request.first_token", "request.done",
                 "mem.alloc"):
        assert want in inst_names, f"missing instant {want!r} in trace"
    hits = tracer.metrics.snapshot()["counters"]
    assert hits.get("compiler.program_cache_hit", 0) >= 1

    # the trace-side latency summary must agree with the benchmark's own
    # Request-timestamp numbers within 1%
    summary = summarize(trace)
    ttfts = [r.first_token_time - r.submit_time for r in done
             if r.first_token_time is not None]
    inter = []
    for r in done:
        ts = sorted(r.token_times)
        inter.extend(b - a for a, b in zip(ts, ts[1:]))

    def check(name, bench_vals, dist):
        assert dist["count"] == len(bench_vals), \
            (name, dist["count"], len(bench_vals))
        for q in (50, 90, 99):
            want = float(np.percentile(bench_vals, q))
            got = dist[f"p{q}"]
            assert abs(got - want) <= 0.01 * abs(want) + 1e-9, \
                f"{name} p{q}: trace {got} vs bench {want}"

    check("ttft", ttfts, summary["requests"]["ttft_s"])
    check("inter_token", inter, summary["requests"]["inter_token_s"])

    toks = sum(len(r.generated) for r in done)
    print(f"[{'obs-on-paged-fifo':>28s}] {toks:4d} tok in {wall:7.2f}s | "
          f"{len(tracer.spans)} spans + {len(tracer.instants)} instants "
          f"-> {trace_path} (schema ok, "
          f"ttft/inter-token match bench within 1%)")
    return {"requests": len(done), "tokens": toks,
            "spans": len(tracer.spans), "instants": len(tracer.instants),
            "dropped_events": tracer.dropped,
            "trace_path": trace_path,
            "ttft_s": summary["requests"]["ttft_s"],
            "inter_token_s": summary["requests"]["inter_token_s"],
            "metrics": tracer.metrics.snapshot()}


def make_spec_workload(n_requests: int, max_new: int, seed: int = 11):
    """Short prompts, longer generations: greedy decode from a tiny
    model settles into short cycles, which n-gram self-drafting then
    predicts — the regime where wide verify amortizes per-step dispatch.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n_requests):
        length = int(rng.integers(5, 12))
        prompt = [int(t) for t in rng.integers(1, 60, size=length)]
        reqs.append((2 * uid, Request(uid=uid, prompt=prompt,
                                      max_new_tokens=max_new)))
    return reqs


def run_spec_scenario(name: str, model, params, policy: ServingPolicy, *,
                      slots: int, max_seq: int, workload, warmup,
                      proposer=None) -> tuple[dict, dict]:
    """Drive the trace on one engine; return (stats, tokens-by-uid).

    The warmup trace runs first on the *same* engine (jit caches are
    per-engine) so the timed run measures steady-state decode, not
    compilation.
    """
    with repro.session(tag=f"bench_serving:{name}"):
        engine = ServeEngine(model, params, batch_slots=slots,
                             max_seq=max_seq, policy=policy,
                             proposer=proposer)
    drive(engine, _fresh(warmup))          # compile-only wave, untimed
    done, wall = drive(engine, _fresh(workload))
    toks = sum(len(r.generated) for r in done)
    spec = engine.describe()["speculative"]
    stats = {
        "scenario": name,
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1) if wall > 0 else None,
        "decode_calls": engine.decode_calls,
        "verify_calls": spec["verify_calls"],
        "spec_rounds": spec["rounds"],
        "accepted_tokens": spec["accepted_tokens"],
        "rejected_tokens": spec["rejected_tokens"],
        "accepted_per_step": spec["accepted_per_step"],
        "rollback_blocks_freed": (engine.kv.rollback_blocks_freed
                                  if engine.paged else 0),
        "provenance": engine.describe(),
    }
    return stats, {r.uid: list(r.generated) for r in done}


def run_spec_section(model, params, *, slots: int, max_seq: int,
                     n_req: int, max_new: int, chunk: int) -> dict:
    """Speculative decode vs one-token decode, same trace, three drafts.

    * ``spec-off-one-token`` — the baseline.
    * ``spec-ngram-k4`` — n-gram self-drafting.  An *untrained* target
      never repeats itself, so acceptance here is near the floor; the
      scenario checks identity and reports honest self-draft acceptance.
    * ``spec-oracle-k4`` — a ``FixedProposer`` replaying the baseline's
      own continuation (a perfect draft).  Every emitted token still
      comes from the target's argmax through the full verify/rollback
      path; the oracle only controls the acceptance rate, isolating the
      engine-mechanics speedup of wide verify at high acceptance.

    Asserts greedy tokens are bit-identical across all three and that
    the high-acceptance run beats one-token decode by >= 1.3x
    end-to-end — the acceptance check for the speculative stack.
    """
    workload = make_spec_workload(n_req, max_new)
    warmup = make_spec_workload(2, 8, seed=12)
    base = dict(cache="paged", scheduler="fifo", block_size=8,
                prefill_chunk=chunk)
    spec_policy = ServingPolicy(**base, speculative=dict(
        enabled=True, k=4, draft="ngram", ngram=3))
    plain, gen_plain = run_spec_scenario(
        "spec-off-one-token", model, params, ServingPolicy(**base),
        slots=slots, max_seq=max_seq, workload=workload, warmup=warmup)
    ngram, gen_ngram = run_spec_scenario(
        "spec-ngram-k4", model, params, spec_policy,
        slots=slots, max_seq=max_seq, workload=workload, warmup=warmup)

    # oracle replay: full greedy sequence per request, continuation
    # looked up by matching the slot context against a sequence prefix
    seqs = [list(r.prompt) + list(gen_plain[r.uid]) for _, r in workload]

    def replay(ctx):
        n = len(ctx)
        for seq in seqs:
            if len(seq) >= n and seq[:n] == ctx:
                return seq[n:]
        return []

    oracle, gen_oracle = run_spec_scenario(
        "spec-oracle-k4", model, params, spec_policy,
        slots=slots, max_seq=max_seq, workload=workload, warmup=warmup,
        proposer=FixedProposer(replay))

    for stats in (plain, ngram, oracle):
        print(f"[{stats['scenario']:>28s}] {stats['tokens']:4d} tok in "
              f"{stats['wall_s']:7.2f}s = {stats['tok_per_s']:8.1f} tok/s"
              f" | verify {stats['verify_calls']} / decode "
              f"{stats['decode_calls']} calls | accepted/step "
              f"{stats['accepted_per_step']}")
    assert gen_ngram == gen_plain, \
        "ngram speculative decode emitted different greedy tokens"
    assert gen_oracle == gen_plain, \
        "oracle speculative decode emitted different greedy tokens"
    assert oracle["accepted_per_step"] > 2.0, \
        "oracle draft should accept most proposals"
    speedup = plain["wall_s"] / max(oracle["wall_s"], 1e-9)
    print(f"\nspeculative decode: {oracle['accepted_per_step']} accepted "
          f"tokens/step at oracle draft ({ngram['accepted_per_step']} "
          f"ngram self-draft), {oracle['verify_calls']} verify vs "
          f"{plain['decode_calls']} one-token calls, "
          f"{oracle['rollback_blocks_freed'] + ngram['rollback_blocks_freed']}"
          f" blocks rolled back; {speedup:.2f}x end-to-end, "
          "greedy tokens identical across all drafts")
    assert speedup >= 1.3, \
        f"speculative speedup {speedup:.2f}x < 1.3x over one-token decode"
    return {"requests": n_req, "max_new": max_new,
            "speedup": round(speedup, 2),
            "results": [plain, ngram, oracle]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small model + short trace (CI smoke)")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--trace", default="poisson",
                    choices=("poisson", "staggered"),
                    help="arrival process for the sharing section")
    ap.add_argument("--obs-trace", metavar="PATH", default=None,
                    help="run an observability-on scenario and write a "
                    "Perfetto-loadable Chrome trace JSON to PATH")
    args = ap.parse_args()

    overrides = {}
    if args.quick:
        overrides = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=64)
    cfg = get_config(args.arch, reduced=True, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req = args.requests or (6 if args.quick else 12)
    max_new = args.max_new or (6 if args.quick else 16)
    workload = make_workload(n_req, max_new)
    chunk = 8

    # ~half the blocks a full complement of slots could want: the
    # priority scenario exercises evict + requeue under real pressure
    tight_pool = 2 * args.slots + 1
    scenarios = [
        ("dense-fifo-legacy-prefill",
         ServingPolicy(cache="dense", scheduler="fifo", prefill_chunk=0)),
        ("dense-fifo-chunked",
         ServingPolicy(cache="dense", scheduler="fifo",
                       prefill_chunk=chunk)),
        ("paged-fifo",
         ServingPolicy(cache="paged", scheduler="fifo", block_size=16,
                       prefill_chunk=chunk)),
        ("paged-sjf",
         ServingPolicy(cache="paged", scheduler="sjf", block_size=16,
                       prefill_chunk=chunk)),
        ("paged-priority-tight-pool",
         ServingPolicy(cache="paged", scheduler="priority", block_size=16,
                       num_blocks=tight_pool, prefill_chunk=chunk)),
    ]

    results = []
    for name, policy in scenarios:
        res = run_scenario(name, model, params, policy, slots=args.slots,
                           max_seq=args.max_seq, workload=workload)
        results.append(res)
        print(f"[{name:>28s}] {res['tokens']:4d} tok in "
              f"{res['wall_s']:7.2f}s = {res['tok_per_s']:8.1f} tok/s | "
              f"ttft {res['ttft_mean_s']}s | "
              f"prefill calls/admission {res['prefill_calls_per_admission']}"
              f" | preempt {res['preemptions']}")

    legacy = results[0]
    chunked = results[1]
    print(f"\nchunked prefill: {chunked['prefill_calls']} jitted prefill "
          f"calls vs {legacy['prefill_calls']} legacy one-token calls "
          f"({legacy['prefill_calls'] / max(1, chunked['prefill_calls']):.1f}"
          f"x fewer compiled-call dispatches per admission stream)")

    print()
    sharing = run_sharing_section(model, params, slots=args.slots,
                                  max_seq=args.max_seq, n_req=8,
                                  max_new=max_new, trace=args.trace,
                                  chunk=chunk)

    print()
    speculative = run_spec_section(model, params, slots=args.slots,
                                   max_seq=max(args.max_seq, 64),
                                   n_req=6 if args.quick else 8,
                                   max_new=48, chunk=chunk)

    payload = {"arch": cfg.name, "slots": args.slots,
               "max_seq": args.max_seq, "prefill_chunk": chunk,
               "results": results, "sharing": sharing,
               "speculative": speculative}

    if args.obs_trace:
        print()
        payload["observability"] = run_obs_section(
            model, params, slots=args.slots, max_seq=args.max_seq,
            n_req=min(n_req, 6), max_new=max_new, chunk=chunk,
            trace_path=args.obs_trace)

    bench_artifact.emit("serving", payload, out=args.out,
                        quick=args.quick, echo=not args.out)


if __name__ == "__main__":
    main()
