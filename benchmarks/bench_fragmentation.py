"""Case study §5.2.2: fragmentation reduction in caching allocators.

Pipeline (exactly as the paper describes, on our stack):
 1. run real model steps under the lazy backend with telemetry recording →
    allocation traces that tie tensor ops to allocations;
 2. replay each trace against allocator policies: bump (lower bound),
    naive caching (round+best-fit, unrestricted handout), caching+split,
    caching+split-threshold (the paper's winning policy);
 3. report internal fragmentation per policy and the reduction vs naive.

The paper's result: the split-restricted caching manager "reduced internal
fragmentation for most models by over 20%".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nn
from repro.core.autograd import Variable
from repro.core.memory import (BumpMemoryManager, CachingMemoryManager,
                               telemetry)
from repro.core.tensor import ops, use_backend


def _record_mlp_trace():
    """Variable-batch MLP steps (the size diversity that fragments caches)."""
    shapes = [(64, 256, 512), (48, 192, 384), (96, 320, 640),
              (64, 256, 512), (32, 128, 256), (80, 288, 576)]
    with use_backend("lazy"):
        trace = telemetry.start_recording()
        for i, (b, d, f) in enumerate(shapes):
            x = ops.full((b, d), 1.0 + i)
            w1 = ops.full((d, f), 0.01)
            w2 = ops.full((f, b), 0.01)
            h = ops.relu(ops.matmul(x, w1))
            out = ops.matmul(h, w2)
            loss = ops.sum(ops.mul(out, out))
            ops.materialize(loss)
        return telemetry.stop_recording()


def _record_attention_trace():
    """Attention at ragged sequence lengths (serving-style size churn)."""
    seqs = [64, 48, 96, 64, 32, 80]
    with use_backend("lazy"):
        trace = telemetry.start_recording()
        for s_len in seqs:
            q = ops.full((4, s_len, 64), 0.1)
            k = ops.full((4, s_len, 64), 0.1)
            v = ops.full((4, s_len, 64), 0.2)
            s = ops.matmul(q, ops.transpose(k, (0, 2, 1)))
            w = ops.softmax(s, axis=-1)
            o = ops.matmul(w, v)
            ops.materialize(ops.sum(o))
        return telemetry.stop_recording()


def _record_varied_trace(seed=0, n=400):
    """Size-diverse synthetic trace (transformer-like mixture of small
    norms/bias buffers and large activations)."""
    rng = np.random.default_rng(seed)
    trace = telemetry.AllocTrace()
    live = []
    uid = 0
    for i in range(n):
        if live and rng.random() < 0.45:
            j = rng.integers(len(live))
            trace.append(telemetry.TraceEvent("free", live.pop(j)))
        else:
            uid += 1
            kind = rng.random()
            if kind < 0.4:
                nbytes = int(rng.integers(256, 4096))           # scalars/norms
            elif kind < 0.8:
                nbytes = int(rng.integers(64 << 10, 512 << 10))  # activations
            else:
                nbytes = int(rng.integers(2 << 20, 16 << 20))    # big buffers
            trace.append(telemetry.TraceEvent("alloc", uid, nbytes))
            live.append(uid)
    return trace


def _frag(policy_kwargs, trace) -> tuple[float, int]:
    mgr = CachingMemoryManager(capacity=1 << 34, **policy_kwargs)
    trace.replay(mgr)
    return mgr.stats.internal_fragmentation, mgr.stats.n_device_allocs


def run() -> list[tuple[str, float, str]]:
    rows = []
    traces = {
        "mlp": _record_mlp_trace(),
        "attention": _record_attention_trace(),
        "varied": _record_varied_trace(),
    }
    for name, trace in traces.items():
        naive, dev_naive = _frag(dict(split_large_blocks=False), trace)
        split, dev_split = _frag(dict(split_large_blocks=True), trace)
        thresh, _ = _frag(dict(split_large_blocks=True,
                               split_threshold=1 << 20), trace)
        best = min(split, thresh)
        reduction = (naive - best) / max(naive, 1e-9) * 100
        rows.append((f"frag_{name}_naive_pct", naive * 100,
                     f"{len(trace)} events, {dev_naive} device allocs"))
        rows.append((f"frag_{name}_split_pct", split * 100, ""))
        rows.append((f"frag_{name}_split_threshold_pct", thresh * 100,
                     f"reduction={reduction:.0f}% (paper: >20%)"))
    return rows


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val:.4f},{derived}")
