"""The unified runtime Session: stack semantics, thread isolation,
back-compat shims, provenance snapshots, and kernel/precision overrides."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.tensor import current_backend, ops, set_backend, use_backend
from repro.runtime import KernelOverrides, PrecisionPolicy, Session


# -- stack semantics ---------------------------------------------------------

def test_default_session_is_jnp_no_mesh():
    s = repro.current_session()
    assert s.backend == "jnp"
    assert s.mesh is None
    assert s.backend_instance().name == "jnp"


def test_nesting_composes_and_restores():
    with repro.session(backend="lazy") as outer:
        assert current_backend().name == "lazy"
        with repro.session(tag="inner") as inner:
            # overrides derive from the *current* session: backend kept
            assert inner.backend == "lazy"
            assert inner.tag == "inner"
        assert repro.current_session() is outer
    assert current_backend().name == "jnp"


def test_restore_on_exception():
    before = repro.current_session()
    with pytest.raises(RuntimeError, match="boom"):
        with repro.session(backend="lazy"):
            assert current_backend().name == "lazy"
            raise RuntimeError("boom")
    assert repro.current_session() is before
    assert current_backend().name == "jnp"


def test_enter_explicit_session_verbatim():
    s = Session(backend="lazy", tag="explicit")
    with repro.session(s) as active:
        assert active is s
        assert current_backend().name == "lazy"
    with pytest.raises(TypeError):
        with repro.session("lazy"):
            pass


def test_replace_accepts_nested_dicts():
    s = Session()
    s2 = s.replace(kernels={"matmul": np.matmul},
                   precision={"compute_dtype": "bf16"})
    assert s2.kernels.matmul is np.matmul
    assert s2.kernels.decode_attention is None     # others preserved
    assert s2.precision.compute_dtype == "bf16"
    assert s.kernels.matmul is None                # original untouched


def test_thread_isolation():
    seen = {}

    def worker():
        # a session entered on the main thread must not leak here
        seen["backend"] = repro.current_session().backend
        with repro.session(backend="lazy"):
            seen["scoped"] = repro.current_session().backend

    with repro.session(backend="pallas"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert repro.current_session().backend == "pallas"
    assert seen["backend"] == "jnp"
    assert seen["scoped"] == "lazy"


# -- back-compat shims -------------------------------------------------------

def test_use_backend_shim_warns_and_swaps():
    with pytest.deprecated_call():
        with use_backend("lazy") as b:
            assert b.name == "lazy"
            assert current_backend().name == "lazy"
            assert repro.current_session().backend == "lazy"
    assert current_backend().name == "jnp"


def test_set_backend_shim_scoped_by_session():
    with repro.session():
        with pytest.deprecated_call():
            set_backend("lazy")
        assert current_backend().name == "lazy"
    # the imperative mutation died with its enclosing scope
    assert current_backend().name == "jnp"


def test_active_mesh_shim_warns_and_installs():
    from repro.launch.mesh import make_mesh
    from repro.sharding.context import active_mesh, get_active_mesh

    mesh = make_mesh((1,), ("data",))
    with pytest.deprecated_call():
        with active_mesh(mesh, batch_axes=("data",)):
            assert get_active_mesh() is mesh
            assert repro.current_session().mesh is mesh
            assert repro.current_session().batch_axes == ("data",)
    assert get_active_mesh() is None


# -- provenance --------------------------------------------------------------

def test_describe_round_trip():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    s = Session(backend="pallas", mesh=mesh, batch_axes=("data",),
                kernels=KernelOverrides(matmul=np.matmul),
                precision=PrecisionPolicy(compute_dtype="bf16"),
                tag="prov")
    d = s.describe()
    assert json.loads(json.dumps(d)) == d
    assert d["backend"] == "pallas"
    assert d["mesh"] == {"axes": {"data": 1}, "devices": 1}
    assert d["kernels"]["matmul"].endswith("matmul")  # ufunc: no __module__
    assert d["precision"]["compute_dtype"] == "bf16"
    assert d["tag"] == "prov"


# -- override consumption ----------------------------------------------------

def test_matmul_kernel_override_scoped():
    calls = []

    def spy(lhs, rhs):
        calls.append(lhs.shape)
        return jnp.matmul(lhs, rhs)

    a = jnp.ones((4, 4))
    with repro.session(kernels={"matmul": spy}):
        out = ops.matmul(a, a)
    assert calls == [(4, 4)]
    np.testing.assert_allclose(np.asarray(out), 4.0 * np.ones((4, 4)))
    ops.matmul(a, a)
    assert len(calls) == 1  # override gone with the scope


def test_precision_policy_applies_to_get_config():
    from repro.configs.base import get_config

    with repro.session(precision={"compute_dtype": "f32",
                                  "cache_dtype": "fp8"}):
        cfg = get_config("codeqwen1.5-7b", reduced=True)
    assert cfg.compute_dtype == jnp.float32
    assert cfg.cache_dtype == "fp8"
    # explicit get_config overrides still beat the policy
    with repro.session(precision={"cache_dtype": "fp8"}):
        cfg = get_config("codeqwen1.5-7b", reduced=True,
                         cache_dtype="compute")
    assert cfg.cache_dtype == "compute"
    # and no leakage outside the scope
    assert get_config("codeqwen1.5-7b", reduced=True).cache_dtype == "compute"


def test_decode_attention_override_reaches_model_decode():
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.models.attention import plain_cache_attention

    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    hits = []

    def attend(q, k, v, valid, *, scale, cap=0.0):
        hits.append(q.shape)
        return plain_cache_attention(q, k, v, valid, scale=scale, cap=cap)

    with repro.session(kernels={"decode_attention": attend}):
        model.decode_step(params, cache, tok, jnp.int32(0))
    assert hits, "session decode_attention override was not consulted"
