"""repro.obs: session gating, span nesting (threads + nested sessions),
bounded retention, metrics, Chrome-trace export/validation, summarize
math, the telemetry bridge, and the instrumented serving/compiler paths.
"""

import json
import threading

import jax
import numpy as np
import pytest

import repro
from repro import obs
from repro.configs.base import get_config
from repro.core.memory import CachingMemoryManager, telemetry
from repro.core.tensor import ops
from repro.models import build_model
from repro.obs import (Tracer, save_trace, to_chrome_trace,
                       validate_chrome_trace)
from repro.obs.metrics import percentile
from repro.obs.summarize import summarize
from repro.runtime import ObservabilityPolicy, ServingPolicy
from repro.serving import Request, ServeEngine


# ---------------------------------------------------------------- gating

def test_obs_off_by_default():
    assert obs.get_tracer() is None
    with repro.session():
        assert obs.get_tracer() is None
    # module-level helpers are no-ops, not errors
    with obs.span("nope") as sp:
        assert sp is None
    obs.instant("nope")


def test_session_obs_coercion_and_provenance():
    with repro.session(obs=True) as sess:
        assert isinstance(sess.obs, ObservabilityPolicy)
        assert sess.obs.enabled
        assert obs.get_tracer() is not None
        desc = sess.describe()["obs"]
        assert desc["enabled"]
    with repro.session(obs={"max_events": 99}) as sess:
        assert sess.obs.enabled and sess.obs.max_events == 99
        assert obs.get_tracer().max_events == 99


def test_derived_sessions_share_tracer_fresh_policy_does_not():
    with repro.session(obs=True):
        outer = obs.get_tracer()
        assert outer is not None
        with repro.session(tag="inner"):       # derived: same policy obj
            assert obs.get_tracer() is outer
        with repro.session(obs=True):          # fresh policy: new tracer
            assert obs.get_tracer() is not outer
        with repro.session(obs=False):         # explicitly off inside
            assert obs.get_tracer() is None
    assert obs.get_tracer() is None


# --------------------------------------------------------------- tracing

def test_span_nesting_and_attrs():
    with repro.session(obs=True):
        t = obs.get_tracer()
        with obs.span("outer", "test", k=1) as a:
            with obs.span("inner", "test") as b:
                pass
            a.attrs["late"] = 2
    assert b.parent == a.sid and a.parent is None
    assert a.attrs == {"k": 1, "late": 2}
    assert [s.name for s in t.spans] == ["inner", "outer"]  # finish order
    assert all(s.end >= s.start for s in t.spans)


def test_spans_do_not_cross_parent_across_threads():
    tracer = Tracer()
    ready = threading.Barrier(2)

    def work(name):
        with tracer.span(f"{name}.outer"):
            ready.wait()
            with tracer.span(f"{name}.inner"):
                pass

    threads = [threading.Thread(target=work, args=(n,), name=n)
               for n in ("a", "b")]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    by_name = {s.name: s for s in tracer.spans}
    assert len(by_name) == 4
    for n in ("a", "b"):
        inner, outer = by_name[f"{n}.inner"], by_name[f"{n}.outer"]
        assert inner.parent == outer.sid
        assert inner.tid == outer.tid
    assert by_name["a.inner"].tid != by_name["b.inner"].tid
    assert set(tracer.thread_names.values()) >= {"a", "b"}


def test_mis_nested_finish_unwinds():
    tracer = Tracer()
    a = tracer.begin("a")
    tracer.begin("b")
    tracer.finish(a)                 # b never finished: unwound with a
    with tracer.span("c") as c:
        pass
    assert c.parent is None          # stack fully unwound


def test_max_events_bound_counts_drops():
    tracer = Tracer(max_events=3)
    for i in range(5):
        tracer.instant(f"e{i}")
    assert len(tracer.instants) == 3
    assert tracer.dropped == 2
    assert tracer.describe()["dropped"] == 2


# --------------------------------------------------------------- metrics

def test_metrics_counters_gauges_histograms():
    tracer = Tracer()
    m = tracer.metrics
    m.counter("c").add()
    m.counter("c").add(2.5)
    g = m.gauge("g")
    g.set(7)
    g.set(9)
    vals = [float(v) for v in np.random.default_rng(0).normal(size=257)]
    h = m.histogram("h")
    for v in vals:
        h.observe(v)
    snap = m.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 9.0
    hs = snap["histograms"]["h"]
    assert hs["count"] == len(vals)
    for q in (50, 90, 99):
        assert hs[f"p{q}"] == pytest.approx(
            float(np.percentile(vals, q)), abs=1e-12)
    # gauge sets also landed on a counter track
    assert [s.value for s in tracer.samples] == [7.0, 9.0]


def test_percentile_matches_numpy_linear_interpolation():
    vals = sorted([0.1, 4.0, 2.0, 9.5, 3.3])
    for q in (0, 10, 25, 50, 75, 90, 99, 100):
        assert percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)), abs=1e-12)


# ---------------------------------------------------------------- export

def test_export_validates_and_round_trips(tmp_path):
    tracer = Tracer()
    with tracer.span("parent", "t", k=1):
        with tracer.span("child", "t"):
            pass
    tracer.instant("evt", "t", uid=3)
    tracer.metrics.gauge("g").set(5)
    tracer.metrics.counter("n").add()
    path = tmp_path / "trace.json"
    obj = save_trace(tracer, str(path))
    assert validate_chrome_trace(obj) == []
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == []
    phases = {e["ph"] for e in loaded["traceEvents"]}
    assert phases == {"M", "X", "i", "C"}
    x = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in x} == {"parent", "child"}
    child = next(e for e in x if e["name"] == "child")
    parent = next(e for e in x if e["name"] == "parent")
    assert child["args"]["parent_id"] == parent["args"]["span_id"]
    assert loaded["metrics"]["counters"]["n"] == 1.0


def test_validator_catches_malformed_events():
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 1, "tid": 1, "ts": 0},
        {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": -5, "dur": 1},
        {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0},
        {"ph": "i", "name": "x", "pid": 1, "tid": 1, "ts": 0, "s": "q"},
        {"ph": "C", "name": "x", "pid": 1, "tid": 1, "ts": 0,
         "args": {"v": "high"}},
        {"ph": "i", "name": 7, "pid": "p", "tid": 1, "ts": 0},
    ]}
    errs = validate_chrome_trace(bad)
    assert len(errs) >= 6
    assert validate_chrome_trace([]) == ["top-level value is not an object"]
    assert validate_chrome_trace({}) == ["missing or non-array 'traceEvents'"]


# ------------------------------------------------------------- summarize

def test_summarize_synthetic_trace_exact_math():
    """Known timestamps in, exact TTFT / inter-token / self-time out."""
    pid = 1
    ev = []

    def span(name, ts, dur, sid, parent=None):
        args = {"span_id": sid}
        if parent is not None:
            args["parent_id"] = parent
        ev.append({"ph": "X", "name": name, "cat": "t", "ts": ts,
                   "dur": dur, "pid": pid, "tid": 1, "args": args})

    def inst(name, ts, uid):
        ev.append({"ph": "i", "s": "t", "name": name, "cat": "t", "ts": ts,
                   "pid": pid, "tid": 1, "args": {"uid": uid}})

    span("root", 0.0, 100.0, sid=1)
    span("leaf", 10.0, 30.0, sid=2, parent=1)
    span("leaf", 50.0, 20.0, sid=3, parent=1)
    inst("request.submit", 0.0, uid=7)
    inst("request.first_token", 1_000_000.0, uid=7)   # µs -> TTFT 1s
    inst("request.token", 1_000_000.0, uid=7)
    inst("request.token", 1_250_000.0, uid=7)
    inst("request.token", 1_750_000.0, uid=7)
    inst("request.done", 1_750_000.0, uid=7)
    s = summarize({"traceEvents": ev})
    by_name = {a["name"]: a for a in s["spans"]["by_name"]}
    assert by_name["root"]["total_us"] == pytest.approx(100.0)
    assert by_name["root"]["self_us"] == pytest.approx(50.0)  # 100-30-20
    assert by_name["leaf"]["count"] == 2
    r = s["requests"]
    assert r["submitted"] == 1 and r["completed"] == 1
    assert r["ttft_s"]["count"] == 1
    assert r["ttft_s"]["p50"] == pytest.approx(1.0)
    assert r["inter_token_s"]["count"] == 2
    assert r["inter_token_s"]["p50"] == pytest.approx(0.375)  # .25/.5 mid
    assert r["inter_token_s"]["max"] == pytest.approx(0.5)


# ------------------------------------------------- telemetry (satellite)

def test_alloc_trace_timestamps_and_old_format_compat(tmp_path):
    trace = telemetry.start_recording()
    telemetry.record_alloc(1, 4096, tag="matmul")
    telemetry.record_free(1)
    t = telemetry.stop_recording()
    assert all(e.ts > 0 for e in t.events)
    assert t.events[0].ts <= t.events[1].ts
    path = tmp_path / "trace.json"
    t.save(str(path))
    t2 = telemetry.AllocTrace.load(str(path))
    assert [(e.kind, e.uid, e.ts) for e in t2.events] == \
        [(e.kind, e.uid, e.ts) for e in t.events]

    # traces written before the ts field existed still load + replay
    old = [{"kind": "alloc", "uid": 5, "nbytes": 512, "tag": "add"},
           {"kind": "free", "uid": 5, "nbytes": 512, "tag": ""}]
    oldpath = tmp_path / "old.json"
    oldpath.write_text(json.dumps(old))
    t3 = telemetry.AllocTrace.load(str(oldpath))
    assert [e.ts for e in t3.events] == [0.0, 0.0]
    mgr = CachingMemoryManager(capacity=1 << 20)
    t3.replay(mgr)
    assert mgr.stats.n_allocs == 1 and mgr.stats.live_allocated == 0


def test_telemetry_bridges_into_obs_without_recording():
    with repro.session(obs=True):
        tracer = obs.get_tracer()
        telemetry.record_alloc(42, 1024, tag="kv.block")
        telemetry.record_free(42)
    names = [(i.name, i.attrs.get("uid")) for i in tracer.instants]
    assert ("mem.alloc", 42) in names and ("mem.free", 42) in names
    # and no AllocTrace was involved
    assert telemetry.stop_recording() is None


# --------------------------------------------- instrumented stack paths

@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_compiler_spans_and_cache_counters():
    @repro.compile
    def f(x, y):
        return ops.tanh(ops.add(ops.mul(x, y), x))

    a = np.linspace(-1, 1, 64, dtype=np.float32)
    with repro.session(obs=True):
        f(a, a)
        f(a + 1, a - 1)
        tracer = obs.get_tracer()
    names = {s.name for s in tracer.spans}
    assert {"compiler.trace", "compiler.compile", "compiler.lower",
            "compiler.execute"} <= names
    assert any(n.startswith("compiler.pass.") for n in names)
    # pass spans nest under the compile span
    compile_sp = next(s for s in tracer.spans
                      if s.name == "compiler.compile")
    for sp in tracer.spans:
        if sp.name.startswith("compiler.pass."):
            assert sp.parent == compile_sp.sid
    counters = tracer.metrics.snapshot()["counters"]
    assert counters["compiler.program_cache_miss"] == 1.0
    assert counters["compiler.program_cache_hit"] == 1.0


def test_serving_trace_reconstructs_lifecycle(tiny):
    """The obs stream must reproduce the admission/preempt/requeue story
    pinned by test_serving_paged.test_preemption_evicts_requeues_and_
    recomputes — same scenario, now read back from the trace."""
    model, params = tiny
    prompts = [[3, 1, 4, 1, 5, 9], [9, 2, 6, 5, 3, 5]]
    pol = ServingPolicy(cache="paged", block_size=4, num_blocks=7,
                        prefill_chunk=4)

    def run(obs_on):
        with repro.session(obs=obs_on, serving=pol):
            eng = ServeEngine(model, params, batch_slots=2, max_seq=32)
            for u, p in enumerate(prompts):
                eng.submit(Request(uid=u, prompt=list(p),
                                   max_new_tokens=12))
            done = eng.run_until_done()
            return eng, done, obs.get_tracer()

    eng, done, tracer = run(True)
    assert eng.preemptions > 0
    events = [(i.name, i.attrs.get("uid")) for i in tracer.instants
              if i.name.startswith("request.")]

    # per-request ordering: submit -> admit -> first_token; a preempted
    # request is requeued and admitted again before finishing
    for uid in (0, 1):
        seq = [n for n, u in events if u == uid]
        assert seq[0] == "request.submit"
        assert seq.count("request.done") == 1 and seq[-1] == "request.done"
        assert seq.index("request.admit") < seq.index("request.first_token")
        n_pre = seq.count("request.preempt")
        assert seq.count("request.admit") == 1 + n_pre
        if n_pre:
            i_pre = seq.index("request.preempt")
            assert "request.requeue" in seq[i_pre:]
            assert "request.admit" in seq[i_pre:]
    assert sum(n == "request.preempt" for n, _ in events) == eng.preemptions

    # spans + histograms agree with engine counters
    assert sum(s.name == "serve.decode_step" for s in tracer.spans) == \
        eng.decode_calls
    hists = tracer.metrics.snapshot()["histograms"]
    assert hists["serving.ttft_s"]["count"] == len(done)

    # observability does not change decoding
    _, done_off, tracer_off = run(False)
    assert tracer_off is None
    assert {r.uid: r.generated for r in done} == \
        {r.uid: r.generated for r in done_off}

    # and the whole stream exports cleanly
    assert validate_chrome_trace(to_chrome_trace(tracer)) == []


def test_serving_kv_telemetry_uses_negative_uid_namespace(tiny):
    """KV-block alloc events must not collide with LazyTensor uids when a
    recording spans both sources."""
    model, params = tiny
    pol = ServingPolicy(cache="paged", block_size=4, prefill_chunk=4)
    telemetry.start_recording()
    try:
        with repro.session(serving=pol):
            eng = ServeEngine(model, params, batch_slots=1, max_seq=32)
            eng.submit(Request(uid=0, prompt=[3, 1, 4, 1], max_new_tokens=4))
            eng.run_until_done()
    finally:
        trace = telemetry.stop_recording()
    kv_events = [e for e in trace.events if e.tag == "kv.block"]
    assert kv_events and all(e.uid < 0 for e in kv_events)
    mgr = CachingMemoryManager(capacity=1 << 30)
    trace.replay(mgr)
    assert mgr.stats.live_allocated == 0
