"""Tensor interface: dispatch, lazy/fusing backend, pallas backend,
op-surface size (paper Table 1 metric), hypothesis lazy==eager property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.memory import telemetry
from repro.core.tensor import (TensorBackend, available_backends,
                               current_backend, get_backend, ops,
                               use_backend)


def test_primitive_op_surface_is_small():
    """Paper Table 1: Flashlight's operator surface is ~60 ops."""
    n = len(TensorBackend.primitive_ops())
    assert 40 <= n <= 80, n


def test_exactly_one_add_one_conv_one_sum():
    """Paper Table 1's 'approx num. ops that perform ADD/CONV/SUM = 1'."""
    prims = TensorBackend.primitive_ops()
    assert prims.count("add") == 1
    assert sum(1 for p in prims if p.startswith("conv")) == 1
    assert prims.count("sum") == 1


def test_default_backend_and_registry():
    assert current_backend().name == "jnp"
    assert {"jnp", "lazy", "pallas"} <= set(available_backends())
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_derived_ops_compose_from_primitives():
    x = jnp.asarray([-1.0, 0.0, 2.0])
    np.testing.assert_allclose(np.asarray(ops.relu(x)), [0, 0, 2])
    np.testing.assert_allclose(np.asarray(ops.sigmoid(x)),
                               1 / (1 + np.exp([1.0, 0.0, -2.0])), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ops.softmax(x)),
                               np.exp([-1, 0, 2]) / np.exp([-1, 0, 2]).sum(),
                               rtol=1e-6)
    oh = ops.one_hot(jnp.asarray([0, 2]), 3)
    np.testing.assert_allclose(np.asarray(oh), [[1, 0, 0], [0, 0, 1]])


_ELEM = ["exp", "tanh", "abs", "neg", "sqrt_abs", "add_self", "mul_self"]


def _apply(name, x):
    if name == "sqrt_abs":
        return ops.sqrt(ops.abs(x))
    if name == "add_self":
        return ops.add(x, x)
    if name == "mul_self":
        return ops.mul(x, x)
    return getattr(ops, name)(x)


@settings(max_examples=25, deadline=None)
@given(chain=st.lists(st.sampled_from(_ELEM), min_size=1, max_size=6),
       seed=st.integers(0, 50))
def test_lazy_backend_matches_eager(chain, seed):
    """Property: deferred+fused evaluation == eager for random chains."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 8))
    eager = x
    for name in chain:
        eager = _apply(name, eager)
    with use_backend("lazy"):
        lazy = x
        for name in chain:
            lazy = _apply(name, lazy)
        out = ops.materialize(lazy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eager),
                               rtol=1e-5, atol=1e-6)


def test_lazy_defers_until_materialize_and_fuses():
    with use_backend("lazy") as lb:
        before = lb.materialize_calls
        a = ops.full((16, 16), 2.0)
        b = ops.tanh(ops.add(ops.mul(a, a), a))
        assert b.value is None          # nothing computed yet
        out = ops.materialize(b)
        assert lb.materialize_calls == before + 1
    np.testing.assert_allclose(np.asarray(out), np.tanh(6.0) * np.ones((16, 16)),
                               rtol=1e-6)


def test_lazy_emits_alloc_telemetry():
    with use_backend("lazy"):
        trace = telemetry.start_recording()
        a = ops.full((32, 32), 1.0)
        b = ops.exp(ops.mul(a, a))
        ops.materialize(b)
        t = telemetry.stop_recording()
    allocs = [e for e in t.events if e.kind == "alloc"]
    assert len(allocs) >= 3
    assert any(e.tag == "exp" for e in allocs)
    assert all(e.nbytes == 32 * 32 * 4 for e in allocs)


def test_lazy_matmul_and_reduction():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    y = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    with use_backend("lazy"):
        out = ops.materialize(ops.sum(ops.matmul(x, y), axis=0))
    np.testing.assert_allclose(np.asarray(out), np.asarray((x @ y).sum(0)),
                               rtol=1e-5)


def test_pallas_backend_matmul_swap_and_fallback():
    x32 = jnp.ones((128, 128), jnp.float32)
    odd = jnp.ones((100, 100), jnp.float32)
    with use_backend("pallas") as pb:
        k0, f0 = pb.kernel_calls, pb.fallback_calls
        r = ops.matmul(x32, x32)
        assert pb.kernel_calls == k0 + 1
        r2 = ops.matmul(odd, odd)          # unaligned -> fallback
        assert pb.fallback_calls == f0 + 1
    np.testing.assert_allclose(np.asarray(r), 128.0 * np.ones((128, 128)))
    np.testing.assert_allclose(np.asarray(r2), 100.0 * np.ones((100, 100)))
