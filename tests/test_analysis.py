"""repro.analysis: the static verification layer.

Four fronts: (1) zero false positives — every selfcheck pipeline
permutation runs with the structured validator between passes and must
stay silent; (2) the mutation corpus — every seeded defect class flagged
by exactly its intended rule; (3) enforcement plumbing — AnalysisPolicy
levels through repro.compile(check=...), the Session, the lazy backend,
and the PassManager; (4) the serving audit over a *real* PagedKVCache.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.analysis import (AnalysisError, AnalysisPolicy, Diagnostic,
                            DiagnosticReport, Severity, analyze_graph,
                            check_graph, check_kernel_call,
                            check_paged_cache, snapshot_cache)
from repro.analysis.mutations import MUTATIONS, run_mutations
from repro.compiler.passes import PassManager
from repro.compiler.selfcheck import CORPUS, PIPELINES, _build
from repro.core.tensor import ops
from repro.runtime import CompilerPolicy


# -- diagnostics primitives ---------------------------------------------------


def test_diagnostic_report_accounting():
    r = DiagnosticReport()
    r.add("shape.mismatch", Severity.ERROR, "bad", node=3, op="add")
    r.add("vmem.over-budget", Severity.WARNING, "big", cluster=1)
    r.add("tile.unaligned", Severity.INFO, "meh")
    assert r.rules == {"shape.mismatch", "vmem.over-budget", "tile.unaligned"}
    assert len(r.errors) == 1 and len(r.warnings) == 1
    assert r.max_severity() == Severity.ERROR
    assert r.counts() == {"INFO": 1, "WARNING": 1, "ERROR": 1}
    assert [d.rule for d in r.at_least(Severity.WARNING)] == [
        "shape.mismatch", "vmem.over-budget"]
    j = r.to_json()
    assert j["diagnostics"][0]["severity"] == "ERROR"
    assert "%3" in r.diagnostics[0].format()


def test_raise_if_errors_thresholds():
    r = DiagnosticReport()
    r.add("numerics.bf16-accum", Severity.WARNING, "accum")
    r.raise_if_errors(Severity.ERROR)          # warnings pass at default
    with pytest.raises(AnalysisError) as ei:
        r.raise_if_errors(Severity.WARNING, context="strict mode")
    assert "strict mode" in str(ei.value)
    assert ei.value.report.rules == {"numerics.bf16-accum"}


def test_analysis_policy_levels():
    assert AnalysisPolicy().enabled and not AnalysisPolicy().strict
    assert not AnalysisPolicy(level="off").enabled
    assert AnalysisPolicy(level="strict").error_threshold == Severity.WARNING
    assert AnalysisPolicy().error_threshold == Severity.ERROR
    with pytest.raises(ValueError):
        AnalysisPolicy(level="paranoid")


# -- front 1: zero false positives on the clean corpus ------------------------


@pytest.mark.parametrize("level", ["default", "strict"])
@pytest.mark.parametrize("pipeline", PIPELINES,
                         ids=["+".join(p) or "identity" for p in PIPELINES])
def test_clean_corpus_verifies_between_passes(pipeline, level):
    """Every selfcheck graph through every pipeline with the structured
    validator between passes: zero findings at WARNING or above."""
    apol = AnalysisPolicy(level=level)
    for gname in CORPUS:
        graph, _ = _build(gname)
        pm = PassManager.from_policy(CompilerPolicy(pipeline=pipeline))
        pm.run(graph, verify=apol)             # raises on any error
        report = analyze_graph(graph, apol, where=gname)
        loud = report.at_least(Severity.WARNING)
        assert not loud, (
            f"false positive on {gname}/{pipeline}@{level}: "
            + "; ".join(d.format() for d in loud))


def test_validate_delegates_to_structured_checker():
    """Graph.validate() keeps its list[str] contract but is now one view
    of check_graph — same findings, both directions."""
    g, _ = _build("shared_subexpr")
    assert g.validate() == []
    g.outputs = g.outputs + (10 ** 9,)
    legacy = g.validate()
    structured = check_graph(g, AnalysisPolicy(level="strict"))
    assert len(legacy) == len(structured) == 1
    assert structured.rules == {"graph.orphan-output"}
    assert legacy[0] == structured.diagnostics[0].format()


# -- front 2: the mutation corpus --------------------------------------------


@pytest.mark.parametrize("mutation", MUTATIONS, ids=lambda m: m.name)
def test_mutation_caught_by_exactly_its_rule(mutation):
    report = mutation.build()
    found = sorted({d.rule for d in report.at_least(Severity.WARNING)})
    assert mutation.rule in found, (
        f"seeded defect ({mutation.defect}) escaped: {found}")
    assert found == [mutation.rule], (
        f"rule cascade on {mutation.name}: expected exactly "
        f"{mutation.rule}, got {found}")


def test_mutation_runner_summary():
    results = run_mutations()
    assert len(results) == len(MUTATIONS)
    assert all(r["caught"] and r["exact"] for r in results)
    # the acceptance-critical defect classes are all represented
    rules = {r["rule"] for r in results}
    assert {"shape.mismatch", "alias.double-write", "tile.oob",
            "vmem.over-budget", "kv.leak", "kv.double-free"} <= rules


# -- front 3: enforcement plumbing -------------------------------------------


def _corrupting_pass_manager(pipeline=("cse",)):
    """A PassManager whose final pass corrupts a node's recorded shape."""

    class CorruptPass:
        name = "corrupt"

        def run(self, graph):
            for uid in reversed(graph.order):
                n = graph.nodes[uid]
                if n.op not in ("input", "const"):
                    n.shape = tuple(s + 1 for s in n.shape) or (7,)
                    break
            return {}

    pm = PassManager.from_policy(CompilerPolicy(pipeline=pipeline))
    pm.passes.append(CorruptPass())
    return pm


def test_pass_manager_verify_names_the_broken_pass():
    g, _ = _build("chain")
    pm = _corrupting_pass_manager()
    with pytest.raises(AnalysisError) as ei:
        pm.run(g, verify=AnalysisPolicy())
    assert "after pass 'corrupt'" in str(ei.value)
    assert ei.value.report.rules == {"shape.mismatch"}


def test_pass_manager_verify_off_is_silent():
    g, _ = _build("chain")
    _corrupting_pass_manager().run(g, verify=AnalysisPolicy(level="off"))


def test_compile_check_levels():
    def f(x):
        return ops.tanh(ops.add(x, x))

    x = jnp.ones((8, 8))
    strictf = repro.compile(f, check="strict")
    np.testing.assert_allclose(np.asarray(strictf(x)),
                               np.tanh(2 * np.ones((8, 8))), rtol=1e-6)
    assert strictf.last_executable.diagnostics is not None
    assert not strictf.last_executable.diagnostics.at_least(Severity.WARNING)
    with pytest.raises(ValueError):
        repro.compile(f, check="paranoid")


def test_compile_check_strict_promotes_warnings():
    """bf16 accumulation is a WARNING: default compiles, strict raises."""

    def accum(x):
        return ops.sum(ops.mul(x, x), axis=None, keepdims=False)

    x = jnp.ones((32, 32), jnp.bfloat16)
    out = repro.compile(accum, check="default")(x)
    assert jnp.dtype(out.dtype) == jnp.dtype(jnp.bfloat16)
    with pytest.raises(AnalysisError) as ei:
        repro.compile(accum, check="strict")(x)
    assert "numerics.bf16-accum" in ei.value.report.rules


def test_session_analysis_reaches_lazy_backend():
    """The session-scoped AnalysisPolicy governs every materialization;
    the backend exposes the report as provenance."""
    with repro.session(backend="lazy",
                       analysis={"level": "default"}) as s:
        lb = s.backend_instance()
        y = ops.mul(ops.add(jnp.ones((4, 4)), 1.0), 2.0)
        ops.materialize(y)
        assert lb.last_analysis is not None
        assert not lb.last_analysis.at_least(Severity.WARNING)
    with repro.session(backend="lazy", analysis={"level": "off"}) as s:
        lb = s.backend_instance()
        ops.materialize(ops.add(jnp.ones((4,)), 2.0))
        assert lb.last_analysis is None
    assert repro.current_session().analysis.level == "default"


def test_session_describe_includes_analysis():
    with repro.session(analysis={"level": "strict",
                                 "vmem_limit_bytes": 123}) as s:
        d = s.describe()["analysis"]
        assert d == {"level": "strict", "vmem_limit_bytes": 123,
                     "audit_serving": False}


def test_executable_describe_embeds_diagnostic_counts():
    f = repro.compile(lambda x: ops.neg(ops.tanh(x)), check="default")
    f(jnp.ones((4, 4)))
    d = f.last_executable.describe()
    assert d["diagnostics"] == {"INFO": 0, "WARNING": 0, "ERROR": 0}


# -- front 4: the serving audit over a real cache -----------------------------


@pytest.fixture(scope="module")
def paged_cache():
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.serving.kv_cache import PagedKVCache

    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    return PagedKVCache(model, slots=2, max_seq=32, block_size=4)


def test_paged_cache_audit_clean_through_lifecycle(paged_cache):
    kv = paged_cache
    assert len(kv.audit()) == 0
    kv.ensure(0, 10)
    kv.ensure(1, 3)
    assert len(kv.audit()) == 0
    kv.release(0)
    assert len(kv.audit()) == 0
    kv.release(1)
    assert len(kv.audit()) == 0


def test_paged_cache_audit_catches_seeded_leak(paged_cache):
    kv = paged_cache
    kv.ensure(0, 7)
    # seed a leak: drop a held block without telling the allocator
    leaked = kv._blocks[0].pop()
    kv.table[0, len(kv._blocks[0])] = 0
    report = kv.audit()
    assert {d.rule for d in report.errors} == {"kv.leak"}
    kv._blocks[0].append(leaked)               # restore
    kv.table[0, len(kv._blocks[0]) - 1] = leaked[0]
    kv.release(0)
    assert len(kv.audit()) == 0


def test_snapshot_is_a_pure_value(paged_cache):
    kv = paged_cache
    kv.ensure(0, 5)
    snap = snapshot_cache(kv)
    kv.release(0)
    # the snapshot still describes the pre-release state
    assert snap.held[0]
    assert check_paged_cache(snap).max_severity() is None
    j = snap.to_json()
    assert j["manager"] == type(kv.manager).__name__


def test_engine_audit_raises_on_corruption():
    """audit_serving wiring: a corrupted table raises at the next
    release instead of surfacing as cross-request garbage."""
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.runtime import ServingPolicy
    from repro.serving import Request, ServeEngine

    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with repro.session(analysis={"audit_serving": True}):
        eng = ServeEngine(model, params, batch_slots=2, max_seq=32,
                          policy=ServingPolicy(cache="paged", block_size=4,
                                               prefill_chunk=4))
        eng.submit(Request(uid=0, prompt=[3, 1, 4], max_new_tokens=4))
        eng.submit(Request(uid=1, prompt=[9, 2], max_new_tokens=24))
        eng.step()
        # corrupt the long-running slot's table past its held prefix; the
        # audit fires when the short request's slot is released
        slot1 = next(s for s, r in eng.active.items() if r.uid == 1)
        eng.kv.table[slot1, eng.kv.max_blocks - 1] = 5
        with pytest.raises(AnalysisError) as ei:
            eng.run_until_done()
        assert "kv.table-stale" in ei.value.report.rules


def test_engine_audit_clean_run_at_strict():
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.runtime import ServingPolicy
    from repro.serving import Request, ServeEngine

    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with repro.session(analysis={"level": "strict"}):
        eng = ServeEngine(model, params, batch_slots=2, max_seq=32,
                          policy=ServingPolicy(cache="paged", block_size=4,
                                               prefill_chunk=4))
        for uid, p in enumerate([[3, 1, 4, 1, 5], [9, 2], [5, 3]]):
            eng.submit(Request(uid=uid, prompt=list(p), max_new_tokens=5))
        done = eng.run_until_done()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert eng.kv.blocks_in_use == 0


# -- kernel contracts ---------------------------------------------------------


def test_kernel_contracts_clean_on_shipped_defaults():
    """The hand-written kernels' own default launches must satisfy their
    declared contracts on representative aligned shapes."""
    cases = [
        ("flash_attention", dict(b=2, h=4, s=1024, d=64)),
        ("flash_decode", dict(n=8, s=2048, d=64)),
        ("flash_verify", dict(n=8, t=5, s=2048, d=64)),
        ("matmul", dict(m=512, k=512, n=512)),
        ("rms_norm", dict(n=1024, d=512)),
    ]
    for kernel, params in cases:
        report = check_kernel_call(kernel, **params)
        assert not report.at_least(Severity.WARNING), (
            kernel, report.dump())


def test_kernel_contract_unknown_kernel():
    with pytest.raises(KeyError):
        check_kernel_call("warp_drive", x=1)


def test_rms_norm_contract_replicates_autoshrink():
    # the launch wrapper shrinks bn until it divides n — so odd row
    # counts are legal and must not be flagged
    report = check_kernel_call("rms_norm", n=1000, d=256)
    assert not report.at_least(Severity.WARNING)


# -- misc ---------------------------------------------------------------------


def test_diagnostic_is_frozen():
    d = Diagnostic("x.y", Severity.INFO, "m")
    with pytest.raises(dataclasses.FrozenInstanceError):
        d.rule = "z"
