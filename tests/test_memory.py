"""Memory-manager invariants (hypothesis) + the §5.2.2 fragmentation study
machinery."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.memory import (BumpMemoryManager, CachingMemoryManager,
                               OutOfMemory, telemetry)


@settings(max_examples=40, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["alloc", "free"]),
              st.integers(1, 1 << 16)),
    min_size=1, max_size=200))
def test_caching_manager_invariants(script):
    """Property: live blocks never overlap; stats stay consistent."""
    mgr = CachingMemoryManager(capacity=1 << 26, round_to=256)
    live: dict[int, int] = {}     # ptr -> rounded size
    for kind, size in script:
        if kind == "alloc":
            ptr = mgr.alloc(size)
            rounded = mgr._live[ptr].size
            # no overlap with existing live blocks
            for p2, s2 in live.items():
                assert ptr + rounded <= p2 or p2 + s2 <= ptr, \
                    "overlapping live blocks"
            live[ptr] = rounded
        elif live:
            ptr = next(iter(live))
            mgr.unlock(ptr)
            del live[ptr]
    assert mgr.stats.live_allocated == sum(live.values())
    assert mgr.stats.n_allocs - mgr.stats.n_frees == len(live)
    assert mgr.stats.high_water <= mgr.capacity


def test_reuse_avoids_device_allocs():
    mgr = CachingMemoryManager(capacity=1 << 20)
    p1 = mgr.alloc(1000)
    mgr.unlock(p1)
    p2 = mgr.alloc(900)          # best-fit reuse of the cached block
    assert mgr.stats.n_device_allocs == 1
    assert p2 == p1


def test_split_threshold_reduces_internal_fragmentation():
    """§5.2.2: restricting splits of large blocks vs naive handout.

    Trace: free one huge block, then many small allocs.  Without
    splitting, the first small alloc consumes the huge block whole
    (internal fragmentation); with splitting allowed the remainder stays
    usable."""
    def run(split):
        mgr = CachingMemoryManager(capacity=1 << 26,
                                   split_large_blocks=split)
        big = mgr.alloc(1 << 20)
        mgr.unlock(big)
        ptrs = [mgr.alloc(4096) for _ in range(64)]
        frag = mgr.stats.internal_fragmentation
        for p in ptrs:
            mgr.unlock(p)
        return frag

    frag_no_split = run(False)
    frag_split = run(True)
    assert frag_split < frag_no_split
    # the paper's §5.2.2 claim is a >20% *reduction* in fragmentation
    assert (frag_no_split - frag_split) / frag_no_split > 0.2


def test_bump_manager_oom():
    mgr = BumpMemoryManager(capacity=1024)
    mgr.alloc(1000)
    with pytest.raises(OutOfMemory):
        mgr.alloc(1000)


def test_trace_record_replay_roundtrip(tmp_path):
    trace = telemetry.start_recording()
    telemetry.record_alloc(1, 4096, tag="matmul")
    telemetry.record_alloc(2, 1024, tag="add")
    telemetry.record_free(1)
    telemetry.record_free(2)
    t = telemetry.stop_recording()
    path = tmp_path / "trace.json"
    t.save(str(path))
    t2 = telemetry.AllocTrace.load(str(path))
    assert len(t2) == 4
    mgr = CachingMemoryManager(capacity=1 << 20)
    t2.replay(mgr)
    assert mgr.stats.n_allocs == 2
    assert mgr.stats.live_allocated == 0
