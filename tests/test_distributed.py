"""DistributedInterface backends, gradient compression w/ error feedback,
pipeline parallelism, serving engine."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.distributed import (EmulatedBackend, GradientSynchronizer,
                                    GradSyncConfig, dequantize_int8,
                                    quantize_int8)


def test_emulated_backend_semantics():
    d = EmulatedBackend()
    x = jnp.arange(4.0)
    np.testing.assert_allclose(np.asarray(d.allReduce(x)), np.asarray(x))
    w = d.allReduce(x, async_op=True)
    np.testing.assert_allclose(np.asarray(w.wait()), np.asarray(x))
    assert d.getWorldSize() == 1


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100), scale=st.floats(0.01, 100.0))
def test_int8_quantization_error_bound(seed, scale):
    """Property: |x - deq(q(x))| <= scale_step/2 elementwise."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(x - deq))) <= float(s) / 2 + 1e-6


def test_error_feedback_preserves_signal():
    """With error feedback, the *accumulated* compressed gradient tracks
    the true accumulated gradient (bias-free compression)."""
    sync = GradientSynchronizer(
        EmulatedBackend(),
        GradSyncConfig(compress="int8", error_feedback=True))
    g = {"w": jnp.asarray([1e-3, 2e-3, -5e-4, 1.0])}  # tiny + large entries
    state = sync.init_state(g)
    acc = np.zeros(4)
    for _ in range(64):
        out, state = sync(g, state, scale=1.0)
        acc += np.asarray(out["w"])
    true_acc = 64 * np.asarray(g["w"])
    np.testing.assert_allclose(acc, true_acc, rtol=0.05, atol=1e-3)


def test_no_compression_is_identity_on_loopback():
    sync = GradientSynchronizer(EmulatedBackend(), GradSyncConfig())
    g = {"a": jnp.arange(3.0), "b": jnp.ones((2, 2))}
    out, _ = sync(g, None, scale=1.0)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y)), g, out)


from conftest import REPO_ROOT as _REPO_ROOT, subproc_env as _subproc_env

_SUBPROC_COLLECTIVES = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import ShardMapBackend

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    d = ShardMapBackend("data")
    x = jnp.arange(8.0)

    def body(xs):
        local = xs
        return (d.allReduce(local, scale=1.0/8),
                d.allGather(local),
                d.reduceScatter(d.allGather(local)))

    from repro.core.compat import shard_map
    out = shard_map(body, mesh=mesh, in_specs=P("data"),
                    out_specs=(P("data"), P(("data",), None) if False
                               else P("data"), P("data")),
                    check_vma=False)(x)
    ar, ag, rs = out
    res = {
      "ar": np.asarray(ar).tolist(),
      "rs": np.asarray(rs).tolist(),
    }
    print(json.dumps(res))
""")


def test_shard_map_backend_collectives_8dev():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_COLLECTIVES],
                       capture_output=True, text=True,
                       env=_subproc_env(), timeout=300,
                       cwd=_REPO_ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    # allReduce(mean): every element = mean(0..7) = 3.5
    np.testing.assert_allclose(res["ar"], [3.5] * 8)
    # reduceScatter(allGather(x)) = 8 * x
    np.testing.assert_allclose(res["rs"], (8 * np.arange(8.0)).tolist())


_SUBPROC_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.training.pipeline import pipeline_apply

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), n_stages)
    Ws = jnp.stack([jax.random.normal(k, (d, d)) * 0.3 for k in ks])

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    out = pipeline_apply(mesh, stage_fn, Ws, x, axis="stage")
    # sequential reference
    ref = x
    for i in range(n_stages):
        ref = jnp.tanh(ref @ Ws[i])
    err = float(jnp.max(jnp.abs(out - ref)))
    print(json.dumps({"err": err}))
""")


def test_pipeline_parallel_equals_sequential_4dev():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_PIPELINE],
                       capture_output=True, text=True,
                       env=_subproc_env(), timeout=300,
                       cwd=_REPO_ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    err = json.loads(r.stdout.strip().splitlines()[-1])["err"]
    assert err < 1e-5, err


def test_bubble_fraction():
    from repro.training.pipeline import bubble_fraction

    assert bubble_fraction(4, 8) == pytest.approx(3 / 11)
    assert bubble_fraction(1, 8) == 0.0


def test_serve_engine_greedy_matches_manual_decode():
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_config("mamba2-370m", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [5, 9, 2]
    engine = ServeEngine(model, params, batch_slots=2, max_seq=32)
    engine.submit(Request(uid=1, prompt=prompt, max_new_tokens=6))
    done = engine.run_until_done()
    assert len(done) == 1 and len(done[0].generated) == 6

    # manual greedy decode (batch of 1 padded to the same slot count)
    cache = model.init_cache(2, 32)
    toks = prompt[:]
    for i, t in enumerate(prompt[:-1]):
        arr = jnp.zeros((2, 1), jnp.int32).at[0, 0].set(t)
        _, cache = model.decode_step(params, cache, arr, jnp.int32(i))
    cur = prompt[-1]
    out = []
    for i in range(6):
        arr = jnp.zeros((2, 1), jnp.int32).at[0, 0].set(cur)
        logits, cache = model.decode_step(params, cache, arr,
                                          jnp.int32(len(prompt) - 1 + i))
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
    assert out == done[0].generated


def test_serve_engine_multi_request_batching():
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.serving.engine import Request, ServeEngine

    cfg = get_config("codeqwen1.5-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=2, max_seq=24)
    for uid in range(4):                      # more requests than slots
        engine.submit(Request(uid=uid, prompt=[1 + uid, 2, 3],
                              max_new_tokens=4))
    done = engine.run_until_done()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]
    assert all(len(r.generated) == 4 for r in done)
