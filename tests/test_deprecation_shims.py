"""The pre-Session entry points must warn and delegate, not fork state.

``core/tensor/dispatch.py`` (``set_backend`` / ``use_backend``) and
``sharding/context.py`` (``active_mesh``) survive as deprecated shims over
the unified Session stack.  These tests pin both halves of that contract:
each shim emits DeprecationWarning, and its effect is visible through
``repro.current_session()`` — the shims ride the same stack, they do not
keep a parallel thread-local alive.
"""

import warnings

import jax
import numpy as np
import pytest

import repro
from repro.core.tensor.dispatch import (current_backend, get_backend,
                                        set_backend, use_backend)
from repro.runtime import stack as _rt
from repro.sharding.context import active_mesh, get_active_mesh


def test_use_backend_warns_and_rides_session_stack():
    depth = len(_rt._STACK.stack)
    before = repro.current_session()
    with pytest.warns(DeprecationWarning, match="use_backend"):
        with use_backend("jnp") as b:
            assert b is get_backend("jnp")
            assert current_backend() is b
            assert repro.current_session().backend_instance() is b
            assert len(_rt._STACK.stack) == depth + 1
    assert repro.current_session() is before
    assert len(_rt._STACK.stack) == depth


def test_set_backend_warns_and_mutates_current_scope():
    with repro.session():                      # scope to contain the edit
        with pytest.warns(DeprecationWarning, match="set_backend"):
            set_backend("lazy")
        assert repro.current_session().backend == "lazy"
        assert current_backend() is get_backend("lazy")
    assert repro.current_session().backend != "lazy"


def test_active_mesh_warns_and_installs_session_mesh():
    devs = np.array(jax.devices()[:1])
    mesh = jax.sharding.Mesh(devs, ("data",))
    assert get_active_mesh() is None
    with pytest.warns(DeprecationWarning, match="active_mesh"):
        with active_mesh(mesh, batch_axes=("data",)) as m:
            assert m is mesh
            sess = repro.current_session()
            assert sess.mesh is mesh
            assert sess.batch_axes == ("data",)
            assert get_active_mesh() is mesh
    assert get_active_mesh() is None


def test_shims_compose_with_modern_sessions():
    """A deprecated shim nested inside repro.session() must pop cleanly
    and leave the outer session's fields intact."""
    with repro.session(tag="outer") as outer:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            with use_backend("jnp"):
                assert repro.current_session().tag == "outer"
        assert repro.current_session() is outer
