"""Loop-aware HLO analyzer: trip-count multiplication, dot FLOPs,
collective byte accounting on a synthetic module."""

from repro.launch.hlo_analysis import analyze_hlo

SYNTH = """
HloModule synth

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%sum (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %w2 = f32[16,32]{1,0} constant({...})
  %d0 = f32[8,32]{1,0} dot(%arg, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %arg)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_trip_count_multiplies_body_costs():
    r = analyze_hlo(SYNTH)
    # entry dot: 2*8*32*16 = 8192 ; body dot: 2*8*16*16 = 4096, x10 = 40960
    assert r["dot_flops"] == 8192 + 10 * 4096
    # body all-reduce: 8*16*4 bytes * 2 (ring) * 10 trips
    assert r["collective_bytes"]["all-reduce"] == 8 * 16 * 4 * 2 * 10
    assert r["collective_counts"]["all-reduce"] == 10
    assert r["n_loops"] >= 1   # counts looped call edges (cond + body)


def test_no_collectives_counts_zero():
    r = analyze_hlo("""
HloModule t
ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  ROOT %d = f32[4,4]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
""")
    assert r["dot_flops"] == 2 * 4 * 4 * 4
    assert r["collective_total_bytes"] == 0
