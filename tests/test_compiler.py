"""repro.compiler: graph IR capture, pass pipeline, Pallas cluster
lowering, repro.compile numerics (hypothesis), telemetry exactly-once
frees after CSE, and Session provenance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.compiler import (CompilerPolicy, Graph, PassManager, compile_graph,
                            trace)
from repro.core.memory import CachingMemoryManager, telemetry
from repro.core.tensor import ops
from repro.core.tensor.lazy_backend import LazyBackend


def _fresh_lazy():
    return LazyBackend()


# --------------------------------------------------------------------------
# IR capture
# --------------------------------------------------------------------------


def test_trace_captures_pending_subgraph():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        x = lb._lift(jnp.ones((8, 8)))
        y = ops.tanh(ops.add(ops.mul(x, x), x))
        g, sources = trace([y])
    assert g.validate() == []
    assert len(g.inputs) == 1 and len(g.outputs) == 1
    opset = {g.nodes[u].op for u in g.order}
    assert {"input", "mul", "add", "tanh"} <= opset
    text = g.dump()
    assert "graph(" in text and "tanh" in text and "return" in text
    # round-trip: the IR interpreter reproduces eager numerics
    (out,) = g.eval()
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.tanh(jnp.ones((8, 8)) * 2)))


def test_cse_merges_duplicate_subexpressions_and_aliases():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        x = lb._lift(jnp.linspace(0.1, 1.0, 16).reshape(4, 4))
        a1 = ops.exp(ops.mul(x, x))
        a2 = ops.exp(ops.mul(x, x))       # identical subexpression
        out = ops.add(a1, a2)
        g, _ = trace([out])
        n0 = len(g.order)
        report = PassManager.from_policy(CompilerPolicy()).run(g)
    by_name = {s.name: s for s in report}
    assert by_name["cse"].extra["merged"] == 2          # mul and exp dups
    assert len(g.order) == n0 - 2
    assert g.validate() == []
    # aliased outputs still resolve to surviving nodes
    assert all(g.resolve(o) in g.nodes for o in g.outputs)


def test_dce_removes_dead_branch_but_keeps_inputs():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        x = lb._lift(jnp.ones((4, 4)))
        live = ops.tanh(x)
        dead = ops.exp(ops.mul(x, ops.full_like(x, 2.0)))
        g, _ = trace([live, dead])
    g.outputs = g.outputs[:1]             # drop the dead branch
    stats = PassManager.from_policy(
        CompilerPolicy(pipeline=("dce",))).run(g)
    assert stats[0].extra["removed"] >= 2
    assert g.validate() == []
    assert all(i in g.nodes for i in g.inputs)


# --------------------------------------------------------------------------
# acceptance: the 16-op chain collapses to <= 2 cluster kernels
# --------------------------------------------------------------------------


def _chain(x, n=16):
    for _ in range(n):
        x = ops.mul(ops.add(x, x), ops.full_like(x, 0.5))
        x = ops.tanh(x)
    return x


def test_chain16_collapses_to_two_clusters_numerics_exact():
    x = jnp.linspace(-1.0, 1.0, 256 * 256).reshape(256, 256)
    eager = _chain(x)
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        out = ops.materialize(_chain(x))
        report = lb.last_compile_report
    # legacy lazy path = one dispatch per op (64 compute nodes)
    legacy_lb = _fresh_lazy()
    with repro.session(backend=legacy_lb, compiler=CompilerPolicy.legacy()):
        out_legacy = ops.materialize(_chain(x))
        legacy_dispatches = legacy_lb.last_compile_report["dispatches"]
    assert report["dispatches"] <= 2 < legacy_dispatches
    assert 1 <= report["pallas_kernels"] <= 2
    assert legacy_dispatches >= 48
    np.testing.assert_array_equal(np.asarray(out), np.asarray(eager))
    np.testing.assert_array_equal(np.asarray(out_legacy), np.asarray(eager))


def test_program_cache_hits_on_identical_structure():
    x = jnp.ones((64, 64))
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        ops.materialize(_chain(x, 4))
        assert lb.program_cache_hits == 0
        ops.materialize(_chain(x, 4))
        assert lb.program_cache_hits == 1


def test_cluster_internal_intermediates_recompute_on_demand():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        x = lb._lift(jnp.full((8, 8), 0.5))
        mid = ops.add(x, x)               # fused into the cluster interior
        out = ops.tanh(ops.mul(mid, mid))
        ops.materialize(out)
        assert out.value is not None
        np.testing.assert_allclose(np.asarray(ops.materialize(mid)),
                                   np.ones((8, 8)), rtol=1e-6)


# --------------------------------------------------------------------------
# satellite: telemetry frees exactly once per surviving node after CSE
# --------------------------------------------------------------------------


def test_telemetry_free_exactly_once_per_surviving_node_after_cse():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        t = telemetry.start_recording()
        x = lb._lift(jnp.ones((32, 32)))
        # two copies of the same consumer chain: CSE merges them, so the
        # shared producer's free must be emitted once, not per consumer
        a1 = ops.exp(ops.mul(x, x))
        a2 = ops.exp(ops.mul(x, x))
        out = ops.add(ops.tanh(a1), ops.tanh(a2))
        ops.materialize(out)
        trace_rec = telemetry.stop_recording()
    allocs = [e.uid for e in trace_rec.events if e.kind == "alloc"]
    frees = [e.uid for e in trace_rec.events if e.kind == "free"]
    assert len(allocs) == len(set(allocs)), "duplicate alloc uids"
    assert len(frees) == len(set(frees)), \
        "free emitted more than once for a node"
    assert set(frees) <= set(allocs)
    # CSE merged mul+exp+tanh dups: 7 logical -> 4 surviving compute nodes
    assert len(allocs) == 4
    assert len(frees) == 3                # all interior; root not freed
    # replay against the memory-manager interface: event counts must agree
    mgr = CachingMemoryManager(capacity=1 << 24)
    trace_rec.replay(mgr)
    assert mgr.stats.n_allocs == len(allocs)
    assert mgr.stats.live_allocated == 0


def test_telemetry_unchanged_semantics_without_cse_opportunities():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        t = telemetry.start_recording()
        a = ops.full((32, 32), 1.0)
        b = ops.exp(ops.mul(a, a))
        ops.materialize(b)
        rec = telemetry.stop_recording()
    allocs = [e for e in rec.events if e.kind == "alloc"]
    assert len(allocs) == 3
    assert {e.tag for e in allocs} == {"full", "mul", "exp"}


# --------------------------------------------------------------------------
# repro.compile decorator
# --------------------------------------------------------------------------


def test_compile_decorator_matches_eager_and_caches():
    @repro.compile
    def f(a, b):
        h = ops.mul(ops.add(a, b), ops.full_like(a, 0.25))
        return ops.sum(ops.tanh(h), axis=-1, keepdims=False)

    a = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    b = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    want = jnp.sum(jnp.tanh((a + b) * 0.25), axis=-1)
    got = f(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert f.trace_count == 1
    f(b, a)                                # same signature: cache hit
    assert f.trace_count == 1 and f.cache_size == 1
    f(a[:4], b[:4])                        # new shapes: retrace
    assert f.trace_count == 2 and f.cache_size == 2


def test_compile_policy_override_and_pytree_outputs():
    policy = CompilerPolicy.legacy()

    @repro.compile(policy=policy)
    def f(x):
        y = ops.neg(x)
        return {"pos": x, "neg": y, "both": (ops.add(x, y),)}

    x = jnp.arange(8.0)
    out = f(x)
    np.testing.assert_array_equal(np.asarray(out["neg"]), -np.arange(8.0))
    np.testing.assert_array_equal(np.asarray(out["both"][0]), np.zeros(8))
    assert f.last_executable.n_kernels == 0   # legacy: nothing generated


# --------------------------------------------------------------------------
# hypothesis: random graphs match eager bit-for-bit (f32) / tol (bf16)
# --------------------------------------------------------------------------

_UNARY = ["tanh", "neg", "abs", "sin", "cos"]
_BINARY = ["add", "sub", "mul", "maximum", "minimum"]
_SHAPE = (4, 8)


def _run_program(program, x, contraction_safe=True):
    """Interpret a random program over a value pool; later steps may
    reuse any earlier value (shared subexprs) and only the final value is
    returned (everything else is a dead branch).

    ``contraction_safe`` keeps the program free of ``mul``-feeds-
    ``add/sub`` patterns (tracked through ``neg``): inside a fused
    computation XLA's CPU/TPU backends legally contract those into FMAs,
    which changes the last ulp vs op-at-a-time eager execution.  Bitwise
    equality is only a meaningful guarantee for contraction-free graphs;
    the unrestricted family is covered by the 2-ulp test below.
    """
    pool = [x]
    from_mul = [False]
    for kind, i, j in program:
        ia, ib = i % len(pool), j % len(pool)
        a, b = pool[ia], pool[ib]
        m = False
        if kind < len(_UNARY):
            name = _UNARY[kind]
            v = getattr(ops, name)(a)
            m = name == "neg" and from_mul[ia]
        elif kind < len(_UNARY) + len(_BINARY):
            name = _BINARY[kind - len(_UNARY)]
            if (contraction_safe and name in ("add", "sub")
                    and (from_mul[ia] or from_mul[ib])):
                name = "maximum"
            v = getattr(ops, name)(a, b)
            m = name == "mul"
        elif kind == len(_UNARY) + len(_BINARY):
            r = ops.sum(a, axis=-1, keepdims=True)
            v = ops.broadcast_to(r, _SHAPE)
        else:
            v = ops.where(ops.ge(a, b), a, b)
        pool.append(v)
        from_mul.append(m)
    return pool[-1]


@settings(max_examples=25, deadline=None)
@given(program=st.lists(
    st.tuples(st.integers(0, len(_UNARY) + len(_BINARY) + 1),
              st.integers(0, 11), st.integers(0, 11)),
    min_size=1, max_size=12),
    seed=st.integers(0, 100))
def test_compiled_random_graphs_match_eager_f32_bitwise(program, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), _SHAPE, jnp.float32)
    eager = _run_program(program, x)
    compiled = repro.compile(lambda v: _run_program(program, v))
    got = compiled(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(eager))


@settings(max_examples=15, deadline=None)
@given(program=st.lists(
    st.tuples(st.integers(0, len(_UNARY) + len(_BINARY) + 1),
              st.integers(0, 11), st.integers(0, 11)),
    min_size=1, max_size=12),
    seed=st.integers(0, 100))
def test_compiled_unrestricted_graphs_within_two_ulp_f32(program, seed):
    """Unrestricted graphs: fused FMA contraction may flip the last ulp,
    never more (relative bound ~2 ulps of f32)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), _SHAPE, jnp.float32)
    eager = np.asarray(_run_program(program, x, contraction_safe=False),
                       np.float64)
    compiled = repro.compile(
        lambda v: _run_program(program, v, contraction_safe=False))
    got = np.asarray(compiled(x), np.float64)
    np.testing.assert_allclose(got, eager, rtol=2.4e-7, atol=1e-37)


@settings(max_examples=10, deadline=None)
@given(program=st.lists(
    st.tuples(st.integers(0, len(_UNARY) + len(_BINARY) + 1),
              st.integers(0, 11), st.integers(0, 11)),
    min_size=1, max_size=8),
    seed=st.integers(0, 100))
def test_compiled_random_graphs_match_eager_bf16_tolerance(program, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), _SHAPE,
                          jnp.float32).astype(jnp.bfloat16)
    eager = np.asarray(_run_program(program, x), np.float32)
    compiled = repro.compile(lambda v: _run_program(program, v))
    got = np.asarray(compiled(x), np.float32)
    np.testing.assert_allclose(got, eager, rtol=2e-2, atol=1e-2)


# --------------------------------------------------------------------------
# policy plumbing + provenance
# --------------------------------------------------------------------------


def test_session_selects_pipeline_and_describe_records_stats():
    lb = _fresh_lazy()
    policy = CompilerPolicy(pipeline=("cse", "fuse"), lowering="jit")
    with repro.session(backend=lb, compiler=policy) as s:
        ops.materialize(_chain(jnp.ones((16, 16)), 4))
        desc = s.describe()
    comp = desc["compiler"]
    assert comp["pipeline"] == ["cse", "fuse"]
    assert comp["lowering"] == "jit"
    run = comp["last_run"]
    assert [p["pass"] for p in run["passes"]] == ["cse", "fuse"]
    assert run["pallas_kernels"] == 0      # jit lowering generates none
    assert all("nodes" in p and "edges" in p for p in run["passes"])
    import json
    json.dumps(desc)                       # provenance stays serializable


def test_session_compiler_dict_override():
    with repro.session(compiler={"pipeline": ("dce",), "lowering": "eager"}) \
            as s:
        assert s.compiler.pipeline == ("dce",)
        assert s.compiler.lowering == "eager"


def test_materialize_many_compiles_jointly():
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        x = lb._lift(jnp.ones((8, 8)))
        shared = ops.tanh(ops.add(x, x))
        o1 = ops.mul(shared, shared)
        o2 = ops.add(shared, x)
        before = lb.materialize_calls
        v1, v2 = ops.materialize((o1, o2))
        assert lb.materialize_calls == before + 1
    np.testing.assert_allclose(np.asarray(v1),
                               np.tanh(2.0) ** 2 * np.ones((8, 8)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2),
                               (np.tanh(2.0) + 1.0) * np.ones((8, 8)),
                               rtol=1e-6)


def test_materialize_namedtuple_preserves_type():
    import collections

    Out = collections.namedtuple("Out", ["a", "b"])
    lb = _fresh_lazy()
    with repro.session(backend=lb):
        x = lb._lift(jnp.ones((4, 4)))
        out = ops.materialize(Out(a=ops.tanh(x), b=ops.neg(x)))
    assert isinstance(out, Out)
    np.testing.assert_allclose(np.asarray(out.a), np.tanh(1.0) * np.ones((4, 4)),
                               rtol=1e-6)


def test_compile_mid_trace_materialized_values_not_cached():
    """Values computed eagerly during the trace (top_k materializes) are
    arg-dependent — replaying them from the cache would pin first-call
    results, so such calls must re-trace every time."""

    @repro.compile
    def f(x):
        v, _ = ops.top_k(x, 2)
        return ops.add(v, v)

    a = jnp.asarray([[1.0, 5.0, 3.0]])
    b = jnp.asarray([[9.0, 2.0, 7.0]])
    np.testing.assert_array_equal(np.asarray(f(a)), [[10.0, 6.0]])
    np.testing.assert_array_equal(np.asarray(f(b)), [[18.0, 14.0]])
    assert f.cache_size == 0 and f.trace_count == 2


def test_compile_array_kwarg_raises_clear_error():
    @repro.compile
    def f(x, scale=None):
        return ops.mul(x, scale)

    with pytest.raises(TypeError, match="positional"):
        f(jnp.ones((2, 2)), scale=jnp.full((2, 2), 3.0))


def test_describe_does_not_leak_other_sessions_pass_stats():
    # both sessions resolve "lazy" to the same registry singleton; B must
    # not report A's legacy-pipeline run as its own provenance
    with repro.session(backend="lazy", compiler=CompilerPolicy.legacy()):
        ops.materialize(_chain(jnp.ones((8, 8)), 2))
    with repro.session(backend="lazy") as s:
        s.backend_instance()               # resolve without materializing
        assert "last_run" not in s.describe()["compiler"]
        ops.materialize(_chain(jnp.ones((8, 8)), 2))
        assert "last_run" in s.describe()["compiler"]


def test_invalid_pass_name_raises():
    with pytest.raises(KeyError):
        PassManager.from_policy(CompilerPolicy(pipeline=("nope",)))


def test_selfcheck_default_pipeline_clean():
    from repro.compiler import selfcheck

    problems = selfcheck.run_corpus(
        pipelines=(("cse", "fold", "dce", "fuse"),))
    assert problems == []
