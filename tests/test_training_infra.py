"""Checkpointing (incl. elastic restore), fault tolerance, optimizers,
data pipeline, train loop."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import optim
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import (HeartbeatTracker,
                                            StragglerMonitor,
                                            run_with_retries)


# ---------------------------------------------------------------- checkpoint

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (8, 4)),
                      "b": jnp.zeros((4,))},
            "stacked": [jnp.arange(6.0), jnp.ones((2, 3), jnp.bfloat16)]}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(7, tree, extra={"step": 7, "note": "hello"})
    restored, extra = mgr.restore(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert extra["note"] == "hello"
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree,
        restored)


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((2,), float(s))})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_shape_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(1, {"x": jnp.ones((4,))})
    mgr.wait()
    with pytest.raises(ValueError, match="shape"):
        mgr.restore({"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save unsharded, restore with explicit shardings (mesh 'resize')."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = mgr.restore(
        {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))


# ---------------------------------------------------------- fault tolerance

def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=32, threshold=3.0)
    for i in range(40):
        assert not mon.record(i, 0.1 + 0.001 * (i % 3))
    assert mon.record(40, 1.5)          # 15x median
    assert mon.flagged and mon.flagged[-1][0] == 40


def test_straggler_monitor_degradation_triggers_checkpoint():
    mon = StragglerMonitor(degrade_patience=4)
    for i in range(20):
        mon.record(i, 0.1)
    for i in range(20, 24):
        mon.record(i, 2.0)
    assert mon.should_checkpoint_now()


def test_heartbeat_tracker():
    hb = HeartbeatTracker(world_size=4, timeout=10.0)
    now = 1000.0
    for r in range(4):
        hb.beat(r, now)
    assert hb.dead_ranks(now + 5) == []
    hb.beat(0, now + 20)
    assert hb.dead_ranks(now + 20) == [1, 2, 3]


def test_run_with_retries_recovers_from_injected_failures(tmp_path):
    """Supervisor restores from checkpoint after crashes; progress is
    monotone and final state correct."""
    mgr = CheckpointManager(tmp_path)
    crash_at = {17, 33}

    def save_fn(step, state):
        mgr.save(step, {"x": state}, extra={"step": step})

    def restore_fn():
        like = {"x": jax.ShapeDtypeStruct((), jnp.float32)}
        state, extra = mgr.restore(like)
        return int(extra["step"]), state["x"]

    def step_fn(step, state):
        if step in crash_at:
            crash_at.discard(step)      # fail once per site
            raise RuntimeError(f"injected failure @ {step}")
        return state + 1.0

    save_fn(0, jnp.float32(0.0))
    state, report = run_with_retries(step_fn, jnp.float32(0.0), 50,
                                     save_fn=save_fn,
                                     restore_fn=restore_fn,
                                     checkpoint_every=10)
    assert report["recovered"] == 2
    assert float(state) == 50.0         # every step ran exactly once


# ------------------------------------------------------------------ optim

def test_adam_matches_reference_formula():
    p = jnp.asarray([1.0, -2.0, 3.0])
    g = jnp.asarray([0.1, 0.2, -0.3])
    opt = optim.Adam(lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    s = opt._init_leaf(p)
    new_p, s = opt._update_leaf(p, g, s, 0.1, 1)
    m = 0.1 * np.asarray(g)
    v = 0.001 * np.asarray(g) ** 2
    mh, vh = m / 0.1, v / 0.001
    expect = np.asarray(p) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p), expect, rtol=1e-6)


def test_adamw_decoupled_decay():
    p = jnp.ones((3,))
    g = jnp.zeros((3,))
    opt = optim.AdamW(lr=0.1, weight_decay=0.5)
    s = opt._init_leaf(p)
    new_p, _ = opt._update_leaf(p, g, s, 0.1, 1)
    np.testing.assert_allclose(np.asarray(new_p), 1.0 - 0.1 * 0.5,
                               rtol=1e-6)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = optim.clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    total = np.sqrt(sum(float(jnp.sum(g ** 2))
                        for g in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_schedules():
    sched = optim.cosine_schedule(1.0, warmup=10, total=110)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(10)), 1.0, rtol=1e-5)
    assert float(sched(110)) < 0.2
    lin = optim.linear_schedule(1.0, warmup=10, total=110)
    np.testing.assert_allclose(float(lin(60)), 0.5, rtol=1e-5)


def test_adafactor_shrinks_loss():
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (8, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    opt = optim.Adafactor(lr=0.1)
    state = opt.init({"w": w})
    params = {"w": w}

    def loss(p):
        return jnp.mean((x @ p["w"]) ** 2)

    l0 = float(loss(params))
    for i in range(20):
        g = jax.grad(loss)(params)
        params, state = opt.apply_with_count(params, g, state, 0.1, i + 1)
    assert float(loss(params)) < l0 * 0.5


# -------------------------------------------------------------------- data

def test_data_pipeline_composition():
    from repro.core.data import (BatchDataset, MapDataset, PrefetchDataset,
                                 ShardDataset, ShuffleDataset, TensorDataset)

    xs = np.arange(100)
    ds = TensorDataset([xs])
    ds = MapDataset(ds, lambda s: (s[0] * 2,))
    shuf = ShuffleDataset(ds, seed=1)
    assert sorted(s[0] for s in shuf) == sorted(2 * xs)
    shard0 = ShardDataset(shuf, 0, 4)
    shard1 = ShardDataset(shuf, 1, 4)
    assert len(shard0) == len(shard1) == 25
    assert not set(s[0] for s in shard0) & set(s[0] for s in shard1)
    batched = BatchDataset(TensorDataset([xs]), 32)
    assert len(batched) == 3
    assert batched[0][0].shape == (32,)
    pre = PrefetchDataset(BatchDataset(TensorDataset([xs]), 10),
                          num_threads=3)
    got = [b[0] for b in pre]
    np.testing.assert_array_equal(np.concatenate(got), xs)


def test_lm_packing_and_tokenizer():
    from repro.core.data import ByteTokenizer, PackedLMDataset

    tok = ByteTokenizer()
    ids = tok.encode("hello")
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == "hello"
    ds = PackedLMDataset(["abcdef" * 10, "xyz" * 20], seq_len=16)
    t, l = ds[0]
    assert t.shape == (16,) and l.shape == (16,)
    np.testing.assert_array_equal(t[1:], l[:-1])  # next-token labels


def test_grad_accumulation_equivalence():
    """accum=2 over a 2x batch == accum=1 small-batch average behavior."""
    from repro.configs.base import get_config
    from repro.core.optim import SGD
    from repro.models import build_model
    from repro.training.train_loop import TrainConfig, make_step_fn

    cfg = get_config("mamba2-370m", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SGD(lr=0.1)
    tok = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

    outs = {}
    for accum in (1, 2):
        tcfg = TrainConfig(steps=5, base_lr=0.1, warmup=0, accum=accum,
                           grad_clip=1e9)
        step = jax.jit(make_step_fn(model, opt, tcfg))
        p, s = params, opt.init(params)
        p, s, m = step(p, s, jnp.int32(1), batch)
        outs[accum] = p
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        outs[1], outs[2])
