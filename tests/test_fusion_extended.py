"""Extended fusion: reduction clusters, matmul-epilogue folding, and the
attention pattern matcher — numerics vs eager/oracles, kernel counts, and
cluster-kind provenance in dump()/describe()."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.compiler import CompilerPolicy, PassManager, trace
from repro.core.tensor import ops
from repro.core.tensor.lazy_backend import LazyBackend
from repro.kernels import ref


def _kinds(exe):
    return [c["kind"] for c in exe.describe()["clusters"]]


# --------------------------------------------------------------------------
# reduction fusion: trailing reductions + epilogues join the cluster
# --------------------------------------------------------------------------


def test_softmax_denominator_chain_fuses_to_one_reduction_kernel():
    @repro.compile
    def f(x):
        e = ops.exp(ops.sub(x, ops.stop_gradient(
            ops.max(x, axis=-1, keepdims=True))))
        return ops.div(e, ops.sum(e, axis=-1, keepdims=True))

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16), jnp.float32)
    got = f(x)
    exe = f.last_executable
    assert exe.n_dispatches == 1 and exe.n_kernels == 1
    assert _kinds(exe) == ["reduction"]
    want = jax.nn.softmax(x, axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_mean_chain_fuses_and_matches_eager_bitwise():
    # sum -> scale -> sub: a mean-centering chain, reduction mid-cluster
    @repro.compile
    def f(x):
        s = ops.sum(x, axis=-1, keepdims=True)
        mean = ops.mul(s, ops.full_like(s, 1.0 / 16.0))
        return ops.sub(x, ops.broadcast_to(mean, (8, 16)))

    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16), jnp.float32)
    got = f(x)
    exe = f.last_executable
    assert exe.n_dispatches == 1 and exe.n_kernels == 1
    assert _kinds(exe) == ["reduction"]
    s = jnp.sum(x, axis=-1, keepdims=True)
    want = x - jnp.broadcast_to(s * jnp.full_like(s, 1.0 / 16.0), (8, 16))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


_RED_SHAPE = (4, 8)
# bitwise family: ops whose fusion into a reduction XLA compiles without
# changing the last ulp.  Chains of two+ trig ops feeding a reduction
# legally diverge by 1 ulp under ANY compiled execution (even plain
# jax.jit) — they belong to the 2-ulp family below, with mul-feeds-add.
_UNARY_SAFE = ["tanh", "neg", "abs"]
_UNARY_ALL = ["tanh", "neg", "abs", "sin", "cos"]
_RED = [("sum", -1, True), ("sum", -1, False), ("sum", None, False),
        ("max", -1, True), ("min", -1, True)]


def _reduction_program(prefix, red, suffix, x, contraction_safe=True):
    """Elementwise prefix -> one reduction -> elementwise epilogue.

    ``contraction_safe`` keeps the graph in the bitwise family: safe
    unaries only and ``maximum`` instead of ``add`` (no FMA contraction).
    """
    unary = _UNARY_SAFE if contraction_safe else _UNARY_ALL
    pool = [x]
    for kind, j in prefix:
        a = pool[j % len(pool)]
        if kind < len(unary):
            v = getattr(ops, unary[kind % len(unary)])(a)
        else:
            b = pool[(kind - len(unary)) % len(pool)]
            v = ops.maximum(a, b) if contraction_safe else ops.add(a, b)
        pool.append(v)
    op, axis, keepdims = _RED[red % len(_RED)]
    r = getattr(ops, op)(pool[-1], axis=axis, keepdims=keepdims)
    for kind in suffix:
        r = getattr(ops, unary[kind % len(unary)])(r)
    return r


@settings(max_examples=20, deadline=None)
@given(prefix=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 9)),
                       min_size=1, max_size=6),
       red=st.integers(0, 10),
       suffix=st.lists(st.integers(0, 9), min_size=0, max_size=3),
       seed=st.integers(0, 100))
def test_reduction_tailed_graphs_match_eager_f32_bitwise(prefix, red,
                                                         suffix, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), _RED_SHAPE, jnp.float32)
    eager = _reduction_program(prefix, red, suffix, x)
    compiled = repro.compile(
        lambda v: _reduction_program(prefix, red, suffix, v))
    got = compiled(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(eager))
    assert compiled.last_executable.n_kernels >= 1
    assert "reduction" in _kinds(compiled.last_executable)


@settings(max_examples=10, deadline=None)
@given(prefix=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 9)),
                       min_size=1, max_size=6),
       red=st.integers(0, 10),
       suffix=st.lists(st.integers(0, 9), min_size=0, max_size=3),
       seed=st.integers(0, 100))
def test_reduction_tailed_unrestricted_within_two_ulp(prefix, red, suffix,
                                                      seed):
    """With mul-feeds-add allowed, fused FMA contraction may flip the
    last ulp — never more."""
    x = jax.random.normal(jax.random.PRNGKey(seed), _RED_SHAPE, jnp.float32)
    eager = np.asarray(
        _reduction_program(prefix, red, suffix, x, contraction_safe=False),
        np.float64)
    compiled = repro.compile(lambda v: _reduction_program(
        prefix, red, suffix, v, contraction_safe=False))
    got = np.asarray(compiled(x), np.float64)
    np.testing.assert_allclose(got, eager, rtol=2.4e-7, atol=1e-37)


# --------------------------------------------------------------------------
# attention matcher: softmax/sigmoid QK^TV variants -> one template kernel
# --------------------------------------------------------------------------

# dot_general inside the (interpreted) template legally differs from
# eager matmul by ~1 ulp per contraction step; scores then pass through
# exp, so equality is tolerance-based, not bitwise.
_ATTN_RTOL, _ATTN_ATOL = 3e-6, 2e-6


def _attn_program(q, k, v, *, mode, shifted, scale, bias=None):
    s = ops.matmul(q, ops.transpose(k, tuple(range(q.ndim - 2))
                                    + (q.ndim - 1, q.ndim - 2)))
    if scale != 1.0:
        s = ops.mul(s, ops.full_like(s, scale))
    if bias is not None:
        s = ops.add(s, bias)
    if mode == "sigmoid":
        ones = ops.full_like(s, 1.0)
        p = ops.div(ones, ops.add(ones, ops.exp(ops.neg(s))))
    else:
        if shifted:
            m = ops.max(s, axis=-1, keepdims=True)
            s = ops.sub(s, ops.stop_gradient(m))
        e = ops.exp(s)
        p = ops.div(e, ops.sum(e, axis=-1, keepdims=True))
    return ops.matmul(p, v)


@settings(max_examples=20, deadline=None)
@given(mode=st.sampled_from(["softmax", "sigmoid"]),
       shifted=st.booleans(),
       scale=st.sampled_from([1.0, 0.125, 0.5]),
       batched=st.booleans(),
       sq=st.sampled_from([8, 16]),
       sk=st.sampled_from([8, 32]),
       d=st.sampled_from([4, 8]),
       seed=st.integers(0, 50))
def test_attention_shaped_graphs_lower_to_one_template_kernel(
        mode, shifted, scale, batched, sq, sk, d, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    lead = (2,) if batched else ()
    q = jax.random.normal(keys[0], lead + (sq, d), jnp.float32)
    k = jax.random.normal(keys[1], lead + (sk, d), jnp.float32)
    v = jax.random.normal(keys[2], lead + (sk, d), jnp.float32)
    compiled = repro.compile(lambda a, b, c: _attn_program(
        a, b, c, mode=mode, shifted=shifted, scale=scale))
    got = compiled(q, k, v)
    exe = compiled.last_executable
    assert exe.n_dispatches == 1 and exe.n_kernels == 1
    assert _kinds(exe) == ["attention"]
    want = ref.attention_variant(q, k, v, mode=mode, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_ATTN_RTOL, atol=_ATTN_ATOL)


def test_sigmoid_attention_matches_oracle():
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (16, 8), jnp.float32)
    k = jax.random.normal(keys[1], (24, 8), jnp.float32)
    v = jax.random.normal(keys[2], (24, 8), jnp.float32)
    compiled = repro.compile(lambda a, b, c: _attn_program(
        a, b, c, mode="sigmoid", shifted=False, scale=0.3535))
    got = compiled(q, k, v)
    exe = compiled.last_executable
    assert exe.n_dispatches == 1 and _kinds(exe) == ["attention"]
    want = ref.attention_variant(q, k, v, mode="sigmoid", scale=0.3535)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_ATTN_RTOL, atol=_ATTN_ATOL)


def test_alibi_bias_attention_matches_oracle():
    # per-head additive distance penalty: bias[h, i, j] = -slope_h |i - j|
    H, S, D = 2, 16, 8
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(keys[0], (H, S, D), jnp.float32)
    k = jax.random.normal(keys[1], (H, S, D), jnp.float32)
    v = jax.random.normal(keys[2], (H, S, D), jnp.float32)
    pos = np.arange(S, dtype=np.float32)
    dist = -np.abs(pos[:, None] - pos[None, :])
    slopes = np.asarray([0.25, 0.0625], np.float32)
    alibi = jnp.asarray(slopes[:, None, None] * dist[None])
    compiled = repro.compile(lambda a, b, c, bias: _attn_program(
        a, b, c, mode="softmax", shifted=True, scale=0.3535, bias=bias))
    got = compiled(q, k, v, alibi)
    exe = compiled.last_executable
    assert exe.n_dispatches == 1 and _kinds(exe) == ["attention"]
    want = ref.attention_variant(q, k, v, scale=0.3535, bias=alibi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_ATTN_RTOL, atol=_ATTN_ATOL)


def test_additive_mask_attention_matches_oracle():
    S, D = 16, 8
    keys = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(keys[0], (S, D), jnp.float32)
    k = jax.random.normal(keys[1], (S, D), jnp.float32)
    v = jax.random.normal(keys[2], (S, D), jnp.float32)
    # additive causal-ish mask: large negative above the diagonal
    mask = jnp.asarray(np.triu(np.full((S, S), -1e9, np.float32), k=1))
    compiled = repro.compile(lambda a, b, c, m: _attn_program(
        a, b, c, mode="softmax", shifted=True, scale=1.0, bias=m))
    got = compiled(q, k, v, mask)
    exe = compiled.last_executable
    assert exe.n_dispatches == 1 and _kinds(exe) == ["attention"]
    want = ref.attention_variant(q, k, v, bias=mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_ATTN_RTOL, atol=_ATTN_ATOL)


def test_attention_jit_fallback_under_lowering_jit():
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(keys[0], (16, 8), jnp.float32)
    k = jax.random.normal(keys[1], (16, 8), jnp.float32)
    v = jax.random.normal(keys[2], (16, 8), jnp.float32)
    policy = CompilerPolicy(lowering="jit")
    compiled = repro.compile(policy=policy)(
        lambda a, b, c: _attn_program(a, b, c, mode="softmax",
                                      shifted=True, scale=0.3535))
    got = compiled(q, k, v)
    exe = compiled.last_executable
    # still one fused dispatch, but through the per-cluster jit fallback
    assert exe.n_dispatches == 1 and exe.n_kernels == 0
    steps = exe.describe()["clusters"]
    assert steps == [{"kind": "attention", "lowering": "jit",
                      "n_ops": steps[0]["n_ops"]}]
    want = ref.attention_variant(q, k, v, scale=0.3535)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=_ATTN_RTOL, atol=_ATTN_ATOL)


# --------------------------------------------------------------------------
# matmul epilogue fusion
# --------------------------------------------------------------------------


def test_matmul_bias_gelu_one_kernel_vs_three_legacy_dispatches():
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(keys[0], (32, 16), jnp.float32)
    w = jax.random.normal(keys[1], (16, 24), jnp.float32)
    b = jax.random.normal(keys[2], (24,), jnp.float32)

    def f(x, w, b):
        return ops.gelu(ops.add(ops.matmul(x, w), b))

    fused = repro.compile(f)
    got = fused(x, w, b)
    exe = fused.last_executable
    assert exe.n_dispatches == 1 and exe.n_kernels == 1
    assert _kinds(exe) == ["epilogue"]
    legacy = repro.compile(policy=CompilerPolicy.legacy())(f)
    legacy(x, w, b)
    assert legacy.last_executable.n_dispatches >= 3
    want = jax.nn.gelu(x @ w + b, approximate=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_matmul_rmsnorm_epilogue_fuses_row_reduction():
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    x = jax.random.normal(keys[0], (16, 8), jnp.float32)
    w = jax.random.normal(keys[1], (8, 32), jnp.float32)
    g = jax.random.normal(keys[2], (32,), jnp.float32)

    @repro.compile
    def f(x, w, g):
        h = ops.matmul(x, w)
        ms = ops.mul(ops.sum(ops.mul(h, h), axis=-1, keepdims=True),
                     ops.full((16, 1), 1.0 / 32.0))
        return ops.mul(ops.mul(h, ops.rsqrt(ops.add(
            ms, ops.full((16, 1), 1e-6)))), g)

    got = f(x, w, g)
    exe = f.last_executable
    assert exe.n_dispatches == 1 and exe.n_kernels == 1
    assert _kinds(exe) == ["epilogue"]
    h = x @ w
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    want = h * jax.lax.rsqrt(ms + 1e-6) * g
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_epilogue_with_interior_escape_stays_unclaimed():
    # the matmul feeds gelu AND escapes as a program output: the epilogue
    # matcher must not claim a cone whose interior is observed outside
    keys = jax.random.split(jax.random.PRNGKey(15), 2)
    x = jax.random.normal(keys[0], (8, 8), jnp.float32)
    w = jax.random.normal(keys[1], (8, 8), jnp.float32)

    @repro.compile
    def f(x, w):
        h = ops.matmul(x, w)
        return h, ops.gelu(h)

    h_got, g_got = f(x, w)
    assert "epilogue" not in _kinds(f.last_executable)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(x @ w),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(g_got),
        np.asarray(jax.nn.gelu(x @ w, approximate=False)),
        rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# provenance: cluster kinds in dump() / describe() / Session.describe()
# --------------------------------------------------------------------------


def test_dump_labels_cluster_kinds():
    lb = LazyBackend()
    with repro.session(backend=lb):
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q = lb._lift(jax.random.normal(keys[0], (8, 4), jnp.float32))
        k = lb._lift(jax.random.normal(keys[1], (8, 4), jnp.float32))
        v = lb._lift(jax.random.normal(keys[2], (8, 4), jnp.float32))
        out = _attn_program(q, k, v, mode="softmax", shifted=True,
                            scale=0.5)
        extra = ops.sum(ops.tanh(ops.add(out, out)), axis=-1,
                        keepdims=True)
        g, _ = trace([extra])
    PassManager.from_policy(CompilerPolicy()).run(g)
    text = g.dump()
    assert "(attention)" in text
    assert "(reduction)" in text


def test_session_describe_records_cluster_kinds():
    lb = LazyBackend()
    with repro.session(backend=lb) as s:
        x = lb._lift(jnp.ones((8, 8), jnp.float32))
        w = lb._lift(jnp.full((8, 8), 0.1, jnp.float32))
        ops.materialize(ops.gelu(ops.matmul(x, w)))
        desc = s.describe()
    last = desc["compiler"]["last_run"]
    assert last["clusters"] == [
        {"kind": "epilogue", "lowering": "pallas",
         "n_ops": last["clusters"][0]["n_ops"]}]
