"""Paged KV-cache serving runtime: block-table cache correctness (paged
must be token-for-token identical to dense under staggered mixed-length
admissions), chunked-prefill call counts, preemption-on-OOM, schedulers,
and the ServingPolicy provenance plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import ServingPolicy
from repro.serving import (BlockTable, FifoScheduler, PagedKVCache,
                           PriorityScheduler, Request, ServeEngine,
                           ShortestPromptScheduler, make_scheduler)
from repro.serving.kv_cache import OutOfMemory


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _run_staggered(model, params, policy, prompts, max_new=8, slots=2,
                   max_seq=32):
    eng = ServeEngine(model, params, batch_slots=slots, max_seq=max_seq,
                      policy=policy)
    eng.submit(Request(uid=0, prompt=list(prompts[0]), max_new_tokens=max_new))
    eng.submit(Request(uid=1, prompt=list(prompts[1]), max_new_tokens=max_new))
    eng.step()
    eng.step()
    # slots now sit at different depths; admit the rest mid-flight
    for uid, p in enumerate(prompts[2:], start=2):
        eng.submit(Request(uid=uid, prompt=list(p), max_new_tokens=max_new))
    done = {r.uid: r.generated for r in eng.run_until_done()}
    return done, eng


PROMPTS = [[3, 1, 4, 1, 5], [9, 2], [5, 3, 5, 8, 9, 7, 2], [2, 7, 1, 8]]


def test_paged_matches_dense_on_staggered_mixed_lengths(tiny):
    """The tentpole regression: the paged engine must be token-for-token
    identical to the dense engine on staggered mixed-length admissions
    (same chunked prefill, reads through the block table)."""
    model, params = tiny
    dense, _ = _run_staggered(
        model, params, ServingPolicy(cache="dense", prefill_chunk=4), PROMPTS)
    paged, ep = _run_staggered(
        model, params,
        ServingPolicy(cache="paged", block_size=4, prefill_chunk=4), PROMPTS)
    assert set(dense) == set(paged) == {0, 1, 2, 3}
    for uid in dense:
        assert dense[uid] == paged[uid], (
            f"request {uid} diverged under paging: "
            f"{paged[uid]} != {dense[uid]}")
    assert ep.kv.blocks_in_use == 0          # everything released


def test_paged_matches_dense_on_window_model():
    """Ring-buffer (sliding-window) layers stay dense inside the paged
    engine and must still agree with the all-dense engine — including a
    prompt longer than the window (ring wraps during chunked prefill)."""
    cfg = get_config("gemma3-27b", reduced=True)   # window 16 interleave
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7, 9, 3, 2, 3, 8, 4],
               [9, 2], [5, 3, 5, 8, 9, 7, 2, 11]]
    dense, _ = _run_staggered(
        model, params, ServingPolicy(cache="dense", prefill_chunk=5),
        prompts, max_new=6, max_seq=48)
    paged, _ = _run_staggered(
        model, params,
        ServingPolicy(cache="paged", block_size=8, prefill_chunk=5),
        prompts, max_new=6, max_seq=48)
    assert dense == paged


def test_chunked_prefill_reduces_jitted_calls(tiny):
    """A length-L prompt must cost ceil((L-1)/chunk) prefill calls, not
    L-1 one-token decodes (the legacy path, kept at prefill_chunk=0)."""
    model, params = tiny
    prompt = list(np.arange(1, 14) % 7 + 1)      # L = 13
    legacy = ServeEngine(model, params, batch_slots=1, max_seq=32,
                         policy=ServingPolicy(prefill_chunk=0))
    legacy.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=2))
    legacy.run_until_done()
    assert legacy.prefill_calls == len(prompt) - 1
    chunked = ServeEngine(model, params, batch_slots=1, max_seq=32,
                          policy=ServingPolicy(prefill_chunk=4))
    chunked.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=2))
    done = chunked.run_until_done()
    assert chunked.prefill_calls == 3            # ceil(12 / 4)
    # and the two admission paths generate identical tokens
    legacy2 = ServeEngine(model, params, batch_slots=1, max_seq=32,
                          policy=ServingPolicy(prefill_chunk=0))
    legacy2.submit(Request(uid=1, prompt=list(prompt), max_new_tokens=2))
    done2 = legacy2.run_until_done()
    assert done[0].generated == done2[0].generated


def test_preemption_evicts_requeues_and_recomputes(tiny):
    """When the block pool runs dry mid-decode, the scheduler's victim is
    evicted (blocks freed, request requeued) and later recomputed —
    output identical to an uncontended run."""
    model, params = tiny
    prompts = [[3, 1, 4, 1, 5, 9], [9, 2, 6, 5, 3, 5]]

    def solo(uid):
        eng = ServeEngine(model, params, batch_slots=1, max_seq=32,
                          policy=ServingPolicy(prefill_chunk=4))
        eng.submit(Request(uid=uid, prompt=list(prompts[uid]),
                           max_new_tokens=12))
        (r,) = eng.run_until_done()
        return r.generated

    ref = {u: solo(u) for u in range(2)}
    # 6 usable blocks of 4 positions; both requests grow to 18 positions
    # (5 blocks each) -> the pool must run dry and evict
    pol = ServingPolicy(cache="paged", block_size=4, num_blocks=7,
                        prefill_chunk=4)
    eng = ServeEngine(model, params, batch_slots=2, max_seq=32, policy=pol)
    for u, p in enumerate(prompts):
        eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=12))
    done = {r.uid: r.generated for r in eng.run_until_done()}
    assert eng.preemptions > 0
    assert done == ref
    assert eng.kv.blocks_in_use == 0


def test_admission_rejects_request_larger_than_pool(tiny):
    model, params = tiny
    pol = ServingPolicy(cache="paged", block_size=4, num_blocks=3,
                        prefill_chunk=4)
    eng = ServeEngine(model, params, batch_slots=1, max_seq=32, policy=pol)
    # needs ceil(12/4)=3 blocks; pool has 2 usable
    eng.submit(Request(uid=0, prompt=list(range(1, 13)), max_new_tokens=2))
    with pytest.raises(OutOfMemory):
        eng.run_until_done()


def test_admission_rejects_prompt_beyond_max_seq(tiny):
    """A prompt that cannot fit max_seq must raise, not requeue forever
    (the paged per-slot block cap is unreachable for such prompts, so
    without the guard run_until_done spins to max_steps)."""
    model, params = tiny
    for pol in (ServingPolicy(cache="dense", prefill_chunk=4),
                ServingPolicy(cache="paged", block_size=4, prefill_chunk=4)):
        eng = ServeEngine(model, params, batch_slots=2, max_seq=16,
                          policy=pol)
        eng.submit(Request(uid=0, prompt=list((i % 7) + 1
                                              for i in range(24)),
                           max_new_tokens=2))
        with pytest.raises(ValueError, match="max_seq"):
            eng.run_until_done()


def test_engine_detects_ssm_staggered_admission_corruption():
    """Regression for the documented corruption: a prefill loop advances
    SSM recurrent state for EVERY slot, so admitting a request while
    another is mid-flight (or into a recycled slot) must raise instead
    of silently corrupting — the safe single-request case keeps working
    (see test_distributed.test_serve_engine_greedy_matches_manual_decode).
    """
    cfg = get_config("mamba2-370m", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # staggered: second request would be admitted while the first decodes
    eng = ServeEngine(model, params, batch_slots=2, max_seq=16)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=8))
    eng.step()
    eng.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=4))
    with pytest.raises(ValueError, match="recurren"):
        eng.run_until_done()
    # recycled slot: admission after the first finished must also raise
    eng2 = ServeEngine(model, params, batch_slots=1, max_seq=16)
    eng2.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng2.run_until_done()
    eng2.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=2))
    with pytest.raises(ValueError, match="recycled"):
        eng2.run_until_done()
    # paged layout is meaningless for recurrent state: refuse up front
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, batch_slots=1, max_seq=16,
                    policy=ServingPolicy(cache="paged"))


def test_paged_rejects_mla_models():
    cfg = get_config("deepseek-v2-lite-16b", reduced=True, moe_impl="dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, batch_slots=1, max_seq=16,
                    policy=ServingPolicy(cache="paged"))
    # dense MLA serving still works (legacy per-token prefill)
    eng = ServeEngine(model, params, batch_slots=1, max_seq=16)
    assert not eng._chunked
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    (done,) = eng.run_until_done()
    assert len(done.generated) == 2


def test_fp8_paged_serving_smoke():
    """fp8 paged cache: scales ride along in the block pool; greedy
    decode agrees between dense-fp8 and paged-fp8."""
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                     cache_dtype="fp8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dense, _ = _run_staggered(
        model, params, ServingPolicy(cache="dense", prefill_chunk=4),
        PROMPTS[:3], max_new=5)
    paged, _ = _run_staggered(
        model, params,
        ServingPolicy(cache="paged", block_size=4, prefill_chunk=4),
        PROMPTS[:3], max_new=5)
    assert dense == paged


# -- schedulers --------------------------------------------------------------

def _reqs(lengths, **kw):
    return [Request(uid=i, prompt=list(range(1, n + 1)), **kw)
            for i, n in enumerate(lengths)]


def test_fifo_scheduler_order_and_requeue():
    s = FifoScheduler()
    a, b, c = _reqs([3, 1, 2])
    for r in (a, b, c):
        s.submit(r)
    assert s.pop() is a
    s.requeue(a)                       # preempted: back to the front
    assert s.pop() is a
    assert s.pop() is b
    assert len(s) == 1


def test_shortest_prompt_scheduler_orders_by_length():
    s = ShortestPromptScheduler()
    reqs = _reqs([5, 2, 7, 3])
    for r in reqs:
        s.submit(r)
    order = [s.pop().uid for _ in range(4)]
    assert order == [1, 3, 0, 2]
    # a preempted request re-sorts with its grown effective prompt
    grown = reqs[1]
    grown.generated = [9] * 10
    s.submit(reqs[0])
    s.requeue(grown)
    assert s.pop() is reqs[0]


def test_priority_scheduler_priority_then_deadline():
    s = PriorityScheduler()
    lo = Request(uid=0, prompt=[1], priority=0)
    hi = Request(uid=1, prompt=[1], priority=5)
    soon = Request(uid=2, prompt=[1], priority=5, deadline=1.0)
    for r in (lo, hi, soon):
        s.submit(r)
    assert s.pop() is soon             # same priority, earlier deadline
    assert s.pop() is hi
    assert s.pop() is lo
    # victim: least important active request ...
    lo.admit_seq, hi.admit_seq = 0, 1
    assert s.choose_victim({3: lo, 4: hi}) == 3
    # ... and among equal priorities, the most relaxed deadline loses,
    # never the most urgent request
    urgent = Request(uid=3, prompt=[1], priority=2, deadline=1.0)
    relaxed = Request(uid=4, prompt=[1], priority=2, deadline=100.0)
    urgent.admit_seq, relaxed.admit_seq = 0, 1
    assert s.choose_victim({5: urgent, 6: relaxed}) == 6
    # no deadlines: evict the youngest admission (least progress wasted)
    a = Request(uid=5, prompt=[1], priority=1)
    b = Request(uid=6, prompt=[1], priority=1)
    a.admit_seq, b.admit_seq = 0, 1
    assert s.choose_victim({7: a, 8: b}) == 8


def test_make_scheduler_registry():
    assert isinstance(make_scheduler("fifo"), FifoScheduler)
    assert isinstance(make_scheduler("sjf"), ShortestPromptScheduler)
    assert isinstance(make_scheduler("priority"), PriorityScheduler)
    inst = PriorityScheduler()
    assert make_scheduler(inst) is inst
    with pytest.raises(ValueError):
        make_scheduler("lifo")


def test_sjf_policy_through_engine(tiny):
    """Scheduler is a live policy: with one slot, SJF admits the shortest
    waiting prompt first regardless of arrival order."""
    model, params = tiny
    eng = ServeEngine(model, params, batch_slots=1, max_seq=32,
                      policy=ServingPolicy(scheduler="sjf", prefill_chunk=4))
    eng.submit(Request(uid=0, prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=2))
    eng.submit(Request(uid=1, prompt=[7, 8], max_new_tokens=2))
    done = eng.run_until_done()
    assert [r.uid for r in done] == [1, 0]


# -- block-table / pool machinery --------------------------------------------

def test_block_table_is_a_jit_stable_pytree():
    bt = BlockTable(jnp.arange(6, dtype=jnp.int32).reshape(2, 3), 4)

    @jax.jit
    def phys(bt):
        return bt.table * bt.block_size

    out = phys(bt)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.arange(6).reshape(2, 3) * 4)
    leaves, treedef = jax.tree_util.tree_flatten(bt)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.block_size == 4


def test_paged_kv_cache_allocator_accounting(tiny):
    model, _ = tiny
    kv = PagedKVCache(model, slots=2, max_seq=32, block_size=4)
    assert kv.usable_blocks == 2 * 8      # slots * ceil(32/4)
    kv.ensure(0, 9)                       # positions 0..9 -> 3 blocks
    assert kv.blocks_in_use == 3
    assert (kv.table[0, :3] > 0).all()    # mapped, never the trash block
    assert (kv.table[0, 3:] == 0).all()
    devalloc_before = kv.manager.stats.n_device_allocs
    kv.release(0)
    assert kv.blocks_in_use == 0
    assert (kv.table[0] == 0).all()
    kv.ensure(1, 9)                       # caching allocator recycles
    assert kv.manager.stats.n_device_allocs == devalloc_before
    with pytest.raises(OutOfMemory):
        kv.ensure(1, 10_000)              # beyond max_seq


def test_serving_policy_lands_in_session_describe(tiny):
    model, params = tiny
    pol = ServingPolicy(cache="paged", block_size=8, scheduler="sjf")
    with repro.session(serving=pol, tag="paged-scenario"):
        eng = ServeEngine(model, params, batch_slots=1, max_seq=32)
    d = eng.session.describe()
    assert d["serving"] == {"cache": "paged", "block_size": 8,
                            "num_blocks": None, "scheduler": "sjf",
                            "allocator": "caching", "prefill_chunk": 16,
                            "prefix": {"enabled": False, "retain": True,
                                       "partial": True},
                            "routing": "round_robin",
                            "speculative": {"enabled": False, "k": 4,
                                            "draft": "ngram", "ngram": 3}}
    # explicit policy argument overrides the session and is recorded
    eng2 = ServeEngine(model, params, batch_slots=1, max_seq=32,
                       policy=ServingPolicy(cache="dense"))
    assert eng2.session.describe()["serving"]["cache"] == "dense"
    d2 = eng2.describe()
    assert d2["slots"] == 1 and "session" in d2
