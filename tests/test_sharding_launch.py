"""Sharding rules engine + cell-plan lowering (single-device mesh) +
multi-device semantics via subprocess (8 fake host devices)."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import ShardingRules, make_rules


def _mesh(shape, axes):
    from repro.launch.mesh import make_mesh
    return make_mesh(shape, axes)


def test_rules_basic_mapping_and_divisibility():
    mesh = _mesh((1, 1), ("data", "model"))
    rules = make_rules("baseline")
    # heads shard over model when divisible
    assert rules.spec((64, 128), ("embed", "heads"), mesh) == P(None, "model")
    # kv_heads=1 cannot shard over model=1? (divisible) -> use bigger mesh
    # via a fake mesh of 4:
    spec = rules.spec((4096, 3), ("embed", "kv_heads"), mesh)
    # size 3 % 1 == 0 on a unit mesh; semantics tested on 8-dev below
    assert spec in (P(None, "model"), P())


def test_rules_no_duplicate_mesh_axes():
    mesh = _mesh((1, 1), ("data", "model"))
    rules = ShardingRules(rules=(("a", "model"), ("b", "model")))
    spec = rules.spec((8, 8), ("a", "b"), mesh)
    parts = [p for p in spec if p is not None]
    assert len(parts) == len(set(parts))
    assert spec == P("model")  # second use dropped


def test_fsdp_rules_shard_embed_over_data():
    mesh = _mesh((1, 1), ("data", "model"))
    rules = make_rules("fsdp")
    assert rules.spec((1024, 512), ("embed", "mlp"), mesh) == \
        P("data", "model")


def test_cellplan_lowers_on_tiny_mesh():
    """The dry-run machinery end-to-end on a 1x1 mesh with reduced cfg."""
    from repro.configs.base import ShapeSpec, get_config
    from repro.launch.steps import BASELINE, CellPlan

    mesh = _mesh((1, 1), ("data", "model"))
    cfg = get_config("codeqwen1.5-7b", reduced=True)
    shape = ShapeSpec("tiny_train", 32, 4, "train")
    plan = CellPlan(cfg, shape, mesh, BASELINE)
    fn, args, in_sh, out_sh, donate = plan.lowerable()
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate).lower(*args).compile()
    from repro.core.compat import cost_analysis
    assert cost_analysis(compiled)["flops"] > 0

    shape_d = ShapeSpec("tiny_decode", 32, 4, "decode")
    plan_d = CellPlan(cfg, shape_d, mesh, BASELINE)
    fn, args, in_sh, out_sh, donate = plan_d.lowerable()
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate).lower(*args).compile()
    assert compiled is not None


from conftest import REPO_ROOT as _REPO_ROOT, subproc_env as _subproc_env

_SUBPROC_FLASH_DECODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.serving.decode_attention import make_flash_decode_attend
    from repro.models.attention import plain_cache_attention

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    B, H, KV, S, D = 4, 8, 2, 64, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    valid = jnp.arange(S) < 50
    ref = plain_cache_attention(q, k, v, valid, scale=0.25)
    attend = make_flash_decode_attend(mesh, seq_axes=("model",),
                                      batch_axes=("data",))
    q_s = jax.device_put(q, NamedSharding(mesh, P("data")))
    k_s = jax.device_put(k, NamedSharding(mesh, P("data", "model")))
    v_s = jax.device_put(v, NamedSharding(mesh, P("data", "model")))
    val_s = jax.device_put(valid, NamedSharding(mesh, P("model")))
    out = jax.jit(lambda *a: attend(*a, scale=0.25))(q_s, k_s, v_s, val_s)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(json.dumps({"err": err}))
""")


def test_flash_decode_sharded_matches_plain_8dev():
    """SP flash-decoding == unsharded attention, on a real 2x4 mesh."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_FLASH_DECODE],
                       capture_output=True, text=True,
                       env=_subproc_env(), timeout=300,
                       cwd=_REPO_ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    err = json.loads(r.stdout.strip().splitlines()[-1])["err"]
    assert err < 1e-4, err


_SUBPROC_TRAIN_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.configs.base import ShapeSpec, get_config
    from repro.launch.steps import BASELINE, CellPlan, Variant
    from repro.models.meta import tree_init
    from repro.sharding.context import active_mesh

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("jamba-v0.1-52b", reduced=True)
    shape = ShapeSpec("tiny_train", 32, 4, "train")
    out = {}
    for vname, variant in [("baseline", BASELINE),
                           ("fsdp", Variant(name="fsdp", sharding="fsdp"))]:
        plan = CellPlan(cfg, shape, mesh, variant)
        fn, args, in_sh, out_sh, donate = plan.lowerable()
        params = tree_init(plan.param_metas, jax.random.PRNGKey(0))
        params = jax.device_put(params, plan.param_shardings())
        opt_state = plan.optimizer.init(params)
        tok = jax.random.randint(jax.random.PRNGKey(1), (32, 32), 0,
                                 cfg.vocab_size)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
        with active_mesh(mesh):
            step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate)
            p2, s2, metrics = step(params, opt_state, jnp.int32(0), batch)
        out[vname] = float(metrics["loss"])
    print(json.dumps(out))
""")


def test_sharded_train_step_runs_and_variants_agree_8dev():
    """A real sharded train step on 8 devices; fsdp == baseline loss."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_TRAIN_SHARDED],
                       capture_output=True, text=True,
                       env=_subproc_env(), timeout=300,
                       cwd=_REPO_ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    losses = json.loads(r.stdout.strip().splitlines()[-1])
    assert np.isfinite(losses["baseline"])
    np.testing.assert_allclose(losses["baseline"], losses["fsdp"],
                               rtol=1e-4)
