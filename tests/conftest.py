"""Test-suite bootstrap: degrade gracefully when ``hypothesis`` is absent.

Six test modules use hypothesis property tests.  CI images without the
``test`` extra used to fail *collection* for all of them, silently skipping
~60 unrelated tests.  When hypothesis is not importable we install a tiny
stand-in module that runs each ``@given`` test as a small deterministic
fixed-example sweep: far weaker than real property testing (no shrinking,
no random exploration — install ``.[test]`` for that), but every module
collects and the properties still get exercised on representative points.
"""

from __future__ import annotations

import os
import pathlib
import sys

REPO_ROOT = str(pathlib.Path(__file__).resolve().parents[1])


def subproc_env():
    """Environment for tests that re-exec python with fake jax devices.

    Inherit the full environment (a stripped env can stall jax device
    init on some hosts); just point the child at the src layout.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env

try:  # the real thing wins whenever it is installed
    import hypothesis  # noqa: F401
except ImportError:
    import types

    _N_EXAMPLES = 5  # fixed examples per @given test

    class _Strategy:
        """A deterministic example generator standing in for a strategy."""

        def __init__(self, gen):
            # gen: index -> example; indexes 0.._N_EXAMPLES-1 are drawn
            self.gen = gen

        def example_at(self, i: int):
            return self.gen(i)

    def _integers(min_value=0, max_value=100, **kw):
        lo = kw.get("min_value", min_value)
        hi = kw.get("max_value", max_value)
        span = max(hi - lo, 0)
        picks = sorted({lo, hi, lo + span // 2, lo + span // 3,
                        lo + (2 * span) // 3})
        return _Strategy(lambda i: picks[i % len(picks)])

    def _floats(min_value=0.0, max_value=1.0, **kw):
        lo = kw.get("min_value", min_value)
        hi = kw.get("max_value", max_value)
        fracs = (0.0, 1.0, 0.5, 0.25, 0.75)
        return _Strategy(lambda i: lo + (hi - lo) * fracs[i % len(fracs)])

    def _booleans():
        return _Strategy(lambda i: i % 2 == 0)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda i: seq[i % len(seq)])

    def _lists(elem, min_size=0, max_size=10, **_):
        def gen(i):
            # vary length across the sweep, elements via the child strategy
            size = min_size + (i * 2 + 1) % (max_size - min_size + 1)
            return [elem.example_at(i + j * 7 + 3) for j in range(size)]

        return _Strategy(gen)

    def _tuples(*strats):
        return _Strategy(
            lambda i: tuple(s.example_at(i + 11 * j)
                            for j, s in enumerate(strats)))

    def _just(value):
        return _Strategy(lambda i: value)

    def _one_of(*strats):
        flat = list(strats[0]) if (len(strats) == 1
                                   and isinstance(strats[0], (list, tuple))
                                   ) else list(strats)
        return _Strategy(lambda i: flat[i % len(flat)].example_at(i))

    def given(*pos_strats, **kw_strats):
        def decorate(fn):
            def wrapper(*args, **kwargs):
                for i in range(_N_EXAMPLES):
                    pos = tuple(s.example_at(i) for s in pos_strats)
                    kws = {k: s.example_at(i)
                           for k, s in kw_strats.items()}
                    try:
                        fn(*args, *pos, **kws, **kwargs)
                    except UnsatisfiedAssumption:
                        continue  # assume() failed: discard this example

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # pytest must not inject fixtures for strategy-bound kwargs
            wrapper.__signature__ = _strip_signature(fn, pos_strats,
                                                     kw_strats)
            return wrapper

        return decorate

    def _strip_signature(fn, pos_strats, kw_strats):
        import inspect

        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        drop = set(kw_strats)
        if pos_strats:  # positional strategies bind to the leading params
            drop |= {p.name for p in params[:len(pos_strats)]}
        return sig.replace(
            parameters=[p for p in params if p.name not in drop])

    def settings(*_a, **_kw):
        def decorate(fn):
            return fn

        return decorate

    def assume(condition):
        if not condition:
            raise _stub.UnsatisfiedAssumption()
        return True

    _stub = types.ModuleType("hypothesis")
    _stub.given = given
    _stub.settings = settings
    _stub.assume = assume
    _stub.note = lambda *_a, **_k: None
    _stub.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large",
        filter_too_much="filter_too_much")

    class UnsatisfiedAssumption(Exception):
        pass

    _stub.UnsatisfiedAssumption = UnsatisfiedAssumption
    _stub.__repro_stub__ = True

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples
    _st.just = _just
    _st.one_of = _one_of
    _stub.strategies = _st

    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _st
