"""Per-kernel allclose vs kernels/ref.py oracles: shape/dtype sweeps in
interpret mode (CPU emulation of the TPU kernel body)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops as K
from repro.kernels import ref as R

RNG = np.random.default_rng(7)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("m,k,n,bm", [(128, 128, 128, 128),
                                      (256, 384, 128, 128),
                                      (512, 128, 256, 128),
                                      (64, 64, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, bm, dtype):
    x, y = _rand((m, k), dtype), _rand((k, n), dtype)
    out = K.matmul(x, y, bm=bm, bn=bm, bk=bm)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(R.matmul(x, y), np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(8, 128), (4, 16, 256), (2, 3, 5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(shape, dtype)
    w = _rand(shape[-1:], jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.rms_norm(x, w), np.float32),
        np.asarray(R.rms_norm(x, w), np.float32), **_tol(dtype))


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([16, 48, 160]), d=st.sampled_from([64, 128]),
       seed=st.integers(0, 10))
def test_rmsnorm_property(n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    np.testing.assert_allclose(np.asarray(K.rms_norm(x, w)),
                               np.asarray(R.rms_norm(x, w)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("s,d,causal,window",
                         [(128, 64, True, 0), (256, 64, True, 64),
                          (128, 128, False, 0), (256, 32, True, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, d, causal, window, dtype):
    b, h, kv = 2, 4, 2
    q = _rand((b, s, h, d), dtype)
    k = _rand((b, s, kv, d), dtype)
    v = _rand((b, s, kv, d), dtype)
    out = K.flash_attention(q, k, v, causal=causal, window=window,
                            bq=64, bk=64)
    kr = jnp.repeat(k, h // kv, 2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, h // kv, 2).transpose(0, 2, 1, 3)
    ref = R.flash_attention(q.transpose(0, 2, 1, 3), kr, vr, causal=causal,
                            window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_softcap():
    b, s, h, d = 1, 64, 2, 32
    q, k, v = (_rand((b, s, h, d), jnp.float32) for _ in range(3))
    out = K.flash_attention(q, k, v, causal=True, softcap=20.0, bq=32, bk=32)
    ref = R.flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            softcap=20.0).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("s,d,valid_len,bk", [(256, 64, 100, 64),
                                              (512, 128, 512, 128),
                                              (128, 32, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(s, d, valid_len, bk, dtype):
    n = 6
    q = _rand((n, d), dtype)
    k = _rand((n, s, d), dtype)
    v = _rand((n, s, d), dtype)
    valid = jnp.arange(s) < valid_len
    out = K.flash_decode(q, k, v, valid, bk=bk)
    ref = R.flash_decode(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_decode_per_row_valid():
    """Paged/continuous-batching path: every row carries its own valid
    mask (slots decode at different depths; gathered block-table views
    have per-slot lengths)."""
    n, s, d, bk = 5, 256, 64, 64
    q = _rand((n, d), jnp.float32)
    k = _rand((n, s, d), jnp.float32)
    v = _rand((n, s, d), jnp.float32)
    lens = jnp.asarray([1, 64, 100, 200, 256])
    valid = jnp.arange(s)[None, :] < lens[:, None]           # [N, S]
    out = K.flash_decode(q, k, v, valid, bk=bk)
    ref = R.flash_decode(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(jnp.float32))
    # per-row result must equal the shared-mask result row-by-row
    for i, ln in enumerate([1, 64, 100, 200, 256]):
        shared = K.flash_decode(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                jnp.arange(s) < ln, bk=bk)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(shared), **_tol(jnp.float32))


@pytest.mark.parametrize("s,t,d,bk", [(256, 5, 64, 64), (512, 3, 128, 128),
                                      (128, 1, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_verify_sweep(s, t, d, bk, dtype):
    """Wide-verify: t query positions per row over a shared KV stream,
    per-(row, position) causal/ragged validity."""
    n = 4
    q = _rand((n, t, d), dtype)
    k = _rand((n, s, d), dtype)
    v = _rand((n, s, d), dtype)
    # row i starts at depth start_i; query j attends positions
    # <= start_i + j (the verify span's staircase mask)
    starts = jnp.asarray([1, 40, 100, s - t], jnp.int32)
    valid = (jnp.arange(s)[None, None, :]
             <= starts[:, None, None] + jnp.arange(t)[None, :, None])
    out = K.flash_verify(q, k, v, valid, bk=bk)
    ref = R.flash_verify(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_verify_t1_matches_flash_decode():
    """flash_decode is the T=1 special case of flash_verify."""
    n, s, d = 5, 256, 64
    q = _rand((n, d), jnp.float32)
    k = _rand((n, s, d), jnp.float32)
    v = _rand((n, s, d), jnp.float32)
    lens = jnp.asarray([1, 64, 100, 200, 256])
    valid = jnp.arange(s)[None, :] < lens[:, None]
    wide = K.flash_verify(q[:, None, :], k, v, valid[:, None, :], bk=64)
    narrow = K.flash_decode(q, k, v, valid, bk=64)
    np.testing.assert_allclose(np.asarray(wide[:, 0]), np.asarray(narrow),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("q,p,n", [(32, 16, 24), (64, 32, 16), (16, 64, 128)])
def test_ssd_chunk_sweep(q, p, n):
    b, h, nc = 2, 3, 4
    rng = np.random.default_rng(q)
    x = jnp.asarray(rng.standard_normal((b, h, nc, q, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, h, nc, q)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, nc, q, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, nc, q, n)), jnp.float32)
    y, st_ = K.ssd_chunk(x, dt, A, B, C)
    yr, sr = R.ssd_chunk(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(sr), rtol=1e-4,
                               atol=1e-5)


def test_ssd_chunked_full_equals_naive_recurrence():
    """The full chunked SSD (models/ssm.py) vs an O(S) step recurrence."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n, chunk = 1, 64, 2, 8, 12, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y, hf = ssd_chunked(x, dt, A, B, C, chunk=chunk)

    # naive recurrence
    hstate = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, B, C))
    An = np.asarray(A)
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None])            # [b,h]
        hstate = hstate * decay[..., None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dtn[:, t], Bn[:, t], xn[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cn[:, t], hstate)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), hstate, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("e,c,d,f", [(4, 64, 32, 48), (2, 128, 128, 128),
                                     (8, 32, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(e, c, d, f, dtype):
    h = _rand((e, c, d), dtype)
    w = _rand((e, d, f), dtype)
    out = K.moe_gmm(h, w, bc=min(c, 32), bf=min(f, 16), bd=min(d, 16))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(R.moe_gmm(h, w), np.float32),
                               **_tol(dtype))
