"""Speculative + wide decoding on the paged KV cache.

The acceptance rule (longest proposal prefix matching the target's own
greedy argmax, plus one bonus token from the verify logits) makes
speculative decoding invisible in the tokens: any proposer — n-gram
self-draft, a mamba2 draft model, an oracle, or an adversary — must
decode bit-identically to one-token decode, while rejected suffixes
roll back by truncating the slot's block table.  Beam search rides the
same machinery: ``fork`` is a refcounted block-table clone, divergent
writes copy-on-write.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import AnalysisPolicy, ServingPolicy, SpeculativePolicy
from repro.serving import (FixedProposer, ModelDraft, NGramProposer,
                           Request, Router, ServeEngine, beam_decode)

PROMPTS = [[3, 1, 4, 1, 5], [9, 2], [5, 3, 5, 8, 9, 7, 2], [2, 7, 1, 8]]

BASE = ServingPolicy(cache="paged", block_size=4, prefill_chunk=8)
SPEC = BASE.replace(speculative={"enabled": True, "k": 4})


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def tiny_cached(tiny):
    # hypothesis re-runs the test body; reuse the module model
    return tiny


def _run(model, params, policy, prompts=PROMPTS, max_new=12, slots=4,
         max_seq=64, stagger=False, **kw):
    eng = ServeEngine(model, params, batch_slots=slots, max_seq=max_seq,
                      policy=policy, **kw)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    if stagger:
        eng.submit(reqs[0])
        eng.step()
        eng.step()
        for r in reqs[1:]:
            eng.submit(r)
    else:
        for r in reqs:
            eng.submit(r)
    done = {r.uid: r.generated for r in eng.run_until_done()}
    return done, eng


def _oracle(ref, prompts):
    """Replay the reference continuation: acceptance == k every round."""
    seqs = [list(p) + list(ref[uid]) for uid, p in enumerate(prompts)]

    def fn(ctx):
        n = len(ctx)
        for seq in seqs:
            if len(seq) >= n and seq[:n] == ctx:
                return seq[n:]
        return []
    return FixedProposer(fn)


def _adversary(ref, prompts, k):
    """Propose exactly the wrong token: acceptance == 0 every round."""
    seqs = [list(p) + list(ref[uid]) for uid, p in enumerate(prompts)]

    def fn(ctx):
        n = len(ctx)
        for seq in seqs:
            if len(seq) > n and seq[:n] == ctx:
                return [(seq[n] + 1) % 64] * k
        return []
    return FixedProposer(fn)


# -- greedy identity across drafts --------------------------------------------


def test_ngram_speculative_identical_to_plain(tiny):
    """The tentpole regression: n-gram self-drafting with wide verify
    and rollback emits exactly the one-token greedy stream."""
    model, params = tiny
    with repro.session(analysis=AnalysisPolicy(level="strict")):
        ref, _ = _run(model, params, BASE, stagger=True)
        out, eng = _run(model, params, SPEC, stagger=True)
    assert out == ref
    d = eng.describe()["speculative"]
    assert d["enabled"] and d["verify_calls"] > 0
    assert d["proposer"]["kind"] == "NGramProposer"
    assert eng.decode_calls == 0          # every step went through verify
    assert eng.kv.blocks_in_use == 0
    assert not eng.kv.audit().diagnostics


def test_model_draft_identical_to_plain(tiny):
    """A mamba2 (SSM) draft model proposing for the transformer target:
    snapshot-selection rollback on the draft side, token identity."""
    model, params = tiny
    dcfg = get_config("mamba2-370m", reduced=True, n_layers=2, d_model=64,
                      vocab_size=64)
    dmodel = build_model(dcfg)
    dparams = dmodel.init(jax.random.PRNGKey(1))
    spec = BASE.replace(speculative={"enabled": True, "k": 3,
                                     "draft": "model"})
    ref, _ = _run(model, params, BASE, stagger=True)
    out, eng = _run(model, params, spec, stagger=True,
                    draft_model=dmodel, draft_params=dparams)
    assert out == ref
    prop = eng.describe()["speculative"]["proposer"]
    assert prop["kind"] == "ModelDraft" and prop["draft_calls"] > 0
    assert eng.kv.blocks_in_use == 0


def test_model_draft_requires_draft_model(tiny):
    model, params = tiny
    spec = BASE.replace(speculative={"enabled": True, "draft": "model"})
    with pytest.raises(ValueError, match="draft_model"):
        ServeEngine(model, params, batch_slots=2, max_seq=32, policy=spec)


# -- acceptance extremes ------------------------------------------------------


def test_oracle_draft_accepts_k_per_round(tiny):
    """A perfect draft accepts all k proposals each round — many tokens
    per verify call, no rollback churn beyond sequence tails."""
    model, params = tiny
    ref, plain = _run(model, params, BASE)
    out, eng = _run(model, params, SPEC,
                    proposer=_oracle(ref, PROMPTS))
    assert out == ref
    d = eng.describe()["speculative"]
    assert d["accepted_per_step"] > 2.0
    assert d["verify_calls"] < plain.decode_calls
    assert d["rejected_tokens"] == 0


def test_adversarial_draft_accepts_zero(tiny):
    """Proposals that are always wrong: acceptance 0, one bonus token
    per round (== plain decode rate), every proposal's KV rolled back —
    and the output stream still identical."""
    model, params = tiny
    ref, _ = _run(model, params, BASE)
    out, eng = _run(model, params, SPEC,
                    proposer=_adversary(ref, PROMPTS, k=4))
    assert out == ref
    d = eng.describe()["speculative"]
    assert d["accepted_tokens"] == 0
    assert d["rejected_tokens"] > 0
    # rejected suffixes crossed block boundaries: blocks actually freed
    assert eng.kv.rollback_blocks_freed > 0
    assert eng.kv.blocks_in_use == 0
    assert not eng.kv.audit().diagnostics


# -- random proposals (property) ----------------------------------------------


_REF = {}


def _plain_ref(model, params):
    key = id(params)
    if key not in _REF:
        _REF[key] = _run(model, params, BASE, max_new=8, stagger=True)[0]
    return _REF[key]


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=1, max_value=40),
       k=st.integers(min_value=1, max_value=5))
def test_random_proposals_are_invisible(tiny_cached, seed, k):
    """Property: arbitrary (deterministic-per-context) proposal streams
    under staggered admissions never change the greedy output."""
    model, params = tiny_cached
    ref = _plain_ref(model, params)

    def fn(ctx):
        r = np.random.default_rng((seed * 1009 + 31 * len(ctx)
                                   + ctx[-1]) % (2 ** 31))
        return [int(t) for t in r.integers(0, 64,
                                           size=int(r.integers(0, k + 1)))]

    pol = BASE.replace(speculative={"enabled": True, "k": k})
    out, eng = _run(model, params, pol, max_new=8, stagger=True,
                    proposer=FixedProposer(fn))
    assert out == ref
    assert not eng.kv.audit().diagnostics


# -- preemption mid-speculation -----------------------------------------------


def test_preempt_mid_speculation_requeues_identically(tiny):
    """A victim evicted between speculative rounds loses its blocks and
    its proposer state; re-admission must catch both up — same tokens
    as the uncontended plain run."""
    model, params = tiny
    base = dict(cache="paged", block_size=4, prefill_chunk=8,
                num_blocks=9)                       # tight pool: preempts
    with repro.session(analysis=AnalysisPolicy(level="strict")):
        ref, eoff = _run(model, params, ServingPolicy(**base),
                         max_new=14, slots=3)
        out, eon = _run(model, params,
                        ServingPolicy(**base, speculative={"enabled": True,
                                                           "k": 4}),
                        max_new=14, slots=3)
    assert out == ref
    assert eon.preemptions + eoff.preemptions > 0   # pressure actually hit
    assert eon.kv.blocks_in_use == 0


# -- composition with prefix sharing ------------------------------------------


def test_speculation_composes_with_prefix_sharing(tiny):
    """Speculative decode over admissions that mapped shared radix
    blocks: COW guards the shared prefix, rollback only ever truncates
    past it, output identical to the plain sharing-off run."""
    model, params = tiny
    sys = [7, 3, 11, 5, 2, 13, 17, 1, 9, 4, 23, 6, 29, 8, 31, 10]
    prompts = [sys + [40 + i, 50 + i] for i in range(4)]
    with repro.session(analysis=AnalysisPolicy(level="strict")):
        ref, _ = _run(model, params, BASE, prompts=prompts, stagger=True)
        out, eng = _run(model, params,
                        SPEC.replace(prefix=True), prompts=prompts,
                        stagger=True)
    assert out == ref
    assert eng.prefill_tokens_saved > 0
    assert eng.describe()["speculative"]["verify_calls"] > 0
    assert eng.kv.blocks_in_use == 0
    eng.kv.clear_prefix()
    assert eng.kv.refcount == {}
    assert not eng.kv.audit().diagnostics


# -- beam search --------------------------------------------------------------


def _ref_beam(model, params, prompt, width, max_new, max_seq=64):
    """Independent beam-search reference: teacher-forced scoring with a
    fresh dense cache per hypothesis — no forks, no block tables."""
    def logprobs(seq):
        cache = model.init_cache(1, max_seq)
        logits = None
        for i, t in enumerate(seq):
            logits, cache = model.decode_step(
                params, cache, jnp.asarray([[t]], jnp.int32),
                jnp.asarray([i], jnp.int32))
        return np.asarray(jax.nn.log_softmax(
            logits[0].astype(jnp.float32)))

    beams = [([], 0.0)]
    for _ in range(max_new):
        cands = []
        for toks, score in beams:
            lp = logprobs(list(prompt) + toks)
            for t in np.argsort(-lp, kind="stable")[:width]:
                cands.append((score + float(lp[t]), toks + [int(t)]))
        cands.sort(key=lambda c: -c[0])
        beams = [(t, s) for s, t in cands[:width]]
    return beams


def test_beam_matches_bruteforce_reference(tiny):
    """Engine beam search (COW forks over KV slots) must find the same
    hypotheses and scores as teacher-forced re-scoring from scratch."""
    model, params = tiny
    prompt = [3, 1, 4, 1, 5]
    eng = ServeEngine(model, params, batch_slots=3, max_seq=64,
                      policy=BASE)
    res = beam_decode(eng, prompt, width=3, max_new=5)
    ref = _ref_beam(model, params, prompt, width=3, max_new=5)
    assert [t for t, _ in res.beams] == [t for t, _ in ref]
    np.testing.assert_allclose([s for _, s in res.beams],
                               [s for _, s in ref], rtol=1e-4, atol=1e-4)
    assert res.stats["forks"] > 0
    assert eng.kv.blocks_in_use == 0
    assert not eng.kv.audit().diagnostics


def test_beam_width_one_is_greedy(tiny):
    model, params = tiny
    ref, _ = _run(model, params, BASE, prompts=[PROMPTS[0]], max_new=8)
    eng = ServeEngine(model, params, batch_slots=4, max_seq=64,
                      policy=BASE)
    res = beam_decode(eng, list(PROMPTS[0]), width=1, max_new=8)
    assert res.tokens == ref[0]
    assert res.stats["forks"] == 0
    assert eng.kv.blocks_in_use == 0


def test_beam_rejects_bad_setups(tiny):
    model, params = tiny
    eng = ServeEngine(model, params, batch_slots=2, max_seq=32,
                      policy=BASE)
    with pytest.raises(ValueError, match="width"):
        beam_decode(eng, [1, 2], width=3, max_new=2)
    dense = ServeEngine(model, params, batch_slots=2, max_seq=32,
                        policy=ServingPolicy(cache="dense"))
    with pytest.raises(ValueError, match="paged"):
        beam_decode(dense, [1, 2], width=2, max_new=2)


# -- gating / policy / provenance ---------------------------------------------


def test_speculation_gates_off_on_dense_cache(tiny):
    """Dense caches cannot rewind: speculation silently degrades to
    plain decode rather than corrupting state."""
    model, params = tiny
    pol = ServingPolicy(cache="dense", prefill_chunk=8,
                        speculative=True)
    out, eng = _run(model, params, pol)
    assert not eng.spec_on
    assert eng.describe()["speculative"]["verify_calls"] == 0
    ref, _ = _run(model, params, ServingPolicy(cache="dense",
                                               prefill_chunk=8))
    assert out == ref


def test_speculative_policy_validation_and_describe(tiny):
    model, params = tiny
    with pytest.raises(ValueError, match="draft"):
        SpeculativePolicy(draft="nope")
    with pytest.raises(ValueError, match="k"):
        SpeculativePolicy(k=0)
    assert ServingPolicy(speculative=True).speculative.enabled
    pol = ServingPolicy(cache="paged",
                        speculative={"enabled": True, "k": 2})
    assert pol.describe()["speculative"]["k"] == 2
    with repro.session(serving=pol):
        eng = ServeEngine(model, params, batch_slots=1, max_seq=32)
    assert eng.session.describe()["serving"]["speculative"]["enabled"]
    assert eng.describe()["speculative"]["enabled"]


def test_router_aggregates_speculative_provenance(tiny):
    """Router.describe() rolls accepted/rejected tokens, rollback frees
    and forks up across replicas next to placement."""
    model, params = tiny
    router = Router([ServeEngine(model, params, batch_slots=2, max_seq=64,
                                 policy=SPEC) for _ in range(2)])
    for i, p in enumerate(PROMPTS):
        router.submit(Request(uid=i, prompt=list(p), max_new_tokens=6))
    router.run_until_done()
    agg = router.describe()["speculative"]
    assert agg["spec_rounds"] > 0
    assert agg["accepted_tokens"] >= 0 and agg["rejected_tokens"] >= 0
    per = [e.describe()["speculative"]["rounds"] for e in router.engines]
    assert agg["spec_rounds"] == sum(per)
