"""End-to-end behaviour tests: the paper's headline claims, in miniature.

1. §5.2.4 — swapping the source of truth for a primitive op changes every
   consumer (core NN stack AND production models) with no call-site edits.
2. §4.2 — the MNIST-flavor end-to-end loop (Listings 7-11) trains.
3. The production train path runs the same model the dry-run lowers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optim
from repro.core.autograd import Variable
from repro.core.nn import Sequential, Linear, ReLU, categoricalCrossEntropy
from repro.core.tensor import (JnpBackend, ops, register_backend,
                               use_backend)


class DoublingAddBackend(JnpBackend):
    """A 'research artifact': custom add implementation (§5.2.4)."""

    name = "doubling"
    calls = 0

    def add(self, lhs, rhs):
        DoublingAddBackend.calls += 1
        return 2.0 * (jnp.add(lhs, rhs))


def test_backend_swap_reaches_all_callsites():
    register_backend("doubling", DoublingAddBackend)
    x = jnp.ones((4, 4))
    assert float(ops.add(x, x).sum()) == 32.0
    DoublingAddBackend.calls = 0
    with use_backend("doubling"):
        # direct op
        assert float(ops.add(x, x)[0, 0]) == 4.0
        # through the core NN stack (Linear bias-add)
        lin = Linear(4, 4)
        _ = lin(Variable(x))
        # through the production substrate (residual adds etc. go through
        # jnp, but embedding/take and projections route via dispatch)
        from repro.configs.base import get_config
        from repro.models import build_model

        cfg = get_config("mamba2-370m", reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        logits, _, _ = model.forward(params, jnp.zeros((1, 8), jnp.int32))
        assert jnp.isfinite(logits).all()
    assert DoublingAddBackend.calls >= 2
    # swap ends with the scope
    assert float(ops.add(x, x)[0, 0]) == 2.0


def test_end_to_end_mnist_flavor_training():
    """Paper Listings 7-11, miniaturized: synthetic 'images', Sequential
    model, SGD loop with loss meter; loss must drop sharply."""
    rng = np.random.default_rng(0)
    n, d, classes = 256, 16, 4
    centers = rng.standard_normal((classes, d)) * 3
    ys = rng.integers(0, classes, n)
    xs = centers[ys] + rng.standard_normal((n, d))

    from repro.core.data import BatchDataset, TensorDataset

    trainset = BatchDataset(TensorDataset([xs.astype(np.float32),
                                           ys.astype(np.int32)]), 32)
    model = Sequential(Linear(d, 32), ReLU(), Linear(32, classes))
    opt = optim.SGDOptimizer(model.params(), lr=0.1)
    losses = []
    for _epoch in range(6):
        for bx, by in trainset:
            out = model(Variable(jnp.asarray(bx)))
            loss = categoricalCrossEntropy(out, Variable(jnp.asarray(by)))
            loss.backward()
            opt.step()
            opt.zeroGrad()
            losses.append(float(loss.tensor()))
    assert losses[-1] < losses[0] * 0.3, (losses[0], losses[-1])


def test_production_train_step_reduces_loss():
    from repro.configs.base import get_config
    from repro.core.optim import AdamW
    from repro.models import build_model
    from repro.training.train_loop import TrainConfig, make_step_fn

    cfg = get_config("codeqwen1.5-7b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    opt_state = opt.init(params)
    tcfg = TrainConfig(steps=30, base_lr=3e-3, warmup=3)
    step_fn = jax.jit(make_step_fn(model, opt, tcfg))
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 64)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    first = last = None
    for step in range(30):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.int32(step), batch)
        if step == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first * 0.7, (first, last)
