"""Per-architecture smoke tests (reduced configs, one fwd/train step on
CPU, shape + NaN assertions) and model-substrate invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config
from repro.models import build_model, tree_params_count


def _batch_for(cfg, key, b=2, s=32):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(key, (b, s // 2, cfg.d_model)),
                "tokens": jnp.zeros((b, s // 2), jnp.int32),
                "labels": jnp.ones((b, s // 2), jnp.int32)}
    if cfg.family == "vlm":
        txt = s - cfg.num_image_tokens
        return {"tokens": jnp.zeros((b, txt), jnp.int32),
                "labels": jnp.ones((b, txt), jnp.int32),
                "image_embeds": jax.random.normal(
                    key, (b, cfg.num_image_tokens, cfg.d_model))}
    return {"tokens": jnp.zeros((b, s), jnp.int32),
            "labels": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Instantiate the reduced config of the same family; one forward +
    one grad step; assert output shapes and no NaNs."""
    key = jax.random.PRNGKey(0)
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch_for(cfg, key)
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch
    # logits shape
    if cfg.family == "encdec":
        logits = model.forward(params, batch)
        assert logits.shape == (2, 16, cfg.vocab_size)
    elif cfg.family == "vlm":
        logits, _, _ = model.forward(params, batch["tokens"],
                                     image_embeds=batch["image_embeds"])
        assert logits.shape[-1] == cfg.vocab_size
    else:
        logits, _, _ = model.forward(params, batch["tokens"])
        assert logits.shape == (2, 32, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_matches_teacher_forcing(arch):
    """prefill + decode_step logits == full-forward last-token logits."""
    key = jax.random.PRNGKey(1)
    cfg = get_config(arch, reduced=True, moe_impl="dense")
    model = build_model(cfg)
    params = model.init(key)
    b, s, maxs = 2, 12, 24
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (b, 8, cfg.d_model))
        ref = model.forward(params, {"frames": frames, "tokens": toks})[:, -1]
        _, cache = model.prefill(params, frames, toks[:, :-1], max_seq=maxs)
        out, _ = model.decode_step(params, cache, toks[:, -1:],
                                   jnp.int32(s - 1))
    elif cfg.family == "vlm":
        img = jax.random.normal(key, (b, cfg.num_image_tokens, cfg.d_model))
        ref = model.forward(params, toks, image_embeds=img)[0][:, -1]
        _, cache = model.prefill(params, toks[:, :-1],
                                 max_seq=maxs + cfg.num_image_tokens,
                                 image_embeds=img)
        out, _ = model.decode_step(params, cache, toks[:, -1:],
                                   jnp.int32(cfg.num_image_tokens + s - 1))
    else:
        ref = model.forward(params, toks)[0][:, -1]
        _, cache = model.prefill(params, toks[:, :-1], max_seq=maxs)
        out, _ = model.decode_step(params, cache, toks[:, -1:],
                                   jnp.int32(s - 1))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=2e-3)


def test_moe_scatter_matches_dense_oracle():
    """With generous capacity, scatter dispatch == dense (no drops)."""
    from repro.models.moe import apply_moe

    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    cfg_scatter = cfg.with_(moe_impl="scatter")
    cfg_dense = cfg.with_(moe_impl="dense")
    m = cfg.moe.__class__(**{**cfg.moe.__dict__, "capacity_factor": 8.0})
    cfg_scatter = cfg_scatter.with_(moe=m)
    cfg_dense = cfg_dense.with_(moe=m)

    from repro.models.moe import moe_meta
    from repro.models.meta import tree_init

    p = tree_init(moe_meta(cfg_scatter), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out_s, aux_s = apply_moe(p, x, cfg_scatter)
    out_d, aux_d = apply_moe(p, x, cfg_dense)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop but output stays finite
    and aux loss pushes toward balance."""
    from repro.models.meta import tree_init
    from repro.models.moe import apply_moe, moe_meta

    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    m = cfg.moe.__class__(**{**cfg.moe.__dict__, "capacity_factor": 1.0})
    cfg = cfg.with_(moe=m, moe_impl="scatter")
    p = tree_init(moe_meta(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    out, aux = apply_moe(p, x, cfg)
    assert bool(jnp.isfinite(out).all())
    assert float(aux) > 0


def test_mla_absorbed_decode_equals_expanded():
    """MLA decode (latent cache + absorbed matmuls) == expanded attention."""
    cfg = get_config("deepseek-v3-671b", reduced=True, moe_impl="dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0,
                              cfg.vocab_size)
    ref = model.forward(params, toks)[0][:, -1]
    _, cache = model.prefill(params, toks[:, :-1], max_seq=16)
    out, _ = model.decode_step(params, cache, toks[:, -1:], jnp.int32(8))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=2e-3)


def test_sliding_window_ring_buffer_decode():
    """Decode past the window: ring buffer must equal full-cache windowed
    attention."""
    cfg = get_config("gemma3-27b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, total = 1, 40            # window is 16 in the reduced config
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, total), 0,
                              cfg.vocab_size)
    ref = model.forward(params, toks)[0][:, -1]
    _, cache = model.prefill(params, toks[:, :-1], max_seq=total + 8)
    out, _ = model.decode_step(params, cache, toks[:, -1:],
                               jnp.int32(total - 1))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(ref) / scale, atol=2e-3)


def test_blockwise_attention_equals_ref():
    cfg = get_config("starcoder2-7b", reduced=True)
    model_ref = build_model(cfg.with_(attention_impl="ref"))
    model_blk = build_model(cfg.with_(attention_impl="blockwise"))
    params = model_ref.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)
    ref = model_ref.forward(params, toks)[0]
    blk = model_blk.forward(params, toks)[0]
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_chunked_ce_equals_dense():
    cfg = get_config("codeqwen1.5-7b", reduced=True)
    model_d = build_model(cfg.with_(ce_impl="dense"))
    model_c = build_model(cfg.with_(ce_impl="chunked"))
    params = model_d.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    ld, _ = model_d.loss_fn(params, batch)
    lc, _ = model_c.loss_fn(params, batch)
    np.testing.assert_allclose(float(ld), float(lc), rtol=1e-5)


def test_scan_vs_unrolled_layers_identical():
    cfg = get_config("granite-34b", reduced=True)
    m_scan = build_model(cfg.with_(scan_layers=True))
    m_unroll = build_model(cfg.with_(scan_layers=False))
    params_scan = m_scan.init(jax.random.PRNGKey(0))
    # rearrange stacked params into unrolled structure
    structs_unroll = m_unroll.abstract_params()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out_scan = m_scan.forward(params_scan, toks)[0]

    def unstack(tree, n):
        return [jax.tree.map(lambda a: a[i], tree) for i in range(n)]

    stages = params_scan["stages"]
    unrolled_stages = []
    for s_params, stage in zip(stages, m_unroll.stages):
        layers = unstack(s_params, stage.repeats)
        unrolled_stages.append({f"r{i}": layers[i]
                                for i in range(stage.repeats)})
    params_unroll = dict(params_scan, stages=unrolled_stages)
    out_unroll = m_unroll.forward(params_unroll, toks)[0]
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_unroll),
                               rtol=1e-4, atol=1e-4)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark
    (via metas only — no allocation)."""
    expect = {"deepseek_v3_671b": (600e9, 760e9),
              "deepseek_v2_lite_16b": (14e9, 18e9),
              "gemma3_27b": (24e9, 30e9),
              "starcoder2_7b": (6e9, 8.5e9),
              "granite_34b": (30e9, 38e9),
              "codeqwen15_7b": (6e9, 8.5e9),
              "mamba2_370m": (0.3e9, 0.45e9),
              "jamba_v01_52b": (45e9, 58e9),
              "whisper_medium": (0.6e9, 0.9e9),  # 24+24 layers, ~769M real
              "paligemma_3b": (2e9, 3.5e9)}
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        n = tree_params_count(model.abstract_params())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}," \
                              f" {hi/1e9}]B"


def test_fp8_kv_cache_decode_quality():
    """fp8 cache: top-1 agreement with full-precision-cache decode on the
    reduced config (random weights = worst case for quantization noise).

    The cache stores a per-position per-head scale next to the fp8
    values and dequantizes inside cache attention (the raw-cast path
    reached only ~0.95 cosine); the token being decoded attends its own
    K/V exactly (quantization is storage-only).  The cosine bound is
    0.97, not higher: e4m3's 3-bit mantissa floors mean round-trip
    relative error at ~2%, which caps the random-weight worst case near
    0.976 — top-1 agreement, the serving-relevant property, is exact.
    """
    cfg_b = get_config("granite-34b", reduced=True)
    cfg_8 = cfg_b.with_(cache_dtype="fp8")
    mb, m8 = build_model(cfg_b), build_model(cfg_8)
    params = mb.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                              cfg_b.vocab_size)
    _, cb = mb.prefill(params, toks[:, :-1], max_seq=16)
    _, c8 = m8.prefill(params, toks[:, :-1], max_seq=16)
    lb, _ = mb.decode_step(params, cb, toks[:, -1:], jnp.int32(11))
    l8, _ = m8.decode_step(params, c8, toks[:, -1:], jnp.int32(11))
    cos = float((lb * l8).sum()
                / (jnp.linalg.norm(lb) * jnp.linalg.norm(l8)))
    assert cos > 0.97, cos
    assert bool((jnp.argmax(lb, -1) == jnp.argmax(l8, -1)).all())
    # fp8 cache really is fp8, and carries its dequantization scales
    assert any(leaf.dtype == jnp.float8_e4m3fn
               for leaf in jax.tree.leaves(c8))
    paths = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_leaves_with_path(c8)]
    assert any("k_scale" in p for p in paths)
