"""ServeEngine decode positions: slots admitted mid-flight must decode at
their own position, not the batch max (regression for the shared-`pos`
bug), and the engine must source kernel overrides from the Session."""

import jax
import jax.numpy as jnp
import pytest

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine


def _tiny_model():
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _decode_alone(model, params, prompt, max_new=8, max_seq=32):
    eng = ServeEngine(model, params, batch_slots=1, max_seq=max_seq)
    eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=max_new))
    (done,) = eng.run_until_done()
    return done.generated


def test_staggered_admissions_decode_at_per_slot_positions():
    """3 requests with different prompt lengths through 2 slots — the
    third is admitted mid-flight once a slot frees.  Greedy decoding must
    match each request decoded alone; with the old shared
    ``pos = slot_pos.max()`` the staggered slots attend at wrong depths
    and diverge."""
    model, params = _tiny_model()
    prompts = [[3, 1, 4, 1, 5], [9, 2], [5, 3, 5, 8, 9, 7, 2]]
    ref = {uid: _decode_alone(model, params, p)
           for uid, p in enumerate(prompts)}

    eng = ServeEngine(model, params, batch_slots=2, max_seq=32)
    eng.submit(Request(uid=0, prompt=list(prompts[0]), max_new_tokens=8))
    eng.submit(Request(uid=1, prompt=list(prompts[1]), max_new_tokens=8))
    eng.step()
    eng.step()
    # slots now sit at different depths; admit another mid-flight
    eng.submit(Request(uid=2, prompt=list(prompts[2]), max_new_tokens=8))
    done = {r.uid: r.generated for r in eng.run_until_done()}

    assert set(done) == {0, 1, 2}
    for uid, generated in done.items():
        assert generated == ref[uid], (
            f"request {uid} diverged under staggered batching: "
            f"{generated} != {ref[uid]}")


def test_slot_recycling_preserves_isolation():
    """A request admitted into a *recycled* slot must not see leftovers
    from the previous occupant's cache."""
    model, params = _tiny_model()
    first = [7, 8, 9, 10, 11, 12]
    second = [4, 2]
    ref = _decode_alone(model, params, second, max_new=6)

    eng = ServeEngine(model, params, batch_slots=1, max_seq=32)
    eng.submit(Request(uid=0, prompt=list(first), max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=list(second), max_new_tokens=6))
    done = {r.uid: r.generated for r in eng.run_until_done()}
    assert done[1] == ref


def test_engine_reads_decode_attention_from_session():
    from repro.models.attention import plain_cache_attention

    model, params = _tiny_model()
    hits = []

    def attend(q, k, v, valid, *, scale, cap=0.0):
        hits.append(1)
        return plain_cache_attention(q, k, v, valid, scale=scale, cap=cap)

    with repro.session(kernels={"decode_attention": attend},
                       tag="serve-test") as sess:
        eng = ServeEngine(model, params, batch_slots=1, max_seq=16)
        assert eng.session is sess
        assert eng.session.describe()["tag"] == "serve-test"
    # the session was snapshotted at construction; stepping outside the
    # scope still uses its kernels
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng.run_until_done()
    assert hits, "engine did not route decode through the session kernel"


def test_ambient_session_does_not_leak_into_compiled_decode():
    """The engine pins its construction-time session snapshot while
    tracing: a kernels override merely ambient at the first step() must
    not get baked into the jitted decode (describe() provenance and
    behavior would disagree)."""
    from repro.models.attention import plain_cache_attention

    model, params = _tiny_model()
    eng = ServeEngine(model, params, batch_slots=1, max_seq=16)
    hits = []

    def attend(q, k, v, valid, *, scale, cap=0.0):
        hits.append(1)
        return plain_cache_attention(q, k, v, valid, scale=scale, cap=cap)

    eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
    with repro.session(kernels={"decode_attention": attend}):
        eng.step()  # first step: jit traces here
    eng.run_until_done()
    assert not hits, "ambient session leaked into the compiled decode"


def test_admission_assigns_slots_ascending_in_arrival_order():
    """Queue hygiene: FIFO admission must fill free slots in ascending
    order (the old engine popped free slots in *descending* order, so
    traces depended on slot-set iteration quirks)."""
    model, params = _tiny_model()
    eng = ServeEngine(model, params, batch_slots=3, max_seq=16)
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=[uid + 1, 2], max_new_tokens=4))
    eng.step()
    assert {slot: r.uid for slot, r in eng.active.items()} == {0: 0, 1: 1,
                                                               2: 2}
    assert eng.waiting == 0


def test_engine_attend_fn_kwarg_deprecated():
    model, params = _tiny_model()
    with pytest.deprecated_call():
        ServeEngine(model, params, batch_slots=1, max_seq=16,
                    attend_fn=lambda q, k, v, valid, *, scale, cap=0.0: q)
