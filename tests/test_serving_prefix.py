"""Prefix-sharing paged KV cache + multi-replica router.

Sharing must be invisible in the tokens: admissions that map cached
blocks out of the radix tree (full-block and partial-block/COW matches,
preempt-and-requeue, retained cross-round hits) decode bit-identically
to the sharing-off paged path and to dense — while measurably skipping
prefill work.  The router half: placement policies, the ``serve()``
stream front door, and routed output == single-engine output.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.configs.base import get_config
from repro.models import build_model
from repro.runtime import AnalysisPolicy, PrefixPolicy, ServingPolicy
from repro.serving import (PrefixIndex, Request, Router, ServeEngine,
                           make_routing, serve, timed_stream)

SYS = [7, 3, 11, 5, 2, 13, 17, 1, 9, 4, 23, 6, 29, 8, 31, 10,
       12, 37, 14, 41, 15, 43, 16, 47, 18, 53, 19, 59, 20, 61, 21, 22]


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("codeqwen1.5-7b", reduced=True, n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _run(model, params, policy, prompts, max_new=6, slots=4, max_seq=64,
         stagger=True):
    eng = ServeEngine(model, params, batch_slots=slots, max_seq=max_seq,
                      policy=policy)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    if stagger:
        eng.submit(reqs[0])
        eng.step()
        eng.step()
        for r in reqs[1:]:
            eng.submit(r)
    else:
        for r in reqs:
            eng.submit(r)
    done = {r.uid: r.generated for r in eng.run_until_done()}
    return done, eng


PAGED = ServingPolicy(cache="paged", block_size=8, prefill_chunk=8)


def test_shared_prefix_identical_to_dense_and_sharing_off(tiny):
    """The tentpole regression: admissions sharing a 32-token system
    prompt must decode token-identically to dense and to sharing-off
    paged — while actually skipping prefill for the shared blocks."""
    model, params = tiny
    prompts = [SYS + [40 + i, 50 + i, 33 + i] for i in range(4)]
    with repro.session(analysis=AnalysisPolicy(level="strict")):
        dense, _ = _run(model, params,
                        ServingPolicy(cache="dense", prefill_chunk=8),
                        prompts)
        off, eoff = _run(model, params, PAGED, prompts)
        on, eon = _run(model, params, PAGED.replace(prefix=True), prompts)
    assert dense == off == on
    assert eoff.prefill_tokens_saved == 0
    # later admissions skip the shared full blocks (32 = 4 x block 8)
    assert eon.prefill_tokens_saved >= 3 * 32
    assert eon.shared_admissions == 3
    # all references drained: no slot blocks, tree clears to zero
    assert eon.kv.blocks_in_use == 0
    eon.kv.clear_prefix()
    assert eon.kv.refcount == {}
    assert not eon.kv.audit().diagnostics


def test_sharing_degrades_silently_on_window_model():
    """Sliding-window layers keep per-slot dense ring caches that a
    skipped prefill would leave unfilled — requesting sharing on such a
    model must silently degrade to shared_len=0, not corrupt decoding."""
    cfg = get_config("gemma3-27b", reduced=True)   # window 16 interleave
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert not model.supports_prefix_sharing()
    prompts = [SYS[:20] + [40 + i] for i in range(3)]
    pol = ServingPolicy(cache="paged", block_size=8, prefill_chunk=5)
    off, _ = _run(model, params, pol, prompts, max_new=5, max_seq=48)
    on, eng = _run(model, params, pol.replace(prefix=True), prompts,
                   max_new=5, max_seq=48)
    assert not eng.prefix_on
    assert eng.prefill_tokens_saved == 0
    assert off == on


def test_cow_on_first_divergent_token(tiny):
    """A fully cached prompt ending mid-block writes its first generated
    token into the still-shared block — that write must copy-on-write
    (exactly once) and decoding must match the sharing-off path."""
    model, params = tiny
    A = [(3 * i + 1) % 60 + 1 for i in range(18)]   # 4 full blocks at bs=4
    C = A[:14]                                      # cached incl. partial
    pol = ServingPolicy(cache="paged", block_size=4, prefill_chunk=4,
                        prefix=True)

    def pair(policy):
        eng = ServeEngine(model, params, batch_slots=2, max_seq=32,
                          policy=policy)
        eng.submit(Request(uid=0, prompt=list(A), max_new_tokens=4))
        eng.run_until_done()
        eng.submit(Request(uid=1, prompt=list(C), max_new_tokens=4))
        done = {r.uid: r.generated for r in eng.run_until_done()}
        return done, eng

    with repro.session(analysis=AnalysisPolicy(level="strict")):
        on, eon = pair(pol)
        off, _ = pair(pol.replace(prefix=False))
    assert on == off
    # whole prompt came out of the tree; the divergent decode write COWed
    assert eon.prefill_tokens_saved >= len(C) - 1
    assert eon.kv.cow_copies == 1


def test_cow_on_divergent_prefill_write(tiny):
    """A prompt sharing a *partial* block (prefix overlap shorter than
    the block) diverges inside it during prefill — COW before the
    tokens land, identical output."""
    model, params = tiny
    A = [(5 * i + 2) % 60 + 1 for i in range(18)]
    B = A[:14] + [33, 44]                # diverges at pos 14, block 3
    pol = ServingPolicy(cache="paged", block_size=4, prefill_chunk=4,
                        prefix=True)

    def pair(policy):
        eng = ServeEngine(model, params, batch_slots=2, max_seq=32,
                          policy=policy)
        eng.submit(Request(uid=0, prompt=list(A), max_new_tokens=4))
        eng.run_until_done()
        eng.submit(Request(uid=1, prompt=list(B), max_new_tokens=4))
        done = {r.uid: r.generated for r in eng.run_until_done()}
        return done, eng

    with repro.session(analysis=AnalysisPolicy(level="strict")):
        on, eon = pair(pol)
        off, _ = pair(pol.replace(prefix=False))
    assert on == off
    assert eon.kv.cow_copies == 1
    # partial=False restricts matches to whole blocks: no COW needed
    strict_blocks, es = pair(pol.replace(
        prefix=PrefixPolicy(enabled=True, partial=False)))
    assert strict_blocks == off
    assert es.kv.cow_copies == 0


def test_refcounts_return_to_zero_after_all_releases(tiny):
    """Every admission increfs shared blocks; finish/preempt decrefs.
    After all requests drain and the tree is cleared, the refcount map
    must be empty and the allocator must hold only the trash block."""
    model, params = tiny
    prompts = [SYS + [40 + i] for i in range(5)]
    on, eng = _run(model, params, PAGED.replace(prefix=True), prompts,
                   slots=3)
    assert len(on) == 5
    assert eng.kv.blocks_in_use == 0
    assert all(c == 1 for c in eng.kv.refcount.values())  # tree-only refs
    eng.kv.clear_prefix()
    assert eng.kv.refcount == {}
    assert not eng.kv.audit().diagnostics
    # retain=False drops tree references as requests finish
    on2, eng2 = _run(model, params, PAGED.replace(
        prefix=PrefixPolicy(enabled=True, retain=False)), prompts, slots=3)
    assert on2 == on
    assert eng2.kv.refcount == {}


def test_preempt_and_requeue_token_identical_with_sharing(tiny):
    """The satellite regression: preemption victims holding shared
    blocks must only decref them, and the requeued request re-admits
    through the radix tree — same tokens as the uncontended run."""
    model, params = tiny
    prompts = [SYS[:16] + [40 + i, 50 + i, 33 + i] for i in range(4)]
    base = dict(cache="paged", block_size=4, prefill_chunk=8,
                num_blocks=13)                      # tight pool: preempts
    with repro.session(analysis=AnalysisPolicy(level="strict")):
        off, eoff = _run(model, params, ServingPolicy(**base, prefix=False),
                         prompts, max_new=10, slots=3, stagger=False)
        on, eon = _run(model, params, ServingPolicy(**base, prefix=True),
                       prompts, max_new=10, slots=3, stagger=False)
    assert off == on
    assert eon.preemptions + eoff.preemptions > 0   # pressure actually hit
    assert eon.kv.blocks_in_use == 0
    eon.kv.clear_prefix()
    assert eon.kv.refcount == {}


@settings(max_examples=12, deadline=None)
@given(specs=st.lists(
           st.tuples(st.integers(min_value=0, max_value=24),
                     st.lists(st.integers(min_value=1, max_value=60),
                              min_size=1, max_size=8)),
           min_size=2, max_size=5),
       seed=st.integers(min_value=1, max_value=30))
def test_random_prefix_overlaps_match_sharing_off(tiny_cached, specs, seed):
    """Property: for random families of prompts overlapping a random
    common stem at random depths, sharing-on decodes exactly what
    sharing-off decodes."""
    model, params = tiny_cached
    rng = np.random.default_rng(seed)
    stem = list(rng.integers(1, 60, size=24))
    prompts = [stem[:cut] + list(tail) for cut, tail in specs]
    pol = ServingPolicy(cache="paged", block_size=4, prefill_chunk=4)
    off, _ = _run(model, params, pol, prompts, max_new=4, slots=3,
                  stagger=False)
    on, eng = _run(model, params, pol.replace(prefix=True), prompts,
                   max_new=4, slots=3, stagger=False)
    assert off == on
    assert not eng.kv.audit().diagnostics


@pytest.fixture(scope="module")
def tiny_cached(tiny):
    # hypothesis re-runs the test body; reuse the module model
    return tiny


# -- router / serve() --------------------------------------------------------


def test_routed_output_matches_single_engine(tiny):
    """Two replicas behind the router must produce exactly the tokens a
    single engine produces for the same requests."""
    model, params = tiny
    prompts = [SYS + [40 + i, 50 + i] for i in range(6)]
    pol = PAGED.replace(prefix=True, routing="prefix_affinity")
    single, _ = _run(model, params, pol, prompts, stagger=False)
    router = Router([ServeEngine(model, params, batch_slots=4, max_seq=64,
                                 policy=pol) for _ in range(2)])
    for i, p in enumerate(prompts):
        router.submit(Request(uid=i, prompt=list(p), max_new_tokens=6))
    routed = {r.uid: r.generated for r in router.run_until_done()}
    assert routed == single
    d = router.describe()
    assert d["replicas"] == 2 and d["routing"] == "prefix_affinity"
    assert set(d["placement"]) == set(range(6))


def test_prefix_affinity_routes_to_warm_replica(tiny):
    """Once one replica has cached the system prompt, later arrivals
    with the same prefix must land on it (longest radix match), while
    cold prompts fall back to least-loaded."""
    model, params = tiny
    pol = PAGED.replace(prefix=True, routing="prefix_affinity")
    router = Router([ServeEngine(model, params, batch_slots=4, max_seq=64,
                                 policy=pol) for _ in range(2)])
    first = router.submit(Request(uid=0, prompt=SYS + [40],
                                  max_new_tokens=3))
    router.run_until_done()                 # replica `first` is now warm
    for i in range(1, 4):
        assert router.submit(Request(uid=i, prompt=SYS + [40 + i],
                                     max_new_tokens=3)) == first
    # a prompt with no cached prefix balances away from the loaded replica
    cold = router.submit(Request(uid=9, prompt=[60, 61, 62],
                                 max_new_tokens=3))
    assert cold != first
    router.run_until_done()


def test_round_robin_and_least_loaded_placement(tiny):
    model, params = tiny
    engines = [ServeEngine(model, params, batch_slots=2, max_seq=32,
                           policy=PAGED) for _ in range(3)]
    rr = Router(engines, routing="round_robin")
    got = [rr.submit(Request(uid=i, prompt=[1 + i], max_new_tokens=2))
           for i in range(5)]
    assert got == [0, 1, 2, 0, 1]
    rr.run_until_done()
    ll = make_routing("least_loaded")
    engines[0].submit(Request(uid=90, prompt=[5], max_new_tokens=2))
    assert ll.route(Request(uid=91, prompt=[6]), engines) == 1
    engines[0].run_until_done()
    with pytest.raises(ValueError):
        make_routing("nope")
    with pytest.raises(TypeError):
        make_routing(123)


def test_serve_stream_front_door(tiny):
    """serve(): timed-iterator arrivals admitted continuously across
    engine steps, finished requests yielded as they complete, output
    identical to a single pre-staged engine."""
    model, params = tiny
    prompts = [SYS[:12] + [40 + i] for i in range(5)]
    pol = PAGED.replace(prefix=True)
    single, _ = _run(model, params, pol, prompts, max_new=4, stagger=False)
    trace = [(2 * i, Request(uid=i, prompt=list(p), max_new_tokens=4))
             for i, p in enumerate(prompts)]
    with repro.session(serving=pol):
        got = {r.uid: r.generated
               for r in serve(model, params, timed_stream(trace),
                              replicas=2, batch_slots=4, max_seq=64)}
    assert got == single
    # callable arrivals: one request per tick, then exhausted
    with repro.session(serving=pol):
        def arrivals(tick):
            if tick < len(prompts):
                return Request(uid=tick, prompt=list(prompts[tick]),
                               max_new_tokens=4)
            return None
        got2 = {r.uid: r.generated
                for r in serve(model, params, arrivals, replicas=3,
                               batch_slots=2, max_seq=64)}
    assert got2 == single


# -- prefix index unit behavior ----------------------------------------------


def test_prefix_index_match_insert_evict():
    idx = PrefixIndex(4)
    created = idx.insert(list(range(1, 13)), [5, 6, 7])
    assert [n.block for n in created] == [5, 6, 7]
    # non-ready nodes: full-block walk matches, partial does not
    nodes, m = idx.match(list(range(1, 11)))
    assert m == 8 and [n.block for n in nodes] == [5, 6]
    for n in created:
        n.ready = True
    nodes, m = idx.match(list(range(1, 11)))
    assert m == 10 and nodes[-1].block == 7      # partial tail overlap 2
    assert idx.match_len(list(range(1, 13))) == 12
    assert idx.match([9, 9, 9]) == ([], 0)
    # dedupe: re-inserting an existing span creates nothing
    assert idx.insert(list(range(1, 9)), [9, 9]) == []
    # LRU eviction only touches leaves the refcount marks tree-only
    refcount = {5: 2, 6: 1, 7: 1}
    freed = idx.evict(lambda b: refcount.get(b, 0) == 1, limit=8)
    assert freed == [7, 6] and idx.blocks() == {5}
    assert len(idx) == 1


def test_prefix_policy_in_session_describe(tiny):
    """Opt-in provenance: PrefixPolicy and routing land in
    Session.describe() like every other serving knob."""
    model, params = tiny
    pol = ServingPolicy(cache="paged", prefix={"enabled": True,
                                               "retain": False},
                        routing="prefix_affinity")
    with repro.session(serving=pol):
        eng = ServeEngine(model, params, batch_slots=1, max_seq=32)
    d = eng.session.describe()["serving"]
    assert d["prefix"] == {"enabled": True, "retain": False,
                           "partial": True}
    assert d["routing"] == "prefix_affinity"
    assert eng.describe()["prefix_sharing"] is True
    # bare-bool coercion
    assert ServingPolicy(prefix=True).prefix == PrefixPolicy(enabled=True)
