"""Tape autograd: oracle (jax.grad) equivalence, §5.2.1 customizations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autograd as ag
from repro.core.autograd import functions as F
from repro.core.tensor import ops


def _tree_allclose(a, b, rtol=1e-4, atol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=rtol, atol=atol), a, b)


UNARY = {
    "exp": (F.exp, jnp.exp),
    "tanh": (F.tanh, jnp.tanh),
    "relu": (F.relu, jax.nn.relu),
    "sigmoid": (F.sigmoid, jax.nn.sigmoid),
    "neg": (F.neg, jnp.negative),
    "gelu": (F.gelu, None),
    "silu": (F.silu, None),
}


@settings(max_examples=30, deadline=None)
@given(
    ops_seq=st.lists(st.sampled_from(sorted(UNARY)), min_size=1, max_size=5),
    rows=st.integers(2, 6), cols=st.integers(2, 6), seed=st.integers(0, 99),
)
def test_tape_matches_jax_grad_on_random_chains(ops_seq, rows, cols, seed):
    """Property: for random unary-op chains over a matmul, the tape's
    gradients equal jax.grad's."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (cols, rows)) * 0.5

    def tape_loss(params):
        h = F.matmul(ag.Variable(x), params["w"])
        for name in ops_seq:
            h = UNARY[name][0](h)
        return F.mean(F.mul(h, h))

    def jax_loss(params):
        h = x @ params["w"]
        for name in ops_seq:
            fn = UNARY[name][1]
            if fn is None:
                fn = {"gelu": lambda v: jax.nn.gelu(v, approximate=False),
                      "silu": jax.nn.silu}[name]
            h = fn(h)
        return jnp.mean(h * h)

    val, grads = ag.value_and_grad(tape_loss)({"w": w})
    jval, jgrads = jax.value_and_grad(jax_loss)({"w": w})
    np.testing.assert_allclose(val, jval, rtol=1e-4, atol=1e-6)
    _tree_allclose(grads, jgrads)


@pytest.mark.parametrize("reduction", ["sum", "mean", "max"])
def test_reductions_and_shape_ops(reduction):
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 5))

    def tape_loss(p):
        h = F.transpose(F.reshape(p["x"], (3, 20)), (1, 0))
        r = getattr(F, reduction)(h, axis=0)
        return F.sum(F.mul(r, r))

    def jax_loss(p):
        h = p["x"].reshape(3, 20).T
        r = getattr(jnp, reduction)(h, axis=0)
        return jnp.sum(r * r)

    val, grads = ag.value_and_grad(tape_loss)({"x": x})
    jval, jgrads = jax.value_and_grad(jax_loss)({"x": x})
    np.testing.assert_allclose(val, jval, rtol=1e-5)
    _tree_allclose(grads, jgrads)


def test_broadcasting_binary_grads():
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 1, 3))
    b = jax.random.normal(jax.random.PRNGKey(1), (5, 3))

    for tape_op, jax_op in [(F.add, jnp.add), (F.mul, jnp.multiply),
                            (F.sub, jnp.subtract), (F.div, jnp.divide),
                            (F.maximum, jnp.maximum)]:
        val, grads = ag.value_and_grad(
            lambda p: F.sum(tape_op(p["a"], p["b"])))({"a": a, "b": b})
        jval, jgrads = jax.value_and_grad(
            lambda p: jnp.sum(jax_op(p["a"], p["b"])))({"a": a, "b": b})
        np.testing.assert_allclose(val, jval, rtol=1e-5)
        _tree_allclose(grads, jgrads)


def test_softmax_logsoftmax_ce_grads():
    logits = jax.random.normal(jax.random.PRNGKey(2), (6, 10))
    labels = jnp.arange(6) % 10

    val, grads = ag.value_and_grad(
        lambda p: F.cross_entropy(p["l"], labels))({"l": logits})
    jval, jgrads = jax.value_and_grad(
        lambda p: -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(p["l"]), labels[:, None], 1)))({"l": logits})
    np.testing.assert_allclose(val, jval, rtol=1e-5)
    _tree_allclose(grads, jgrads)


def test_tape_under_jit_and_scanless_stack():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))

    def loss(p):
        h = F.relu(F.matmul(ag.Variable(x), p["w1"]))
        return F.mean(F.mul(h, h))

    params = {"w1": jax.random.normal(jax.random.PRNGKey(1), (16, 8))}
    eager = ag.value_and_grad(loss)(params)
    jitted = jax.jit(ag.value_and_grad(loss))(params)
    _tree_allclose(eager, jitted)


def test_graph_pruning_cuts_subtrees():
    """§5.2.1 on-the-fly pruning: cut gradient flow into a named subtree."""
    x = ag.Variable(jnp.ones((4,)), requires_grad=True)
    y = ag.Variable(jnp.ones((4,)), requires_grad=True)
    pruned = F.exp(x)                    # this branch will be pruned
    kept = F.mul(y, y)
    out = F.sum(F.add(pruned, kept))
    out.backward(prune=lambda node: node.name == "exp")
    assert x.grad is None                # flow into exp subtree was cut
    np.testing.assert_allclose(np.asarray(y.grad), 2 * np.ones(4))


def test_fused_composite_is_one_node():
    """§5.2.1 pre-fused gradients: composite records a single tape node."""
    x = ag.Variable(jnp.ones((8,)) * 0.3, requires_grad=True)

    def composite(v):
        return ops.mul(ops.tanh(v), ops.exp(v))

    fused = ag.fused(composite, name="tanh_exp")(x)
    assert ag.tape_size(fused) == 1
    unfused = F.mul(F.tanh(x), F.exp(x))
    assert ag.tape_size(unfused) == 3
    loss_f = F.sum(fused)
    loss_f.backward()
    gf = np.asarray(x.grad)
    x.zero_grad()
    F.sum(unfused).backward()
    np.testing.assert_allclose(gf, np.asarray(x.grad), rtol=1e-5)


def test_free_on_use_node_lifetime():
    """§5.2.1 custom node lifetime: consumed nodes refuse reuse."""
    x = ag.Variable(jnp.ones((4,)), requires_grad=True)
    y = F.sum(F.exp(x))
    y.backward(free_on_use=True)
    with pytest.raises(RuntimeError, match="consumed"):
        y.backward()
    # retain_graph equivalent
    x2 = ag.Variable(jnp.ones((4,)), requires_grad=True)
    y2 = F.sum(F.exp(x2))
    y2.backward(free_on_use=False)
    y2.backward(free_on_use=False)  # fine


def test_no_grad_and_detach():
    x = ag.Variable(jnp.ones((4,)), requires_grad=True)
    with ag.no_grad():
        y = F.mul(x, x)
    assert y.node is None
    z = F.mul(x.detach(), x.detach())
    assert z.node is None


def test_grad_accumulation_across_backwards():
    x = ag.Variable(jnp.ones((3,)), requires_grad=True)
    F.sum(F.mul(x, x)).backward()
    g1 = np.asarray(x.grad)
    F.sum(F.mul(x, x)).backward()   # accumulates
    np.testing.assert_allclose(np.asarray(x.grad), 2 * g1)
