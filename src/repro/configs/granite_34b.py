"""Granite 34B code [arXiv:2405.04324; hf].

Assignment spec: 88L d_model=6144 48H (kv=1, MQA) d_ff=24576 vocab=49152.
head_dim = 6144/48 = 128.  The assignment note says "llama-arch", but with
a gated (3-matrix) MLP these dims give ~47B params; the 34B total is only
consistent with GPTBigCode's non-gated 2-matrix MLP (which is also what
hf:ibm-granite/granite-34b-code-base ships: GPTBigCode + MQA).  We follow
the parameter-count-consistent reading: LayerNorm + non-gated GELU MLP
(33.8B params).  kv=1 means the kv-head axis cannot shard over the model
axis — the rules engine replicates it and decode uses sequence-sharded
flash-decoding instead (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152,
        rope_theta=10000.0, norm="layernorm", act="gelu",
        source="arXiv:2405.04324 + hf:ibm-granite/granite-34b-code-base",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="granite-34b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=1,
        d_ff=128, vocab_size=512,
        rope_theta=10000.0, norm="rmsnorm", act="silu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
