"""Jamba v0.1 52B [arXiv:2403.19887; hf].

Assignment spec: 32L d_model=4096 32H (kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2, Mamba+attn 1:7 interleave.  Structure: 4 blocks of 8 layers
(attention at offset 4), MoE every 2nd layer.  DEVIATION (DESIGN.md §5):
mamba sublayers use our Mamba-2/SSD block (d_state=16 as Jamba, head_dim
64) rather than Mamba-1's selective scan — SSD is the TPU-native (matmul)
formulation of the same state-space family.
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        hybrid_pattern="MMMMAMMM",
        moe=MoEConfig(n_routed=16, n_shared=0, top_k=2, d_expert=14336,
                      first_k_dense=0, every=2),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      chunk=256),
        rope_theta=10000.0, norm="rmsnorm", act="silu",
        source="arXiv:2403.19887 + hf:ai21labs/Jamba-v0.1 (SSD deviation)",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="jamba-v0.1-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        hybrid_pattern="MMMMAMMM",
        moe=MoEConfig(n_routed=4, n_shared=0, top_k=2, d_expert=128,
                      first_k_dense=0, every=2, capacity_factor=2.0),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                      chunk=16),
        rope_theta=10000.0, norm="rmsnorm", act="silu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
