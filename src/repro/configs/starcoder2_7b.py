"""StarCoder2 7B [arXiv:2402.19173; hf].

Assignment spec: 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152,
GQA + RoPE.  head_dim = 4608/36 = 128.  StarCoder2 uses non-gated
GELU MLP + LayerNorm.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab_size=49152,
        rope_theta=100000.0, norm="layernorm", act="gelu",
        source="arXiv:2402.19173 + hf:bigcode/starcoder2-7b",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="starcoder2-7b-smoke", family="dense",
        n_layers=3, d_model=72, n_heads=6, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        rope_theta=100000.0, norm="layernorm", act="gelu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
