"""Mamba-2 370M [arXiv:2405.21060].

Assignment spec: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD.  Mamba-2 defaults: expand=2 (d_inner=2048),
head_dim=64 (32 SSD heads), d_conv=4, chunk=256.  Attention-free, so all
decode shapes including long_500k run — decode is O(1)-state.
"""

from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=32, n_kv_heads=32,
        d_ff=0, vocab_size=50280,
        attention="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk=256),
        norm="rmsnorm", act="silu", tie_embeddings=True,
        source="arXiv:2405.21060 (SSD defaults)",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="mamba2-370m-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=512,
        attention="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                      chunk=16),
        norm="rmsnorm", act="silu", tie_embeddings=True,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
