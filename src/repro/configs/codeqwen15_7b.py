"""CodeQwen1.5 7B [hf:Qwen/CodeQwen1.5-7B].

Assignment spec: 32L d_model=4096 32H (kv=32 — full MHA) d_ff=13440
vocab=92416, qwen1.5-arch: RMSNorm + gated SiLU; rope_theta=1e6 for the
64k context window.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab_size=92416,
        rope_theta=1000000.0, norm="rmsnorm", act="silu",
        source="hf:Qwen/CodeQwen1.5-7B",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="codeqwen1.5-7b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        rope_theta=1000000.0, norm="rmsnorm", act="silu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
