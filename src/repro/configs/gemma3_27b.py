"""Gemma-3 27B [hf:google/gemma-3-27b-pt pattern; assignment-verified tier:
unverified].

Assignment spec: 62L d_model=5376 32H (kv=16) d_ff=21504 vocab=262144,
5 local:1 global interleave, 128k context.  Gaps from the gemma family:
head_dim=128 (decoupled from d_model), sliding window 1024, gated-GELU MLP,
tied embeddings.  Single rope_theta (gemma3's dual local/global theta noted
as a deviation in DESIGN.md).  62 = 10 full (5L+1G) groups + 2 trailing
local layers — the stage planner scans the 10 groups and unrolls the tail.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
        rope_theta=10000.0, norm="rmsnorm", act="geglu",
        tie_embeddings=True,
        source="hf:google/gemma-3-27b-pt (family-pattern fill-ins)",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="gemma3-27b-smoke", family="dense",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        window_pattern=(16, 16, 16, 16, 16, 0),
        rope_theta=10000.0, norm="rmsnorm", act="geglu",
        tie_embeddings=True,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
