"""PaLiGemma 3B [arXiv:2407.07726; hf].

Assignment spec: 18L d_model=2048 8H (kv=1) d_ff=16384 vocab=257216,
SigLIP + gemma.  The SigLIP vision tower is a STUB: ``input_specs()``
supplies 256 precomputed patch embeddings [B, 256, d_model] which the
model prepends as a bidirectionally-visible prefix (prefix-LM masking, as
PaLI).  Gemma-2b fill-ins: head_dim=256, gated-GELU, tied embeddings.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=257216,
        num_image_tokens=256,
        rope_theta=10000.0, norm="rmsnorm", act="geglu",
        tie_embeddings=True,
        source="arXiv:2407.07726 + hf:google/paligemma-3b-pt-224",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="paligemma-3b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512,
        num_image_tokens=8,
        rope_theta=10000.0, norm="rmsnorm", act="geglu",
        tie_embeddings=True,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
