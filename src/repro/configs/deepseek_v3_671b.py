"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

Assignment spec: 61L d_model=7168 128H d_ff=2048 vocab=129280, MoE 256e
top-8, MLA, 1 shared + 256 routed, MTP.  Gaps filled from the HF config:
first 3 layers dense with ff=18432 (the assignment's d_ff=2048 is the
routed-expert intermediate size), MLA ranks q_lora=1536 / kv_lora=512 /
qk_nope=128 / qk_rope=64 / v_head=128.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        attention="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_expert=2048,
                      first_k_dense=3, every=1),
        rope_theta=10000.0, norm="rmsnorm", act="silu", mtp_depth=1,
        source="arXiv:2412.19437 + hf:deepseek-ai/DeepSeek-V3",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_expert=32,
                      first_k_dense=1, every=1, capacity_factor=2.0),
        rope_theta=10000.0, norm="rmsnorm", act="silu", mtp_depth=1,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
