"""Whisper medium [arXiv:2212.04356].

Assignment spec: 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865,
enc-dec, conv frontend STUB.  Real whisper-medium is 24 encoder + 24
decoder layers; ``input_specs()`` supplies precomputed frame embeddings
(batch, seq/2, d_model) and the decoder sees seq/2 tokens so total
positions per cell = seq_len (DESIGN.md §5).  RoPE replaces whisper's
learned/sinusoidal positions (shape-independence; documented deviation).
Shapes beyond whisper's trained 1.5k/448 positions are architectural
stress configs.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, encoder_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865,
        rope_theta=10000.0, norm="layernorm", act="gelu",
        source="arXiv:2212.04356 (24+24 layers; RoPE deviation)",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="whisper-medium-smoke", family="encdec",
        n_layers=2, encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        rope_theta=10000.0, norm="layernorm", act="gelu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
