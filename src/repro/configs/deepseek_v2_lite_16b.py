"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf].

Assignment spec: 27L d_model=2048 16H d_ff=1408 vocab=102400, MoE 64e
top-6, MLA kv_lora=512, 2 shared.  (The bracket's "160 routed" is the
V2-236B figure; the primary "MoE 64e" wins — HF config confirms 64 routed
experts for Lite.)  Gaps from HF: layer 0 dense with ff=10944, no q-lora,
qk_nope=128 / qk_rope=64 / v_head=128.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab_size=102400,
        attention="mla",
        mla=MLAConfig(q_lora_rank=None, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                      first_k_dense=1, every=1),
        rope_theta=10000.0, norm="rmsnorm", act="silu",
        source="arXiv:2405.04434 + hf:deepseek-ai/DeepSeek-V2-Lite",
    )


def reduced_config() -> ModelConfig:
    import jax.numpy as jnp

    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        attention="mla",
        mla=MLAConfig(q_lora_rank=None, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=32,
                      first_k_dense=1, every=1, capacity_factor=2.0),
        rope_theta=10000.0, norm="rmsnorm", act="silu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
