"""Model configuration system + architecture registry.

Every assigned architecture is a ``ModelConfig`` in ``configs/<id>.py``,
selectable by ``--arch <id>`` in the launchers.  ``reduced()`` yields the
smoke-test variant of the same family (small widths/layers/experts, tiny
vocab) exercised on CPU.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden dim
    first_k_dense: int = 0           # leading dense layers
    every: int = 1                   # MoE on layers where (i % every == every-1)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001   # load-balance loss coefficient


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int | None          # None = direct q projection
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None      # default d_model // n_heads

    # attention flavor
    attention: str = "gqa"           # gqa | mla | none
    rope_theta: float = 10000.0
    # per-layer sliding windows: window_pattern[i % len] (0 = global).
    window_pattern: tuple[int, ...] = ()
    mla: MLAConfig | None = None

    # mixture of experts
    moe: MoEConfig | None = None

    # state-space layers
    ssm: SSMConfig | None = None
    # hybrid layout: string over {"A","M"} per layer within a repeating group
    hybrid_pattern: str = ""

    # encoder-decoder
    encoder_layers: int = 0
    # vlm
    num_image_tokens: int = 0

    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # multi-token prediction depth (deepseek-v3 MTP); 0 = off
    mtp_depth: int = 0

    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    cache_dtype: str = "compute"     # compute | fp8 (quantized KV cache)

    # runtime/perf knobs (hillclimbed in §Perf)
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    attention_impl: str = "ref"      # ref | pallas
    moe_impl: str = "scatter"        # scatter | dense  (dense = oracle)
    ce_impl: str = "dense"           # dense | chunked  (chunked = low-mem CE)
    source: str = ""                 # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def resolved_cache_dtype(self):
        if self.cache_dtype == "fp8":
            return jnp.float8_e4m3fn
        return self.compute_dtype

    def layer_kind(self, i: int) -> str:
        """'A' attention(+mlp/moe) | 'M' mamba(+mlp/moe) for layer i."""
        if self.family == "ssm":
            return "M"
        if self.hybrid_pattern:
            return self.hybrid_pattern[i % len(self.hybrid_pattern)]
        return "A"

    def window_for_layer(self, i: int) -> int:
        if not self.window_pattern:
            return 0
        return self.window_pattern[i % len(self.window_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_k_dense:
            return False
        return (i % self.moe.every) == (self.moe.every - 1)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


# -- registry ----------------------------------------------------------------

ARCHS: tuple[str, ...] = (
    "deepseek_v3_671b", "deepseek_v2_lite_16b", "gemma3_27b",
    "starcoder2_7b", "granite_34b", "codeqwen15_7b", "mamba2_370m",
    "jamba_v01_52b", "whisper_medium", "paligemma_3b",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "gemma3-27b": "gemma3_27b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-34b": "granite_34b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "mamba2-370m": "mamba2_370m",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "whisper-medium": "whisper_medium",
    "paligemma-3b": "paligemma_3b",
})


def get_config(arch: str, reduced: bool = False, **overrides) -> ModelConfig:
    mod_name = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.reduced_config() if reduced else mod.config()
    cfg = _apply_session_precision(cfg)
    if overrides:
        cfg = cfg.with_(**overrides)
    return cfg


def _apply_session_precision(cfg: "ModelConfig") -> "ModelConfig":
    """Session-level precision policy beats the arch default (explicit
    ``get_config(..., compute_dtype=...)`` overrides still beat both)."""
    from repro.runtime import current_session, resolve_dtype

    pol = current_session().precision
    changes: dict = {}
    if pol.param_dtype is not None:
        changes["param_dtype"] = resolve_dtype(pol.param_dtype)
    if pol.compute_dtype is not None:
        changes["compute_dtype"] = resolve_dtype(pol.compute_dtype)
    if pol.cache_dtype is not None:
        changes["cache_dtype"] = pol.cache_dtype
    return cfg.with_(**changes) if changes else cfg


def list_archs() -> list[str]:
    return [a.replace("_", "-") for a in ARCHS]


# -- input shapes (assignment) -------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: SSM / hybrid / local-attention
# archs only (see DESIGN.md §5).
LONG_CONTEXT_ARCHS = {"gemma3_27b", "mamba2_370m", "jamba_v01_52b"}


def cells() -> list[tuple[str, str, str | None]]:
    """All 40 (arch, shape) cells; third item is a skip-reason or None."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            reason = None
            if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                reason = ("pure full-attention architecture: 500k context "
                          "requires sub-quadratic attention (DESIGN.md §5)")
            out.append((arch, shape.name, reason))
    return out
