"""ParamMeta: single source of truth for parameter shape, dtype, logical
sharding axes, and initializer.

``abstract_params`` trees built from these drive three consumers without
drift: (1) real initialization for smoke tests / small-scale training,
(2) ``jax.ShapeDtypeStruct`` stand-ins for the multi-pod dry-run (no
allocation), (3) PartitionSpec derivation via ``repro.sharding.rules``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamMeta:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis names, len == ndim
    dtype: Any = jnp.float32
    init: str = "normal"               # normal | zeros | ones | fan_in
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def nbytes(self) -> int:
        return math.prod(self.shape) * jnp.dtype(self.dtype).itemsize

    def instantiate(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "fan_in":
            fan_in = self.shape[0] if len(self.shape) >= 1 else 1
            std = 1.0 / math.sqrt(max(1, fan_in))
            return (jax.random.normal(key, self.shape) * std).astype(self.dtype)
        return (jax.random.normal(key, self.shape) * self.scale).astype(
            self.dtype)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def tree_structs(metas: Any) -> Any:
    """ShapeDtypeStruct tree for .lower() — zero allocation."""
    return jax.tree.map(lambda m: m.struct(), metas, is_leaf=is_meta)


def tree_init(metas: Any, key) -> Any:
    leaves, treedef = jax.tree.flatten(metas, is_leaf=is_meta)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [m.instantiate(k) for m, k in zip(leaves, keys)])


def tree_axes(metas: Any) -> Any:
    return jax.tree.map(lambda m: m.axes, metas, is_leaf=is_meta)


def tree_nbytes(metas: Any) -> int:
    return sum(m.nbytes() for m in jax.tree.leaves(metas, is_leaf=is_meta))


def tree_params_count(metas: Any) -> int:
    return sum(math.prod(m.shape)
               for m in jax.tree.leaves(metas, is_leaf=is_meta))


def stacked(meta: ParamMeta, n: int, axis_name: str = "layers") -> ParamMeta:
    """Add a leading scan axis (stacked layers for lax.scan)."""
    return ParamMeta((n,) + meta.shape, (axis_name,) + meta.axes,
                     meta.dtype, meta.init, meta.scale)


def stack_tree(metas: Any, n: int, axis_name: str = "layers") -> Any:
    return jax.tree.map(lambda m: stacked(m, n, axis_name), metas,
                        is_leaf=is_meta)
