"""Mamba-2 / SSD (state-space duality) block.

TPU-native adaptation (DESIGN.md §6): the SSD *chunked* form replaces the
sequential selective scan with per-chunk matmuls (MXU-friendly) plus a
short inter-chunk state recurrence — this is the form our Pallas kernel
targets.  The naive O(S) recurrence lives in kernels/ref.py as the oracle.

Shapes follow Mamba-2 with a single B/C group:
  x: [B, S, H, P]   (H = d_inner/head_dim heads, P = head_dim)
  dt: [B, S, H]     (softplus-discretized step)
  A: [H]            (negative scalar decay per head)
  B, C: [B, S, N]   (input/output projections, N = d_state)
State h: [B, H, P, N].
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import linear
from .meta import ParamMeta


def ssm_meta(cfg) -> dict[str, ParamMeta]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    n = s.d_state
    dt = cfg.param_dtype
    return {
        "in_x": ParamMeta((d, di), ("embed", "mlp"), dt, "fan_in"),
        "in_z": ParamMeta((d, di), ("embed", "mlp"), dt, "fan_in"),
        "in_B": ParamMeta((d, n), ("embed", None), dt, "fan_in"),
        "in_C": ParamMeta((d, n), ("embed", None), dt, "fan_in"),
        "in_dt": ParamMeta((d, h), ("embed", "heads"), dt, "fan_in"),
        "conv_x": ParamMeta((s.d_conv, di), (None, "mlp"), dt, "normal", 0.1),
        "conv_B": ParamMeta((s.d_conv, n), (None, None), dt, "normal", 0.1),
        "conv_C": ParamMeta((s.d_conv, n), (None, None), dt, "normal", 0.1),
        "A_log": ParamMeta((h,), ("heads",), jnp.float32, "zeros"),
        "D": ParamMeta((h,), ("heads",), jnp.float32, "ones"),
        "dt_bias": ParamMeta((h,), ("heads",), jnp.float32, "zeros"),
        "out_norm": ParamMeta((di,), ("mlp",), dt, "ones"),
        "out_proj": ParamMeta((di, d), ("mlp", "embed"), dt, "fan_in"),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i: i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} a[..., k].

    a: [..., Q] -> [..., Q, Q] lower-triangular cumulative sums.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD. Returns (y [B,S,H,P], h_final [B,H,P,N]).

    Equivalent to the recurrence
      h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ;   y_t = C_t · h_t
    evaluated chunk-parallel: intra-chunk via a masked attention-like
    matmul, inter-chunk via a scan over per-chunk states.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = chunk
    assert s % q == 0, (s, q)
    c = s // q
    dtA = dt * A[None, None, :]                              # [B,S,H] (<=0)
    xr = x.reshape(b, c, q, h, p)
    dtr = dt.reshape(b, c, q, h)
    ar = dtA.reshape(b, c, q, h).transpose(0, 3, 1, 2)       # [B,H,C,Q]
    br = B.reshape(b, c, q, n)
    cr = C.reshape(b, c, q, n)

    a_cum = jnp.cumsum(ar, -1)                               # [B,H,C,Q]
    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(ar))                                 # [B,H,C,Q,Q]
    scores = jnp.einsum("bcqn,bcsn->bcqs", cr, br)           # [B,C,Q,Q]
    y_diag = jnp.einsum("bcqs,bhcqs,bcsh,bcshp->bcqhp",
                        scores, L, dtr, xr)
    # 2) per-chunk end states
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)          # [B,H,C,Q]
    states = jnp.einsum("bcqn,bhcq,bcqh,bcqhp->bchpn",
                        br, decay_to_end, dtr, xr)           # [B,C,H,P,N]
    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                    # [B,H,C]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp                                        # [B,H,P,N],[B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                    # emit h BEFORE chunk

    sts = states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)  # [C,B,H,P,N]
    decs = chunk_decay.transpose(2, 0, 1)                      # [C,B,H]
    h_final, h_prevs = jax.lax.scan(step, h0.astype(jnp.float32),
                                    (sts, decs))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # [B,C,H,P,N]
    # 4) inter-chunk contribution
    in_decay = jnp.exp(a_cum)                                # [B,H,C,Q]
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", cr, h_prevs, in_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), h_final


def ssm_cache_spec(cfg, batch: int, max_seq: int, window: int = 0):
    s = cfg.ssm
    d = cfg.d_model
    di, h, n = s.d_inner(d), s.n_heads(d), s.d_state
    return {
        "h": ParamMeta((batch, h, s.head_dim, n),
                       ("batch", "heads", None, None), jnp.float32, "zeros"),
        "conv_x": ParamMeta((batch, s.d_conv - 1, di),
                            ("batch", None, "mlp"), cfg.compute_dtype,
                            "zeros"),
        "conv_B": ParamMeta((batch, s.d_conv - 1, n),
                            ("batch", None, None), cfg.compute_dtype,
                            "zeros"),
        "conv_C": ParamMeta((batch, s.d_conv - 1, n),
                            ("batch", None, None), cfg.compute_dtype,
                            "zeros"),
    }


def _project(p, x, cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    xs = linear(x, p["in_x"])
    z = linear(x, p["in_z"])
    Bp = linear(x, p["in_B"])
    Cp = linear(x, p["in_C"])
    dt = jax.nn.softplus(
        linear(x, p["in_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return xs, z, Bp, Cp, dt


def _finish(p, y, z, x_heads, cfg):
    """Skip connection + gated RMSNorm + out projection."""
    s = cfg.ssm
    b, slen = y.shape[:2]
    y = y + x_heads * p["D"].astype(jnp.float32)[None, None, :, None].astype(
        y.dtype)
    di = s.d_inner(cfg.d_model)
    y = y.reshape(b, slen, di)
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt((g * g).mean(-1, keepdims=True) + 1e-6)
    y = (g * p["out_norm"].astype(jnp.float32)).astype(y.dtype)
    return linear(y, p["out_proj"])


def apply_ssm(p, x, cfg):
    """Full-sequence SSD. x: [B, S, D] -> [B, S, D]."""
    s = cfg.ssm
    b, slen, _ = x.shape
    h = s.n_heads(cfg.d_model)
    xs, z, Bp, Cp, dt = _project(p, x, cfg)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"]).astype(jnp.float32)) \
        .astype(x.dtype)
    Bp = _causal_conv(Bp, p["conv_B"])
    Cp = _causal_conv(Cp, p["conv_C"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(b, slen, h, s.head_dim)
    if cfg.attention_impl == "pallas" and jax.default_backend() == "tpu":
        from repro.kernels import ops as kops

        y, _ = kops.ssd_chunk(xh, dt, A, Bp, Cp, chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bp, Cp, chunk=min(s.chunk, slen))
    return _finish(p, y, z, xh, cfg)


def ssm_prefill(p, x, cfg, *, max_seq: int, **_):
    s = cfg.ssm
    b, slen, _ = x.shape
    h = s.n_heads(cfg.d_model)
    xs, z, Bp, Cp, dt = _project(p, x, cfg)
    conv_tail = {"conv_x": xs[:, -(s.d_conv - 1):],
                 "conv_B": Bp[:, -(s.d_conv - 1):],
                 "conv_C": Cp[:, -(s.d_conv - 1):]}
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"]).astype(jnp.float32)) \
        .astype(x.dtype)
    Bp = _causal_conv(Bp, p["conv_B"])
    Cp = _causal_conv(Cp, p["conv_C"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(b, slen, h, s.head_dim)
    y, h_final = ssd_chunked(xh, dt, A, Bp, Cp, chunk=min(s.chunk, slen))
    out = _finish(p, y, z, xh, cfg)
    cache = {"h": h_final, **conv_tail}
    return out, cache


def ssm_decode(p, cache, x, cfg, *, pos=None, **_):
    """One-step recurrence: O(1) in sequence length."""
    s = cfg.ssm
    b = x.shape[0]
    h = s.n_heads(cfg.d_model)
    xs, z, Bp, Cp, dt = _project(p, x, cfg)                  # seq dim = 1

    def conv_step(tail, new, w):
        full = jnp.concatenate([tail, new], 1)               # [B, K, C]
        out = (full.astype(jnp.float32)
               * w.astype(jnp.float32)[None]).sum(1, keepdims=True)
        return out.astype(new.dtype), full[:, 1:]

    xs_c, tail_x = conv_step(cache["conv_x"], xs, p["conv_x"])
    Bp_c, tail_B = conv_step(cache["conv_B"], Bp, p["conv_B"])
    Cp_c, tail_C = conv_step(cache["conv_C"], Cp, p["conv_C"])
    xs_c = jax.nn.silu(xs_c.astype(jnp.float32)).astype(x.dtype)
    A = -jnp.exp(p["A_log"])                                 # [H]
    dt1 = dt[:, 0]                                           # [B,H]
    xh = xs_c.reshape(b, 1, h, s.head_dim)
    x1 = xh[:, 0].astype(jnp.float32)                        # [B,H,P]
    decay = jnp.exp(dt1 * A[None])                           # [B,H]
    hs = cache["h"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt1, Bp_c[:, 0].astype(jnp.float32), x1)
    y1 = jnp.einsum("bn,bhpn->bhp", Cp_c[:, 0].astype(jnp.float32), hs)
    y = y1[:, None].astype(x.dtype)                          # [B,1,H,P]
    out = _finish(p, y, z, xh, cfg)
    return out, {"h": hs, "conv_x": tail_x, "conv_B": tail_B,
                 "conv_C": tail_C}
