"""Decoder blocks, heterogeneous layer groups, and scanned stages.

Compile-time discipline for 512-device GSPMD lowering on a CPU host:
layers are grouped into *stages* of identical structure and stacked under
``jax.lax.scan`` (params get a leading ``layers`` axis), so the HLO holds
one copy of each distinct block body regardless of depth.  Heterogeneous
interleaves (gemma3's 5 local:1 global, jamba's 7 mamba:1 attention with
alternating MoE) become a *group block* — the repeating pattern unrolled
once — scanned over its repeats.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import apply_mlp, apply_norm, mlp_meta, norm_meta
from .meta import ParamMeta, is_meta, stack_tree


@dataclass(frozen=True)
class LayerSig:
    """Structural signature of one layer."""
    kind: str            # "A" attention | "M" mamba
    window: int = 0      # 0 = global attention
    use_moe: bool = False
    has_mlp: bool = True  # SSM-only archs have no FFN sublayer
    causal: bool = True


class DecoderLayer:
    """One pre-norm transformer/mamba layer per its signature."""

    def __init__(self, cfg, sig: LayerSig):
        self.cfg = cfg
        self.sig = sig
        if sig.kind == "A":
            self._attn_meta = (attn.mla_meta if cfg.attention == "mla"
                               else attn.gqa_meta)
            self._attn_apply = (attn.mla_attention if cfg.attention == "mla"
                                else attn.gqa_attention)
            self._attn_prefill = (attn.mla_prefill if cfg.attention == "mla"
                                  else attn.gqa_prefill)
            self._attn_decode = (attn.mla_decode if cfg.attention == "mla"
                                 else attn.gqa_decode)
            self._cache_spec = (attn.mla_cache_spec if cfg.attention == "mla"
                                else attn.gqa_cache_spec)

    # -- params ---------------------------------------------------------
    def abstract(self) -> dict:
        cfg, sig = self.cfg, self.sig
        out: dict[str, Any] = {"norm1": norm_meta(cfg)}
        if sig.kind == "A":
            out["attn"] = self._attn_meta(cfg)
        else:
            out["ssm"] = ssm_mod.ssm_meta(cfg)
        if sig.has_mlp:
            out["norm2"] = norm_meta(cfg)
            if sig.use_moe:
                out["moe"] = moe_mod.moe_meta(cfg)
            else:
                out["mlp"] = mlp_meta(cfg)
        return out

    # -- full sequence -----------------------------------------------------
    def apply(self, p, x, *, positions, prefix_len: int = 0):
        from repro.sharding.context import constrain_batch

        cfg, sig = self.cfg, self.sig
        x = constrain_batch(x)
        aux = jnp.zeros((), jnp.float32)
        h = apply_norm(p["norm1"], x, cfg)
        if sig.kind == "A":
            h = self._attn_apply(p["attn"], h, cfg, positions=positions,
                                 window=sig.window, prefix_len=prefix_len,
                                 causal=sig.causal)
        else:
            h = ssm_mod.apply_ssm(p["ssm"], h, cfg)
        x = x + h
        if sig.has_mlp:
            h = apply_norm(p["norm2"], x, cfg)
            if sig.use_moe:
                h, aux = moe_mod.apply_moe(p["moe"], h, cfg)
            else:
                h = apply_mlp(p["mlp"], h, cfg)
            x = x + h
        return x, aux

    # -- caches ---------------------------------------------------------------
    def cache_spec(self, batch: int, max_seq: int, paged=None) -> dict:
        cfg, sig = self.cfg, self.sig
        if sig.kind == "A":
            return self._cache_spec(cfg, batch, max_seq, window=sig.window,
                                    paged=paged)
        if paged is not None:
            raise NotImplementedError(
                "paged KV cache: SSM layers carry recurrent state, not a "
                "positional cache; there is nothing to page")
        return ssm_mod.ssm_cache_spec(cfg, batch, max_seq)

    def prefill(self, p, x, *, positions, max_seq: int, prefix_len: int = 0):
        cfg, sig = self.cfg, self.sig
        h = apply_norm(p["norm1"], x, cfg)
        if sig.kind == "A":
            h, cache = self._attn_prefill(p["attn"], h, cfg,
                                          positions=positions,
                                          window=sig.window, max_seq=max_seq,
                                          prefix_len=prefix_len)
        else:
            h, cache = ssm_mod.ssm_prefill(p["ssm"], h, cfg, max_seq=max_seq)
        x = x + h
        if sig.has_mlp:
            h = apply_norm(p["norm2"], x, cfg)
            if sig.use_moe:
                h, _ = moe_mod.apply_moe(p["moe"], h, cfg)
            else:
                h = apply_mlp(p["mlp"], h, cfg)
            x = x + h
        return x, cache

    def decode(self, p, cache, x, *, pos, attend_fn=None, block_table=None):
        cfg, sig = self.cfg, self.sig
        h = apply_norm(p["norm1"], x, cfg)
        if sig.kind == "A":
            # ring-buffer (window) caches stay local; full caches may be
            # sequence-sharded -> flash-decoding attend_fn
            fn = None if sig.window > 0 else attend_fn
            h, cache = self._attn_decode(p["attn"], cache, h, cfg, pos=pos,
                                         window=sig.window, attend_fn=fn,
                                         block_table=block_table)
        else:
            h, cache = ssm_mod.ssm_decode(p["ssm"], cache, h, cfg, pos=pos)
        x = x + h
        if sig.has_mlp:
            h = apply_norm(p["norm2"], x, cfg)
            if sig.use_moe:
                h, _ = moe_mod.apply_moe(p["moe"], h, cfg)
            else:
                h = apply_mlp(p["mlp"], h, cfg)
            x = x + h
        return x, cache

    def prefill_chunk(self, p, cache, x, *, positions, count,
                      block_table=None):
        """Consume one [B, T] prompt chunk against the decode cache (see
        ``attention.gqa_prefill_chunk``).  Attention-cache layers only:
        SSM recurrences need a batch-level bulk prefill."""
        cfg, sig = self.cfg, self.sig
        if sig.kind != "A":
            raise NotImplementedError(
                "chunked prefill: SSM layers advance recurrent state on "
                "every call and need batch-level bulk prefill")
        if cfg.attention == "mla":
            raise NotImplementedError(
                "chunked prefill is not implemented for MLA")
        h = apply_norm(p["norm1"], x, cfg)
        h, cache = attn.gqa_prefill_chunk(
            p["attn"], cache, h, cfg, positions=positions, count=count,
            window=sig.window,
            block_table=None if sig.window > 0 else block_table)
        x = x + h
        if sig.has_mlp:
            h = apply_norm(p["norm2"], x, cfg)
            if sig.use_moe:
                h, _ = moe_mod.apply_moe(p["moe"], h, cfg)
            else:
                h = apply_mlp(p["mlp"], h, cfg)
            x = x + h
        return x, cache


class GroupBlock:
    """A repeating pattern of heterogeneous layers, unrolled once."""

    def __init__(self, cfg, sigs: list[LayerSig]):
        self.layers = [DecoderLayer(cfg, s) for s in sigs]

    def abstract(self):
        return {f"l{i}": lyr.abstract() for i, lyr in enumerate(self.layers)}

    def apply(self, p, x, **kw):
        aux = jnp.zeros((), jnp.float32)
        for i, lyr in enumerate(self.layers):
            x, a = lyr.apply(p[f"l{i}"], x, **kw)
            aux = aux + a
        return x, aux

    def cache_spec(self, batch, max_seq, paged=None):
        return {f"l{i}": lyr.cache_spec(batch, max_seq, paged=paged)
                for i, lyr in enumerate(self.layers)}

    def prefill(self, p, x, **kw):
        caches = {}
        for i, lyr in enumerate(self.layers):
            x, caches[f"l{i}"] = lyr.prefill(p[f"l{i}"], x, **kw)
        return x, caches

    def decode(self, p, cache, x, **kw):
        new = {}
        for i, lyr in enumerate(self.layers):
            x, new[f"l{i}"] = lyr.decode(p[f"l{i}"], cache[f"l{i}"], x, **kw)
        return x, new

    def prefill_chunk(self, p, cache, x, **kw):
        new = {}
        for i, lyr in enumerate(self.layers):
            x, new[f"l{i}"] = lyr.prefill_chunk(p[f"l{i}"], cache[f"l{i}"],
                                                x, **kw)
        return x, new


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


class Stage:
    """``repeats`` copies of one block, scanned with stacked params."""

    def __init__(self, cfg, block, repeats: int):
        self.cfg = cfg
        self.block = block
        self.repeats = repeats
        self.scan = cfg.scan_layers and repeats > 1

    def abstract(self):
        metas = self.block.abstract()
        if self.scan:
            return stack_tree(metas, self.repeats)
        if self.repeats == 1:
            return {"r0": metas}
        return {f"r{i}": self.block.abstract() for i in range(self.repeats)}

    def cache_spec(self, batch, max_seq, paged=None):
        spec = self.block.cache_spec(batch, max_seq, paged=paged)
        if self.scan:
            return stack_tree(spec, self.repeats)
        if self.repeats == 1:
            return {"r0": spec}
        return {f"r{i}": self.block.cache_spec(batch, max_seq, paged=paged)
                for i in range(self.repeats)}

    # -- full sequence -------------------------------------------------------
    def apply(self, p, x, **kw):
        if not self.scan:
            aux = jnp.zeros((), jnp.float32)
            for i in range(self.repeats):
                x, a = self.block.apply(p[f"r{i}"], x, **kw)
                aux = aux + a
            return x, aux

        def body(carry, layer_p):
            h, aux = carry
            h, a = self.block.apply(layer_p, h, **kw)
            return (h, aux + a), None

        body = _remat(body, self.cfg.remat)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), p)
        return x, aux

    def prefill(self, p, x, **kw):
        if not self.scan:
            caches = {}
            for i in range(self.repeats):
                x, caches[f"r{i}"] = self.block.prefill(p[f"r{i}"], x, **kw)
            return x, caches

        def body(h, layer_p):
            h, cache = self.block.prefill(layer_p, h, **kw)
            return h, cache

        x, caches = jax.lax.scan(body, x, p)
        return x, caches

    def decode(self, p, cache, x, **kw):
        if not self.scan:
            new = {}
            for i in range(self.repeats):
                x, new[f"r{i}"] = self.block.decode(p[f"r{i}"],
                                                    cache[f"r{i}"], x, **kw)
            return x, new

        def body(h, inp):
            layer_p, layer_cache = inp
            h, new_cache = self.block.decode(layer_p, layer_cache, h, **kw)
            return h, new_cache

        x, new = jax.lax.scan(body, x, (p, cache))
        return x, new

    def prefill_chunk(self, p, cache, x, **kw):
        if not self.scan:
            new = {}
            for i in range(self.repeats):
                x, new[f"r{i}"] = self.block.prefill_chunk(
                    p[f"r{i}"], cache[f"r{i}"], x, **kw)
            return x, new

        def body(h, inp):
            layer_p, layer_cache = inp
            h, new_cache = self.block.prefill_chunk(layer_p, layer_cache, h,
                                                    **kw)
            return h, new_cache

        x, new = jax.lax.scan(body, x, (p, cache))
        return x, new


def build_stages(cfg) -> list[Stage]:
    """Derive homogeneous stages from the per-layer signature sequence."""
    sigs = []
    for i in range(cfg.n_layers):
        sigs.append(LayerSig(
            kind=cfg.layer_kind(i),
            window=cfg.window_for_layer(i),
            use_moe=cfg.is_moe_layer(i),
            has_mlp=(cfg.family != "ssm"),
        ))
    # period of the repeating structure
    head = cfg.moe.first_k_dense if cfg.moe else 0
    period = 1
    for n in (len(cfg.window_pattern) or 1, len(cfg.hybrid_pattern) or 1,
              cfg.moe.every if cfg.moe else 1):
        period = math.lcm(period, n)
    stages: list[Stage] = []
    if head:
        stages.append(Stage(cfg, DecoderLayer(cfg, sigs[0]), head))
    body = sigs[head:]
    n_groups = len(body) // period
    if n_groups > 0:
        pattern = body[:period]
        block = (DecoderLayer(cfg, pattern[0]) if period == 1
                 else GroupBlock(cfg, pattern))
        stages.append(Stage(cfg, block, n_groups))
    tail = body[n_groups * period:]
    if tail:
        # leftover layers (e.g. gemma3's 62 = 10*6 + 2)
        if all(t == tail[0] for t in tail):
            stages.append(Stage(cfg, DecoderLayer(cfg, tail[0]), len(tail)))
        else:
            stages.append(Stage(cfg, GroupBlock(cfg, tail), 1))
    return stages
