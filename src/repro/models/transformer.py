"""Decoder-only LM facade (also hosts the VLM variant — image embeddings
arrive pre-computed from the stubbed SigLIP frontend and are prepended as a
bidirectional prefix).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .blocks import DecoderLayer, LayerSig, Stage, build_stages
from .layers import apply_norm, embed, embed_meta, norm_meta, unembed
from .meta import ParamMeta, tree_init, tree_structs


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       z_loss: float = 0.0) -> jax.Array:
    """Mean next-token CE; logits fp32 [B,S,V], labels int [B,S]."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = (lse - gold).mean()
    if z_loss > 0:
        loss = loss + z_loss * (lse ** 2).mean()
    return loss


class LM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.stages = build_stages(cfg)
        # multi-token-prediction head (DeepSeek-V3): one extra layer that
        # predicts token t+2 from the trunk's hidden state
        self._mtp_layer = (DecoderLayer(cfg, LayerSig(kind="A"))
                           if cfg.mtp_depth > 0 else None)

    # -- params -----------------------------------------------------------
    def abstract_params(self) -> dict:
        cfg = self.cfg
        out: dict[str, Any] = {
            "embed": embed_meta(cfg),
            "final_norm": norm_meta(cfg),
            "stages": [s.abstract() for s in self.stages],
        }
        if not cfg.tie_embeddings:
            out["lm_head"] = ParamMeta((cfg.vocab_size, cfg.d_model),
                                       ("vocab", "embed"), cfg.param_dtype,
                                       "normal", 0.02)
        if self._mtp_layer is not None:
            out["mtp"] = {"layer": self._mtp_layer.abstract(),
                          "norm": norm_meta(cfg)}
        return out

    def init(self, key):
        return tree_init(self.abstract_params(), key)

    def param_structs(self):
        return tree_structs(self.abstract_params())

    # -- forward -------------------------------------------------------------
    def _trunk(self, p, x, *, positions, prefix_len: int = 0):
        aux = jnp.zeros((), jnp.float32)
        for stage, sp in zip(self.stages, p["stages"]):
            x, a = stage.apply(sp, x, positions=positions,
                               prefix_len=prefix_len)
            aux = aux + a
        return x, aux

    def _head_table(self, p):
        return p["embed"] if self.cfg.tie_embeddings else p["lm_head"]

    def forward(self, p, tokens, *, image_embeds=None):
        cfg = self.cfg
        x = embed(p["embed"], tokens, cfg)
        prefix_len = 0
        if image_embeds is not None:
            x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
            prefix_len = image_embeds.shape[1]
        positions = jnp.arange(x.shape[1])
        x, aux = self._trunk(p, x, positions=positions, prefix_len=prefix_len)
        h = apply_norm(p["final_norm"], x, cfg)
        logits = unembed(h, self._head_table(p), cfg)
        return logits, aux, x

    # -- training loss ------------------------------------------------------------
    def loss_fn(self, p, batch: dict):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        image_embeds = batch.get("image_embeds")
        x = embed(p["embed"], tokens, cfg)
        prefix_len = 0
        if image_embeds is not None:
            x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
            prefix_len = image_embeds.shape[1]
            pad = jnp.zeros(
                (labels.shape[0], prefix_len), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        positions = jnp.arange(x.shape[1])
        x, aux = self._trunk(p, x, positions=positions, prefix_len=prefix_len)
        h = apply_norm(p["final_norm"], x, cfg)
        table = self._head_table(p)
        if cfg.ce_impl == "chunked":
            loss = self._chunked_ce(h, table, labels)
        else:
            logits = unembed(h, table, cfg)
            loss = cross_entropy_loss(logits, labels)
        metrics = {"ce": loss, "moe_aux": aux}
        loss = loss + aux
        if self._mtp_layer is not None:
            mtp_loss = self._mtp_loss(p, x, positions, labels)
            metrics["mtp"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        return loss, metrics

    def _mtp_loss(self, p, x, positions, labels):
        """Predict token t+2 from trunk hidden state (depth-1 MTP)."""
        h, _ = self._mtp_layer.apply(p["mtp"]["layer"], x,
                                     positions=positions)
        h = apply_norm(p["mtp"]["norm"], h, self.cfg)
        logits = unembed(h[:, :-1], self._head_table(p), self.cfg)
        return cross_entropy_loss(logits, labels[:, 1:])

    def _chunked_ce(self, h, table, labels, n_chunks: int = 16):
        """Never materializes [B, S, V]: per-chunk unembed + CE.

        Activation-memory optimization (§Perf): the dense-CE logits tensor
        is the single largest activation for big-vocab models.
        """
        cfg = self.cfg
        b, s, d = h.shape
        while s % n_chunks != 0:
            n_chunks //= 2
        hs = h.reshape(b, n_chunks, s // n_chunks, d).swapaxes(0, 1)
        ls = labels.reshape(b, n_chunks, s // n_chunks).swapaxes(0, 1)

        def chunk_loss(hc_lc):
            hc, lc = hc_lc
            logits = unembed(hc, table, cfg)
            return cross_entropy_loss(logits, lc)

        losses = jax.lax.map(chunk_loss, (hs, ls))
        return losses.mean()

    # -- serving ------------------------------------------------------------------
    def cache_spec(self, batch: int, max_seq: int):
        return [s.cache_spec(batch, max_seq) for s in self.stages]

    def init_cache(self, batch: int, max_seq: int):
        return tree_init(self.cache_spec(batch, max_seq),
                         jax.random.PRNGKey(0))

    def paged_cache_spec(self, batch: int, max_seq: int, *, num_blocks: int,
                         block_size: int):
        """Cache metas with global-attention layers laid out as shared
        block pools (window ring buffers stay dense per slot)."""
        return [s.cache_spec(batch, max_seq, paged=(num_blocks, block_size))
                for s in self.stages]

    def init_paged_cache(self, batch: int, max_seq: int, *, num_blocks: int,
                         block_size: int):
        return tree_init(
            self.paged_cache_spec(batch, max_seq, num_blocks=num_blocks,
                                  block_size=block_size),
            jax.random.PRNGKey(0))

    def has_recurrent_state(self) -> bool:
        """True when any layer carries SSM recurrent state (staggered
        per-slot admission corrupts it — see ``ServeEngine``)."""
        return any(self.cfg.layer_kind(i) == "M"
                   for i in range(self.cfg.n_layers))

    def supports_paged_cache(self) -> bool:
        return not self.has_recurrent_state() and self.cfg.attention != "mla"

    def supports_chunked_prefill(self) -> bool:
        return not self.has_recurrent_state() and self.cfg.attention != "mla"

    def supports_prefix_sharing(self) -> bool:
        """Prefix sharing skips prefill for cached positions, which only
        works when *every* layer's cache is position-addressed through
        the paged pool — sliding-window layers keep per-slot dense ring
        buffers that a skipped prefill would leave unfilled."""
        return self.supports_paged_cache() and all(
            self.cfg.window_for_layer(i) == 0
            for i in range(self.cfg.n_layers))

    def prefill_step(self, p, cache, tokens, start, count, *,
                     block_table=None):
        """Chunked batched prefill: one jitted call consumes a [B, T]
        chunk of prompt tokens per slot, writing K/V into ``cache`` at
        positions ``start[b] + t`` for the first ``count[b]`` tokens of
        each row (count 0 = slot untouched).  Returns the new cache;
        logits come from the subsequent ``decode_step`` on the last
        prompt token, as in per-token admission."""
        cfg = self.cfg
        x = embed(p["embed"], tokens, cfg)
        t = tokens.shape[1]
        positions = (jnp.asarray(start, jnp.int32)[:, None]
                     + jnp.arange(t, dtype=jnp.int32)[None, :])
        count = jnp.asarray(count, jnp.int32)
        new_caches = []
        for stage, sp, sc in zip(self.stages, p["stages"], cache):
            x, nc = stage.prefill_chunk(sp, sc, x, positions=positions,
                                        count=count,
                                        block_table=block_table)
            new_caches.append(nc)
        return new_caches

    def supports_speculative(self) -> bool:
        """Speculative decoding rolls rejected positions back by
        truncating block tables, which requires every layer's cache to
        be position-addressed through the paged pool — a sliding-window
        ring buffer overwrites old positions in place and cannot
        rewind.  The constraint is exactly prefix sharing's."""
        return self.supports_prefix_sharing()

    def verify_step(self, p, cache, tokens, start, count, *,
                    block_table=None):
        """Wide verify for speculative decoding: like ``prefill_step``
        — per-slot token spans written at ``start[b] + t`` with a
        ``count[b]`` validity mask — but returns logits for *every*
        position so the engine can score all k+1 draft proposals in one
        batched forward.  Returns ([B, T, V], cache)."""
        cfg = self.cfg
        x = embed(p["embed"], tokens, cfg)
        t = tokens.shape[1]
        positions = (jnp.asarray(start, jnp.int32)[:, None]
                     + jnp.arange(t, dtype=jnp.int32)[None, :])
        count = jnp.asarray(count, jnp.int32)
        new_caches = []
        for stage, sp, sc in zip(self.stages, p["stages"], cache):
            x, nc = stage.prefill_chunk(sp, sc, x, positions=positions,
                                        count=count,
                                        block_table=block_table)
            new_caches.append(nc)
        h = apply_norm(p["final_norm"], x, cfg)
        logits = unembed(h, self._head_table(p), cfg)
        return logits, new_caches

    def prefill(self, p, tokens, *, max_seq: int, image_embeds=None):
        cfg = self.cfg
        x = embed(p["embed"], tokens, cfg)
        prefix_len = 0
        if image_embeds is not None:
            x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
            prefix_len = image_embeds.shape[1]
        positions = jnp.arange(x.shape[1])
        caches = []
        for stage, sp in zip(self.stages, p["stages"]):
            x, cache = stage.prefill(sp, x, positions=positions,
                                     max_seq=max_seq, prefix_len=prefix_len)
            caches.append(cache)
        h = apply_norm(p["final_norm"], x[:, -1:], cfg)
        logits = unembed(h, self._head_table(p), cfg)[:, 0]
        return logits, caches

    def decode_step(self, p, cache, token, pos, *, attend_fn=None,
                    block_table=None):
        """token: [B, 1] int; pos: scalar int32 or per-slot [B] int32;
        block_table routes global-attention caches through a paged pool.
        Returns ([B, V], cache)."""
        cfg = self.cfg
        x = embed(p["embed"], token, cfg)
        new_caches = []
        for stage, sp, sc in zip(self.stages, p["stages"], cache):
            x, nc = stage.decode(sp, sc, x, pos=pos, attend_fn=attend_fn,
                                 block_table=block_table)
            new_caches.append(nc)
        h = apply_norm(p["final_norm"], x, cfg)
        logits = unembed(h, self._head_table(p), cfg)[:, 0]
        return logits, new_caches
