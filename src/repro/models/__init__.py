from .meta import (ParamMeta, is_meta, stack_tree, stacked, tree_axes,
                   tree_init, tree_nbytes, tree_params_count, tree_structs)
from .transformer import LM, cross_entropy_loss
from .encdec import EncDecLM


def build_model(cfg):
    """Factory: ModelConfig -> model facade."""
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return LM(cfg)


__all__ = ["ParamMeta", "is_meta", "stack_tree", "stacked", "tree_axes",
           "tree_init", "tree_nbytes", "tree_params_count", "tree_structs",
           "LM", "EncDecLM", "build_model", "cross_entropy_loss"]
