"""Functional model layers for the production substrate.

Projections (the MXU-bound parameter matmuls) route through the tensor
dispatch (``repro.core.tensor.ops``) so the paper's backend-swap property
(§5.2.4) reaches the entire model zoo; norms probe the active backend for
a fused kernel.  Glue (reshapes/einsum attention math) uses jnp directly —
those paths are swapped at a coarser grain via ``attention_impl``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.tensor import ops as T
from repro.core.tensor.dispatch import current_backend
from .meta import ParamMeta


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """[..., D] @ [D, F] -> [..., F] through the dispatch layer."""
    nd = x.ndim
    dn = (((nd - 1,), (0,)), ((), ()))
    return T.dot_general(x, w, dn, preferred_element_type=None)


def linear_meta(d_in: int, d_out: int, axes: tuple, dtype,
                init: str = "fan_in") -> ParamMeta:
    return ParamMeta((d_in, d_out), axes, dtype, init)


# -- norms --------------------------------------------------------------------

def norm_meta(cfg) -> dict[str, ParamMeta]:
    if cfg.norm == "layernorm":
        return {"scale": ParamMeta((cfg.d_model,), ("embed",),
                                   cfg.param_dtype, "ones"),
                "bias": ParamMeta((cfg.d_model,), ("embed",),
                                  cfg.param_dtype, "zeros")}
    return {"scale": ParamMeta((cfg.d_model,), ("embed",),
                               cfg.param_dtype, "ones")}


def apply_norm(p: dict, x: jax.Array, cfg) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if "bias" in p:
        mu = x32.mean(-1, keepdims=True)
        v = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(v + 1e-5)
        out = out * p["scale"].astype(jnp.float32) \
            + p["bias"].astype(jnp.float32)
        return out.astype(x.dtype)
    backend = current_backend()
    if hasattr(backend, "rms_norm_fused") and x.ndim in (2, 3):
        return backend.rms_norm_fused(x, p["scale"]).astype(x.dtype)
    ms = (x32 * x32).mean(-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + 1e-6)
            * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- embeddings ------------------------------------------------------------------

def embed_meta(cfg) -> ParamMeta:
    return ParamMeta((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                     cfg.param_dtype, "normal", 0.02)


def embed(table: jax.Array, ids: jax.Array, cfg) -> jax.Array:
    out = T.take(table, ids, axis=0)
    return out.astype(cfg.compute_dtype)


def unembed(x: jax.Array, table: jax.Array, cfg) -> jax.Array:
    """Logits: [..., D] @ [V, D]^T, fp32 accumulation."""
    dn = (((x.ndim - 1,), (1,)), ((), ()))
    logits = T.dot_general(x, table, dn, preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


# -- gated MLP ---------------------------------------------------------------------

def mlp_meta(cfg, d_ff: int | None = None,
             ff_axis: str = "mlp") -> dict[str, ParamMeta]:
    d_ff = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    m = {"w_up": ParamMeta((cfg.d_model, d_ff), ("embed", ff_axis), dt,
                           "fan_in"),
         "w_down": ParamMeta((d_ff, cfg.d_model), (ff_axis, "embed"), dt,
                             "fan_in")}
    if cfg.act in ("silu", "geglu"):   # gated variants
        m["w_gate"] = ParamMeta((cfg.d_model, d_ff), ("embed", ff_axis), dt,
                                "fan_in")
    return m


def apply_mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    up = linear(x, p["w_up"])
    if "w_gate" in p:
        act = jax.nn.gelu if cfg.act == "geglu" else jax.nn.silu
        h = act(linear(x, p["w_gate"]).astype(jnp.float32)).astype(
            x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return linear(h, p["w_down"])


# -- RoPE ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D] (or [..., H, D] with scalar pos); rotate pairs."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d // 2], x32[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x
