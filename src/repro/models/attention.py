"""Attention variants: GQA/MQA/MHA (+ sliding window, softcap), and
DeepSeek MLA (multi-head latent attention) with matrix-absorbed decode.

Full-sequence paths (train/prefill) support ``attention_impl="pallas"``
(flash-attention kernel) or ``"ref"`` (masked-softmax oracle, also the
dry-run lowering path).  Decode paths produce *partial* (m, l, o) softmax
statistics so the serving layer can combine across sequence-sharded KV
caches (flash-decoding; see repro/serving/decode_attention.py).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import apply_rope, linear, softcap
from .meta import ParamMeta

NEG_INF = -2.0 ** 30  # finite: keeps fully-masked rows NaN-free


def _session_kernels():
    from repro.runtime import current_session

    return current_session().kernels


# ===========================================================================
# masks
# ===========================================================================

def make_mask(q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool = True,
              window: int = 0, prefix_len: int = 0) -> jax.Array:
    """[Sq, Sk] boolean mask. window>0 = sliding window; prefix positions
    (< prefix_len) are bidirectionally visible (PaLI-style prefix-LM)."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    m = (q >= k) if causal else jnp.ones((q_pos.shape[0], kv_pos.shape[0]),
                                         bool)
    if window > 0:
        m = m & (q - k < window)
    if prefix_len > 0:
        m = m | (k < prefix_len)
    return m


# ===========================================================================
# GQA
# ===========================================================================

def gqa_meta(cfg) -> dict[str, ParamMeta]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.param_dtype
    return {
        "wq": ParamMeta((d, h * hd), ("embed", "heads"), dt, "fan_in"),
        "wk": ParamMeta((d, kv * hd), ("embed", "kv_heads"), dt, "fan_in"),
        "wv": ParamMeta((d, kv * hd), ("embed", "kv_heads"), dt, "fan_in"),
        "wo": ParamMeta((h * hd, d), ("heads", "embed"), dt, "fan_in"),
    }


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, s, h, hd)
    k = linear(x, p["wk"]).reshape(b, s, kv, hd)
    v = linear(x, p["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_ref(q, k, v, mask, scale, cap: float = 0.0):
    """Reference grouped attention. q:[B,S,H,D] k/v:[B,S,Kv,D];
    mask: [Sq, Sk] shared, or [B, Sq, Sk] per-batch (chunked prefill at
    per-slot positions)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    m = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
    scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])  # dv may differ from dk (MLA)


def _sdpa_blockwise(q, k, v, positions, *, causal, window, prefix_len,
                    scale, cap: float = 0.0, q_chunk: int = 1024):
    """Query-chunked attention: never materializes the [S, S] score matrix.

    Peak scores buffer is [B, Kv, G, q_chunk, S] instead of O(S²) — the
    memory-term optimization for long-sequence prefill (§Perf).  Exact
    (full softmax row per chunk; the key axis is never split).
    """
    b, s, h, d = q.shape
    while s % q_chunk != 0:
        q_chunk //= 2
    n = s // q_chunk
    qc = q.reshape(b, n, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = positions.reshape(n, q_chunk)

    def one_chunk(args):
        qi, pi = args
        mask = make_mask(pi, positions, causal=causal, window=window,
                         prefix_len=prefix_len)
        return _sdpa_ref(qi, k, v, mask, scale, cap)

    out = jax.lax.map(one_chunk, (qc, pc))            # [n, B, qc, H, Dv]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])


def gqa_attention(p, x, cfg, *, positions, window: int = 0,
                  prefix_len: int = 0, causal: bool = True) -> jax.Array:
    """Full-sequence attention (train/prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    override = _session_kernels().attention
    if override is not None:
        out = override(q, k, v, positions=positions, causal=causal,
                       window=window, prefix_len=prefix_len, scale=scale,
                       cap=cfg.logit_softcap)
    elif cfg.attention_impl == "pallas" and jax.default_backend() == "tpu":
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, softcap=cfg.logit_softcap)
    elif cfg.attention_impl == "blockwise":
        out = _sdpa_blockwise(q, k, v, positions, causal=causal,
                              window=window, prefix_len=prefix_len,
                              scale=scale, cap=cfg.logit_softcap)
    else:
        mask = make_mask(positions, positions, causal=causal, window=window,
                         prefix_len=prefix_len)
        out = _sdpa_ref(q, k, v, mask, scale, cfg.logit_softcap)
    return linear(out.reshape(b, s, -1), p["wo"])


# ===========================================================================
# quantized (fp8) cache storage
# ===========================================================================

FP8_MAX = 448.0  # float8_e4m3fn max normal


def quantize_kv(x, cache_dtype):
    """Quantize a K/V tensor for cache storage.

    fp8 caches store a per-position per-head scale (amax over the head
    dim / FP8_MAX) next to the values, so dequantized reads recover the
    full dynamic range — raw casts crush small-magnitude heads.  Returns
    ``(stored, scale)``; scale is None for non-fp8 cache dtypes.
    """
    if cache_dtype != jnp.float8_e4m3fn:
        return x.astype(cache_dtype), None
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.maximum(amax / FP8_MAX, 1e-12)
    return (x32 / scale[..., None]).astype(cache_dtype), scale


def dequantize_kv(stored, scale, out_dtype):
    """Inverse of :func:`quantize_kv`; identity when scale is None."""
    if scale is None:
        return stored
    return (stored.astype(jnp.float32)
            * scale[..., None]).astype(out_dtype)


def gqa_cache_spec(cfg, batch: int, max_seq: int, window: int = 0,
                   paged=None):
    """Cache metas for one layer.  Window layers get per-slot ring
    buffers (always dense — they are already small and fixed-size).
    ``paged=(num_blocks, block_size)`` lays global-attention caches out
    as a shared block pool indexed through a block table; fp8 caches
    additionally carry per-position per-head scale planes.
    """
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.resolved_cache_dtype
    fp8 = cfg.cache_dtype == "fp8"
    if paged is not None and window == 0:
        num_blocks, block_size = paged
        p = num_blocks * block_size
        spec = {
            "k": ParamMeta((p, kv, hd), (None, "kv_heads", None), dt,
                           "zeros"),
            "v": ParamMeta((p, kv, hd), (None, "kv_heads", None), dt,
                           "zeros"),
        }
        if fp8:
            spec["k_scale"] = ParamMeta((p, kv), (None, "kv_heads"),
                                        jnp.float32, "zeros")
            spec["v_scale"] = ParamMeta((p, kv), (None, "kv_heads"),
                                        jnp.float32, "zeros")
        return spec
    s = min(window, max_seq) if window > 0 else max_seq
    seq_ax = None if window > 0 else "seq_shard"
    spec = {
        "k": ParamMeta((batch, s, kv, hd),
                       ("batch", seq_ax, "kv_heads", None), dt, "zeros"),
        "v": ParamMeta((batch, s, kv, hd),
                       ("batch", seq_ax, "kv_heads", None), dt, "zeros"),
    }
    if fp8:
        spec["k_scale"] = ParamMeta((batch, s, kv),
                                    ("batch", seq_ax, "kv_heads"),
                                    jnp.float32, "zeros")
        spec["v_scale"] = ParamMeta((batch, s, kv),
                                    ("batch", seq_ax, "kv_heads"),
                                    jnp.float32, "zeros")
    return spec


def gqa_prefill(p, x, cfg, *, positions, window: int = 0, max_seq: int,
                prefix_len: int = 0):
    """Full-seq attention + build the decode cache."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    mask = make_mask(positions, positions, window=window,
                     prefix_len=prefix_len)
    out = _sdpa_ref(q, k, v, mask, scale, cfg.logit_softcap)
    out = linear(out.reshape(b, s, -1), p["wo"])
    cache = _write_prefill_cache(k, v, cfg, window, max_seq)
    return out, cache


def _write_prefill_cache(k, v, cfg, window, max_seq):
    dt = cfg.resolved_cache_dtype
    k, k_scale = quantize_kv(k, dt)
    v, v_scale = quantize_kv(v, dt)
    b, s = k.shape[:2]

    def pack(arr):
        """Lay out one [B, S, ...] tensor as its cache-resident form."""
        trail = ((0, 0),) * (arr.ndim - 2)
        if window > 0:
            w = min(window, max_seq)
            if s >= w:
                # ring-buffer layout: slot i holds position p with
                # p % w == i, matching decode's `slot = pos % w`
                shift = (s - w) % w
                return jnp.roll(arr[:, -w:], shift, axis=1)
            return jnp.pad(arr, ((0, 0), (0, w - s)) + trail)
        return jnp.pad(arr, ((0, 0), (0, max_seq - s)) + trail)

    cache = {"k": pack(k), "v": pack(v)}
    if k_scale is not None:
        cache["k_scale"] = pack(k_scale)
        cache["v_scale"] = pack(v_scale)
    return cache


def _decode_positions(pos, b):
    """Normalize decode position(s): scalar -> (rope positions [1],
    per_slot=False); per-slot [B] array -> ([B, 1], per_slot=True)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return pos, jnp.full((1,), pos, jnp.int32), False
    if pos.ndim != 1 or pos.shape[0] != b:
        raise ValueError(f"pos must be scalar or [batch]; got {pos.shape}")
    return pos, pos[:, None], True


def _batched_cache_update(cache, new, slot):
    """Write ``new`` [B, 1, ...] at a per-batch position ``slot`` [B]."""
    def upd(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s,) + (0,) * (c.ndim - 1))

    return jax.vmap(upd)(cache, new, slot)


def _scalar_cache_update(cache, new, slot):
    """Write ``new`` [B, 1, ...] at one shared position ``slot``."""
    return jax.lax.dynamic_update_slice(
        cache, new, (0, slot) + (0,) * (cache.ndim - 2))


# -- paged (block-table) addressing -----------------------------------------
# The block table is duck-typed: anything with ``.table`` ([B, MB] int32
# physical block ids) and ``.block_size`` (static int) works — the real
# class lives in repro/serving/kv_cache.py to keep models import-light.

def _paged_write_index(block_table, pos):
    """Physical pool index for writing position ``pos`` [B] (or [B, T])."""
    bs = block_table.block_size
    blk = jnp.take_along_axis(block_table.table,
                              (pos // bs).reshape(pos.shape[0], -1),
                              axis=1).reshape(pos.shape)
    return blk * bs + pos % bs


def _paged_read_index(block_table):
    """[B, L] physical pool indices for the full logical view
    (L = max_blocks * block_size); unmapped blocks resolve to the
    reserved trash block and must be masked by validity."""
    bs = block_table.block_size
    mb = block_table.table.shape[1]
    l = jnp.arange(mb * bs, dtype=jnp.int32)
    return block_table.table[:, l // bs] * bs + (l % bs)[None, :]


def _paged_gather(cache, block_table, out_dtype):
    """Gather the logical [B, L, ...] K/V view through the block table,
    dequantizing fp8 pools on the way out."""
    idx = _paged_read_index(block_table)
    k = cache["k"][idx]
    v = cache["v"][idx]
    if "k_scale" in cache:
        k = dequantize_kv(k, cache["k_scale"][idx], out_dtype)
        v = dequantize_kv(v, cache["v_scale"][idx], out_dtype)
    return k, v


def decode_valid_mask(pos, s_cache, window: int = 0):
    """Causal validity over cache slots: [S] for scalar ``pos``, [B, S]
    for a per-slot position vector.  Once a ring buffer has wrapped
    (``pos + 1 >= s_cache``) every slot holds a live entry."""
    idx = jnp.arange(s_cache)
    if jnp.ndim(pos) == 1:
        idx, pos = idx[None, :], pos[:, None]
    mask = idx <= pos
    if window > 0:
        mask = mask | (pos + 1 >= s_cache)
    return mask


def gqa_decode(p, cache, x, cfg, *, pos, window: int = 0, attend_fn=None,
               block_table=None):
    """One decode step. x: [B, 1, D]; pos: scalar position shared by the
    whole batch, or a [B] int vector of *per-slot* positions (continuous
    batching admits requests mid-flight, so slots decode at different
    depths).

    ``attend_fn(q, k, v, valid)`` lets the serving layer substitute a
    sequence-sharded flash-decoding implementation; when omitted, the
    session's ``kernels.decode_attention`` override applies (ring-buffer
    window caches stay local and always use plain cache attention).

    With ``block_table`` (paged serving), global-attention caches are
    block pools: the new K/V scatters through the table and attention
    reads a gathered logical view, so the attend interface is unchanged.
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pos, pos_arr, per_slot = _decode_positions(pos, b)
    q = linear(x, p["wq"]).reshape(b, 1, h, hd)
    k = linear(x, p["wk"]).reshape(b, 1, kv, hd)
    v = linear(x, p["wv"]).reshape(b, 1, kv, hd)
    q = apply_rope(q, pos_arr, cfg.rope_theta)[:, 0]          # [B, H, D]
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    scaled = "k_scale" in cache
    if scaled:
        kq, k_sc = quantize_kv(k, cache["k"].dtype)
        vq, v_sc = quantize_kv(v, cache["v"].dtype)
    else:
        kq, vq = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    new_cache = dict(cache)
    if block_table is not None and window == 0:
        pos_b = pos if per_slot else jnp.full((b,), pos, jnp.int32)
        phys = _paged_write_index(block_table, pos_b)          # [B]
        new_cache["k"] = cache["k"].at[phys].set(kq[:, 0])
        new_cache["v"] = cache["v"].at[phys].set(vq[:, 0])
        if scaled:
            new_cache["k_scale"] = cache["k_scale"].at[phys].set(k_sc[:, 0])
            new_cache["v_scale"] = cache["v_scale"].at[phys].set(v_sc[:, 0])
        k_view, v_view = _paged_gather(new_cache, block_table, x.dtype)
        if scaled:
            # quantization is a storage effect only: the token being
            # decoded attends its own K/V exactly (it never left VMEM)
            k_view = _batched_cache_update(k_view, k.astype(x.dtype), pos_b)
            v_view = _batched_cache_update(v_view, v.astype(x.dtype), pos_b)
        valid = decode_valid_mask(pos_b, k_view.shape[1])
    else:
        s_cache = cache["k"].shape[1]
        slot = jnp.mod(pos, s_cache) if window > 0 else pos
        upd = _batched_cache_update if per_slot else _scalar_cache_update
        new_cache["k"] = upd(cache["k"], kq, slot)
        new_cache["v"] = upd(cache["v"], vq, slot)
        if scaled:
            new_cache["k_scale"] = upd(cache["k_scale"], k_sc, slot)
            new_cache["v_scale"] = upd(cache["v_scale"], v_sc, slot)
            k_view = dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                   x.dtype)
            v_view = dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                   x.dtype)
            k_view = upd(k_view, k.astype(x.dtype), slot)
            v_view = upd(v_view, v.astype(x.dtype), slot)
        else:
            k_view, v_view = new_cache["k"], new_cache["v"]
        valid = decode_valid_mask(pos, s_cache, window)
    scale = 1.0 / math.sqrt(hd)
    attend = attend_fn
    if attend is None and window == 0:
        attend = _session_kernels().decode_attention
    attend = attend or plain_cache_attention
    out = attend(q, k_view, v_view, valid, scale=scale,
                 cap=cfg.logit_softcap)
    out = linear(out.reshape(b, 1, -1), p["wo"])
    return out, new_cache


def gqa_prefill_chunk(p, cache, x, cfg, *, positions, count,
                      window: int = 0, block_table=None):
    """Chunked batched prefill: consume a [B, T] chunk of prompt tokens
    in ONE call, writing K/V into the decode cache at per-slot positions
    and attending causally over cache-so-far + chunk.

    x: [B, T, D] chunk activations; positions: [B, T] int32 per-token
    absolute positions; count: [B] number of valid tokens this chunk
    (0 = slot not prefilling — its writes are dropped, its outputs are
    garbage the caller discards).  Replaces O(prompt_len) one-token
    decode calls per admission with O(prompt_len / T) chunk calls.
    """
    b, t, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, t, h, hd)
    k = linear(x, p["wk"]).reshape(b, t, kvh, hd)
    v = linear(x, p["wv"]).reshape(b, t, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scaled = "k_scale" in cache
    if scaled:
        kq, k_sc = quantize_kv(k, cache["k"].dtype)
        vq, v_sc = quantize_kv(v, cache["v"].dtype)
    else:
        kq, vq = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        k_sc = v_sc = None
    tok_valid = jnp.arange(t, dtype=jnp.int32)[None, :] < count[:, None]
    scale = 1.0 / math.sqrt(hd)
    new_cache = dict(cache)
    qpos = positions[:, :, None]                              # [B, T, 1]

    if window > 0:
        # Ring buffers: attend over (ring-as-of-chunk-start ++ chunk),
        # then write only the chunk tail that survives the window —
        # writing first would let late chunk tokens overwrite ring slots
        # early chunk queries still need.
        w = cache["k"].shape[1]
        i = jnp.arange(w, dtype=jnp.int32)[None, :]
        sm1 = positions[:, :1] - 1                            # start - 1
        ring_pos = sm1 - jnp.mod(sm1 - i, w)                  # [B, w]
        ring_ok = ring_pos >= 0
        if scaled:
            ring_k = dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
            ring_v = dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
            chunk_k = dequantize_kv(kq, k_sc, x.dtype)
            chunk_v = dequantize_kv(vq, v_sc, x.dtype)
        else:
            ring_k, ring_v = cache["k"], cache["v"]
            # round-trip chunk K/V through the cache dtype: chunk queries
            # see exactly what later decode steps will read back
            chunk_k, chunk_v = kq.astype(x.dtype), vq.astype(x.dtype)
        kp = positions[:, None, :]                            # [B, 1, T]
        ring_mask = (ring_ok[:, None, :] & (ring_pos[:, None, :] <= qpos)
                     & (qpos - ring_pos[:, None, :] < w))
        in_window = tok_valid[:, None, :] & (qpos - kp < w)
        keys = [ring_k.astype(x.dtype), chunk_k]
        vals = [ring_v.astype(x.dtype), chunk_v]
        if scaled:
            # cross-token reads see storage quantization; self is exact
            masks = [ring_mask, in_window & (kp < qpos),
                     tok_valid[:, None, :] & (kp == qpos)]
            keys.append(k.astype(x.dtype))
            vals.append(v.astype(x.dtype))
        else:
            masks = [ring_mask, in_window & (kp <= qpos)]
        out = _sdpa_ref(q, jnp.concatenate(keys, 1),
                        jnp.concatenate(vals, 1),
                        jnp.concatenate(masks, -1), scale,
                        cfg.logit_softcap)
        end = positions[:, :1] + count[:, None]               # [B, 1]
        keep = tok_valid & (positions >= end - w)
        widx = jnp.where(keep, jnp.mod(positions, w), w)      # w = dropped
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
        for key, newv in (("k", kq), ("v", vq),
                          ("k_scale", k_sc), ("v_scale", v_sc)):
            if key in cache:
                new_cache[key] = cache[key].at[bidx, widx].set(
                    newv, mode="drop")
        return linear(out.reshape(b, t, -1), p["wo"]), new_cache

    if block_table is not None:
        pool = cache["k"].shape[0]
        phys = _paged_write_index(block_table, positions)     # [B, T]
        phys = jnp.where(tok_valid, phys, pool)               # OOB = dropped
        for key, newv in (("k", kq), ("v", vq),
                          ("k_scale", k_sc), ("v_scale", v_sc)):
            if key in cache:
                new_cache[key] = cache[key].at[phys].set(newv, mode="drop")
        k_view, v_view = _paged_gather(new_cache, block_table, x.dtype)
    else:
        s_cache = cache["k"].shape[1]
        widx = jnp.where(tok_valid, positions, s_cache)       # OOB = dropped
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
        for key, newv in (("k", kq), ("v", vq),
                          ("k_scale", k_sc), ("v_scale", v_sc)):
            if key in cache:
                new_cache[key] = cache[key].at[bidx, widx].set(
                    newv, mode="drop")
        if scaled:
            k_view = dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                   x.dtype)
            v_view = dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                   x.dtype)
        else:
            k_view, v_view = new_cache["k"], new_cache["v"]
    kv_idx = jnp.arange(k_view.shape[1], dtype=jnp.int32)
    if scaled:
        # cross-token reads see storage quantization; self is exact
        kp = positions[:, None, :]                            # [B, 1, T]
        mask = jnp.concatenate(
            [kv_idx[None, None, :] < qpos,
             tok_valid[:, None, :] & (kp == qpos)], -1)
        k_view = jnp.concatenate([k_view, k.astype(x.dtype)], 1)
        v_view = jnp.concatenate([v_view, v.astype(x.dtype)], 1)
    else:
        mask = kv_idx[None, None, :] <= qpos                  # [B, T, S]
    out = _sdpa_ref(q, k_view, v_view, mask, scale, cfg.logit_softcap)
    return linear(out.reshape(b, t, -1), p["wo"]), new_cache


# ===========================================================================
# cache attention core (shared by GQA decode and MLA absorbed decode)
# ===========================================================================

def partial_cache_attention(q, k, v, valid, *, scale, cap: float = 0.0):
    """Partial softmax stats for flash-decoding combine.

    q: [B, H, Dk]; k: [B, S, Kv, Dk]; v: [B, S, Kv, Dv]; valid: [S] bool
    (shared across the batch) or [B, S] (per-slot decode depths).
    Caches may be stored quantized (fp8) — math upcasts to q's dtype.
    Returns m: [B, Kv, G], l: [B, Kv, G], o: [B, Kv, G, Dv].
    """
    b, h, dk = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dk)
    k = k.astype(q.dtype)
    v = v.astype(q.dtype)
    vmask = (valid if valid.ndim == 2 else valid[None])[:, None, None, :]
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    scores = jnp.where(vmask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                                 # [B,Kv,G]
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(vmask, e, 0.0)
    l = jnp.sum(e, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", e.astype(v.dtype), v).astype(
        jnp.float32)
    return m, l, o


def plain_cache_attention(q, k, v, valid, *, scale, cap: float = 0.0):
    """Unsharded decode attention; returns [B, H, Dv] in q's dtype."""
    m, l, o = partial_cache_attention(q, k, v, valid, scale=scale, cap=cap)
    b, kvh, g, dv = o.shape
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, kvh * g, dv).astype(q.dtype)


# ===========================================================================
# MLA (DeepSeek multi-head latent attention)
# ===========================================================================

def mla_meta(cfg) -> dict[str, ParamMeta]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.param_dtype
    qk = m.qk_nope_dim + m.qk_rope_dim
    out: dict[str, ParamMeta] = {}
    if m.q_lora_rank:
        out["wq_a"] = ParamMeta((d, m.q_lora_rank), ("embed", None), dt,
                                "fan_in")
        out["q_norm"] = ParamMeta((m.q_lora_rank,), (None,), dt, "ones")
        out["wq_b"] = ParamMeta((m.q_lora_rank, h * qk), (None, "heads"), dt,
                                "fan_in")
    else:
        out["wq"] = ParamMeta((d, h * qk), ("embed", "heads"), dt, "fan_in")
    out["wkv_a"] = ParamMeta((d, m.kv_lora_rank + m.qk_rope_dim),
                             ("embed", None), dt, "fan_in")
    out["kv_norm"] = ParamMeta((m.kv_lora_rank,), (None,), dt, "ones")
    out["wkv_b"] = ParamMeta((m.kv_lora_rank,
                              h * (m.qk_nope_dim + m.v_head_dim)),
                             (None, "heads"), dt, "fan_in")
    out["wo"] = ParamMeta((h * m.v_head_dim, d), ("heads", "embed"), dt,
                          "fan_in")
    return out


def _rms(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    return (x32 * jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    if m.q_lora_rank:
        cq = _rms(linear(x, p["wq_a"]), p["q_norm"])
        q = linear(cq, p["wq_b"]).reshape(b, s, h, qk)
    else:
        q = linear(x, p["wq"]).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, x, cfg, positions):
    m = cfg.mla
    kv_a = linear(x, p["wkv_a"])
    c_kv = _rms(kv_a[..., : m.kv_lora_rank], p["kv_norm"])  # [B,S,C]
    k_rope = kv_a[..., m.kv_lora_rank:][:, :, None, :]       # [B,S,1,R]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, x, cfg, *, positions, causal: bool = True,
                  **_ignored) -> jax.Array:
    """Expanded (train/prefill) MLA: materialize per-head K/V."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions)
    kv = linear(c_kv, p["wkv_b"]).reshape(b, s, h,
                                          m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_dim))], -1)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    mask = make_mask(positions, positions, causal=causal)
    out = _sdpa_ref(q, k, v, mask, scale)
    return linear(out.reshape(b, s, -1), p["wo"])


def mla_cache_spec(cfg, batch: int, max_seq: int, window: int = 0,
                   paged=None):
    """MLA caches the *latent* (c_kv, k_rope) — the memory win of MLA."""
    if paged is not None:
        raise NotImplementedError(
            "paged KV cache is not implemented for MLA latent caches; "
            "serve MLA models with ServingPolicy(cache='dense')")
    m = cfg.mla
    dt = cfg.resolved_cache_dtype
    return {
        "c_kv": ParamMeta((batch, max_seq, m.kv_lora_rank),
                          ("batch", "seq_shard", None), dt, "zeros"),
        "k_rope": ParamMeta((batch, max_seq, m.qk_rope_dim),
                            ("batch", "seq_shard", None), dt, "zeros"),
    }


def mla_prefill(p, x, cfg, *, positions, max_seq: int, window: int = 0,
                prefix_len: int = 0):
    out = mla_attention(p, x, cfg, positions=positions)
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions)
    c_kv = c_kv.astype(cfg.resolved_cache_dtype)
    k_rope = k_rope.astype(cfg.resolved_cache_dtype)
    s = x.shape[1]
    pad = max_seq - s
    cache = {"c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
             "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))}
    return out, cache


def mla_decode(p, cache, x, cfg, *, pos, window: int = 0, attend_fn=None,
               block_table=None):
    """Absorbed-matmul decode on the latent cache (DeepSeek-V2 appendix).

    Per head: score = q_nopeᵀ·W_uk·c + q_ropeᵀ·k_rope, so W_uk is folded
    into q once per step and attention runs in the compressed space — the
    cache is (kv_lora + rope) wide instead of heads×(nope+v).
    """
    if block_table is not None:
        raise NotImplementedError(
            "paged KV cache is not implemented for MLA decode")
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    pos, pos_arr, per_slot = _decode_positions(pos, b)
    q_nope, q_rope = _mla_q(p, x, cfg, pos_arr)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]              # [B,H,*]
    c_kv_new, k_rope_new = _mla_kv_latent(p, x, cfg, pos_arr)
    c_kv_new = c_kv_new.astype(cache["c_kv"].dtype)
    k_rope_new = k_rope_new.astype(cache["k_rope"].dtype)
    if per_slot:
        new_c = _batched_cache_update(cache["c_kv"], c_kv_new, pos)
        new_r = _batched_cache_update(cache["k_rope"], k_rope_new, pos)
    else:
        new_c = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new,
                                             (0, pos, 0))
        new_r = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new,
                                             (0, pos, 0))
    # absorb W_uk into q
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_dim]                        # [C,H,N]
    w_uv = wkv_b[..., m.qk_nope_dim:]                         # [C,H,V]
    q_eff = jnp.einsum("bhn,chn->bhc", q_nope, w_uk)          # [B,H,C]
    q_cat = jnp.concatenate([q_eff, q_rope], -1)              # [B,H,C+R]
    kv_cat = jnp.concatenate([new_c, new_r], -1)[:, :, None, :]  # [B,S,1,C+R]
    vals = new_c[:, :, None, :]                               # [B,S,1,C]
    s_cache = new_c.shape[1]
    valid = decode_valid_mask(pos, s_cache)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    attend = (attend_fn or _session_kernels().decode_attention
              or plain_cache_attention)
    o_c = attend(q_cat, kv_cat, vals, valid, scale=scale)     # [B,H,C]
    o = jnp.einsum("bhc,chv->bhv", o_c.astype(jnp.float32),
                   w_uv.astype(jnp.float32)).astype(x.dtype)
    out = linear(o.reshape(b, 1, -1), p["wo"])
    return out, {"c_kv": new_c, "k_rope": new_r}


# ===========================================================================
# cross-attention (encoder-decoder)
# ===========================================================================

def cross_meta(cfg) -> dict[str, ParamMeta]:
    return gqa_meta(cfg)


def cross_attention(p, x, enc_kv, cfg) -> jax.Array:
    """x: [B,Sq,D]; enc_kv: dict with precomputed k,v [B,Sk,Kv,hd]."""
    b, sq, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, sq, h, hd)
    k, v = enc_kv["k"], enc_kv["v"]
    sk = k.shape[1]
    mask = jnp.ones((sq, sk), bool)
    out = _sdpa_ref(q, k, v, mask, 1.0 / math.sqrt(hd))
    return linear(out.reshape(b, sq, -1), p["wo"])


def cross_kv(p, enc_out, cfg):
    b, sk, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": linear(enc_out, p["wk"]).reshape(b, sk, kv, hd),
            "v": linear(enc_out, p["wv"]).reshape(b, sk, kv, hd)}


def cross_decode(p, x, enc_kv, cfg, attend_fn=None):
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, h, hd)
    k, v = enc_kv["k"], enc_kv["v"]
    valid = jnp.ones((k.shape[1],), bool)
    attend = (attend_fn or _session_kernels().decode_attention
              or plain_cache_attention)
    out = attend(q, k, v, valid, scale=1.0 / math.sqrt(hd))
    return linear(out.reshape(b, 1, -1), p["wo"])
