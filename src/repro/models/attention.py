"""Attention variants: GQA/MQA/MHA (+ sliding window, softcap), and
DeepSeek MLA (multi-head latent attention) with matrix-absorbed decode.

Full-sequence paths (train/prefill) support ``attention_impl="pallas"``
(flash-attention kernel) or ``"ref"`` (masked-softmax oracle, also the
dry-run lowering path).  Decode paths produce *partial* (m, l, o) softmax
statistics so the serving layer can combine across sequence-sharded KV
caches (flash-decoding; see repro/serving/decode_attention.py).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import apply_rope, linear, softcap
from .meta import ParamMeta

NEG_INF = -2.0 ** 30  # finite: keeps fully-masked rows NaN-free


def _session_kernels():
    from repro.runtime import current_session

    return current_session().kernels


# ===========================================================================
# masks
# ===========================================================================

def make_mask(q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool = True,
              window: int = 0, prefix_len: int = 0) -> jax.Array:
    """[Sq, Sk] boolean mask. window>0 = sliding window; prefix positions
    (< prefix_len) are bidirectionally visible (PaLI-style prefix-LM)."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    m = (q >= k) if causal else jnp.ones((q_pos.shape[0], kv_pos.shape[0]),
                                         bool)
    if window > 0:
        m = m & (q - k < window)
    if prefix_len > 0:
        m = m | (k < prefix_len)
    return m


# ===========================================================================
# GQA
# ===========================================================================

def gqa_meta(cfg) -> dict[str, ParamMeta]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.param_dtype
    return {
        "wq": ParamMeta((d, h * hd), ("embed", "heads"), dt, "fan_in"),
        "wk": ParamMeta((d, kv * hd), ("embed", "kv_heads"), dt, "fan_in"),
        "wv": ParamMeta((d, kv * hd), ("embed", "kv_heads"), dt, "fan_in"),
        "wo": ParamMeta((h * hd, d), ("heads", "embed"), dt, "fan_in"),
    }


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, s, h, hd)
    k = linear(x, p["wk"]).reshape(b, s, kv, hd)
    v = linear(x, p["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_ref(q, k, v, mask, scale, cap: float = 0.0):
    """Reference grouped attention. q:[B,S,H,D] k/v:[B,S,Kv,D]."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])  # dv may differ from dk (MLA)


def _sdpa_blockwise(q, k, v, positions, *, causal, window, prefix_len,
                    scale, cap: float = 0.0, q_chunk: int = 1024):
    """Query-chunked attention: never materializes the [S, S] score matrix.

    Peak scores buffer is [B, Kv, G, q_chunk, S] instead of O(S²) — the
    memory-term optimization for long-sequence prefill (§Perf).  Exact
    (full softmax row per chunk; the key axis is never split).
    """
    b, s, h, d = q.shape
    while s % q_chunk != 0:
        q_chunk //= 2
    n = s // q_chunk
    qc = q.reshape(b, n, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = positions.reshape(n, q_chunk)

    def one_chunk(args):
        qi, pi = args
        mask = make_mask(pi, positions, causal=causal, window=window,
                         prefix_len=prefix_len)
        return _sdpa_ref(qi, k, v, mask, scale, cap)

    out = jax.lax.map(one_chunk, (qc, pc))            # [n, B, qc, H, Dv]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])


def gqa_attention(p, x, cfg, *, positions, window: int = 0,
                  prefix_len: int = 0, causal: bool = True) -> jax.Array:
    """Full-sequence attention (train/prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    override = _session_kernels().attention
    if override is not None:
        out = override(q, k, v, positions=positions, causal=causal,
                       window=window, prefix_len=prefix_len, scale=scale,
                       cap=cfg.logit_softcap)
    elif cfg.attention_impl == "pallas" and jax.default_backend() == "tpu":
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, softcap=cfg.logit_softcap)
    elif cfg.attention_impl == "blockwise":
        out = _sdpa_blockwise(q, k, v, positions, causal=causal,
                              window=window, prefix_len=prefix_len,
                              scale=scale, cap=cfg.logit_softcap)
    else:
        mask = make_mask(positions, positions, causal=causal, window=window,
                         prefix_len=prefix_len)
        out = _sdpa_ref(q, k, v, mask, scale, cfg.logit_softcap)
    return linear(out.reshape(b, s, -1), p["wo"])


def gqa_cache_spec(cfg, batch: int, max_seq: int, window: int = 0):
    """Cache metas for one layer. Window layers get ring buffers."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    s = min(window, max_seq) if window > 0 else max_seq
    seq_ax = None if window > 0 else "seq_shard"
    dt = cfg.resolved_cache_dtype
    return {
        "k": ParamMeta((batch, s, kv, hd),
                       ("batch", seq_ax, "kv_heads", None), dt, "zeros"),
        "v": ParamMeta((batch, s, kv, hd),
                       ("batch", seq_ax, "kv_heads", None), dt, "zeros"),
    }


def gqa_prefill(p, x, cfg, *, positions, window: int = 0, max_seq: int,
                prefix_len: int = 0):
    """Full-seq attention + build the decode cache."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    mask = make_mask(positions, positions, window=window,
                     prefix_len=prefix_len)
    out = _sdpa_ref(q, k, v, mask, scale, cfg.logit_softcap)
    out = linear(out.reshape(b, s, -1), p["wo"])
    cache = _write_prefill_cache(k, v, cfg, window, max_seq)
    return out, cache


def _write_prefill_cache(k, v, cfg, window, max_seq):
    k = k.astype(cfg.resolved_cache_dtype)
    v = v.astype(cfg.resolved_cache_dtype)
    b, s = k.shape[:2]
    if window > 0:
        w = min(window, max_seq)
        if s >= w:
            # ring-buffer layout: slot i holds position p with p % w == i,
            # matching decode's `slot = pos % w` convention
            shift = (s - w) % w
            kw = jnp.roll(k[:, -w:], shift, axis=1)
            vw = jnp.roll(v[:, -w:], shift, axis=1)
        else:
            kw = jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            vw = jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        return {"k": kw, "v": vw}
    pad = max_seq - s
    return {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}


def _decode_positions(pos, b):
    """Normalize decode position(s): scalar -> (rope positions [1],
    per_slot=False); per-slot [B] array -> ([B, 1], per_slot=True)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return pos, jnp.full((1,), pos, jnp.int32), False
    if pos.ndim != 1 or pos.shape[0] != b:
        raise ValueError(f"pos must be scalar or [batch]; got {pos.shape}")
    return pos, pos[:, None], True


def _batched_cache_update(cache, new, slot):
    """Write ``new`` [B, 1, ...] at a per-batch position ``slot`` [B]."""
    def upd(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s,) + (0,) * (c.ndim - 1))

    return jax.vmap(upd)(cache, new, slot)


def decode_valid_mask(pos, s_cache, window: int = 0):
    """Causal validity over cache slots: [S] for scalar ``pos``, [B, S]
    for a per-slot position vector.  Once a ring buffer has wrapped
    (``pos + 1 >= s_cache``) every slot holds a live entry."""
    idx = jnp.arange(s_cache)
    if jnp.ndim(pos) == 1:
        idx, pos = idx[None, :], pos[:, None]
    mask = idx <= pos
    if window > 0:
        mask = mask | (pos + 1 >= s_cache)
    return mask


def gqa_decode(p, cache, x, cfg, *, pos, window: int = 0, attend_fn=None):
    """One decode step. x: [B, 1, D]; pos: scalar position shared by the
    whole batch, or a [B] int vector of *per-slot* positions (continuous
    batching admits requests mid-flight, so slots decode at different
    depths).

    ``attend_fn(q, k, v, valid)`` lets the serving layer substitute a
    sequence-sharded flash-decoding implementation; when omitted, the
    session's ``kernels.decode_attention`` override applies (ring-buffer
    window caches stay local and always use plain cache attention).
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pos, pos_arr, per_slot = _decode_positions(pos, b)
    q = linear(x, p["wq"]).reshape(b, 1, h, hd)
    k = linear(x, p["wk"]).reshape(b, 1, kv, hd)
    v = linear(x, p["wv"]).reshape(b, 1, kv, hd)
    q = apply_rope(q, pos_arr, cfg.rope_theta)[:, 0]          # [B, H, D]
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    k = k.astype(cache["k"].dtype)                            # fp8 cache opt
    v = v.astype(cache["v"].dtype)
    s_cache = cache["k"].shape[1]
    slot = jnp.mod(pos, s_cache) if window > 0 else pos
    if per_slot:
        new_k = _batched_cache_update(cache["k"], k, slot)
        new_v = _batched_cache_update(cache["v"], v, slot)
    else:
        new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    valid = decode_valid_mask(pos, s_cache, window)
    scale = 1.0 / math.sqrt(hd)
    attend = attend_fn
    if attend is None and window == 0:
        attend = _session_kernels().decode_attention
    attend = attend or plain_cache_attention
    out = attend(q, new_k, new_v, valid, scale=scale,
                 cap=cfg.logit_softcap)
    out = linear(out.reshape(b, 1, -1), p["wo"])
    return out, {"k": new_k, "v": new_v}


# ===========================================================================
# cache attention core (shared by GQA decode and MLA absorbed decode)
# ===========================================================================

def partial_cache_attention(q, k, v, valid, *, scale, cap: float = 0.0):
    """Partial softmax stats for flash-decoding combine.

    q: [B, H, Dk]; k: [B, S, Kv, Dk]; v: [B, S, Kv, Dv]; valid: [S] bool
    (shared across the batch) or [B, S] (per-slot decode depths).
    Caches may be stored quantized (fp8) — math upcasts to q's dtype.
    Returns m: [B, Kv, G], l: [B, Kv, G], o: [B, Kv, G, Dv].
    """
    b, h, dk = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dk)
    k = k.astype(q.dtype)
    v = v.astype(q.dtype)
    vmask = (valid if valid.ndim == 2 else valid[None])[:, None, None, :]
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    scores = jnp.where(vmask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                                 # [B,Kv,G]
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(vmask, e, 0.0)
    l = jnp.sum(e, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", e.astype(v.dtype), v).astype(
        jnp.float32)
    return m, l, o


def plain_cache_attention(q, k, v, valid, *, scale, cap: float = 0.0):
    """Unsharded decode attention; returns [B, H, Dv] in q's dtype."""
    m, l, o = partial_cache_attention(q, k, v, valid, scale=scale, cap=cap)
    b, kvh, g, dv = o.shape
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, kvh * g, dv).astype(q.dtype)


# ===========================================================================
# MLA (DeepSeek multi-head latent attention)
# ===========================================================================

def mla_meta(cfg) -> dict[str, ParamMeta]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.param_dtype
    qk = m.qk_nope_dim + m.qk_rope_dim
    out: dict[str, ParamMeta] = {}
    if m.q_lora_rank:
        out["wq_a"] = ParamMeta((d, m.q_lora_rank), ("embed", None), dt,
                                "fan_in")
        out["q_norm"] = ParamMeta((m.q_lora_rank,), (None,), dt, "ones")
        out["wq_b"] = ParamMeta((m.q_lora_rank, h * qk), (None, "heads"), dt,
                                "fan_in")
    else:
        out["wq"] = ParamMeta((d, h * qk), ("embed", "heads"), dt, "fan_in")
    out["wkv_a"] = ParamMeta((d, m.kv_lora_rank + m.qk_rope_dim),
                             ("embed", None), dt, "fan_in")
    out["kv_norm"] = ParamMeta((m.kv_lora_rank,), (None,), dt, "ones")
    out["wkv_b"] = ParamMeta((m.kv_lora_rank,
                              h * (m.qk_nope_dim + m.v_head_dim)),
                             (None, "heads"), dt, "fan_in")
    out["wo"] = ParamMeta((h * m.v_head_dim, d), ("heads", "embed"), dt,
                          "fan_in")
    return out


def _rms(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    return (x32 * jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    if m.q_lora_rank:
        cq = _rms(linear(x, p["wq_a"]), p["q_norm"])
        q = linear(cq, p["wq_b"]).reshape(b, s, h, qk)
    else:
        q = linear(x, p["wq"]).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, x, cfg, positions):
    m = cfg.mla
    kv_a = linear(x, p["wkv_a"])
    c_kv = _rms(kv_a[..., : m.kv_lora_rank], p["kv_norm"])  # [B,S,C]
    k_rope = kv_a[..., m.kv_lora_rank:][:, :, None, :]       # [B,S,1,R]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, x, cfg, *, positions, causal: bool = True,
                  **_ignored) -> jax.Array:
    """Expanded (train/prefill) MLA: materialize per-head K/V."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions)
    kv = linear(c_kv, p["wkv_b"]).reshape(b, s, h,
                                          m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_dim))], -1)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    mask = make_mask(positions, positions, causal=causal)
    out = _sdpa_ref(q, k, v, mask, scale)
    return linear(out.reshape(b, s, -1), p["wo"])


def mla_cache_spec(cfg, batch: int, max_seq: int, window: int = 0):
    """MLA caches the *latent* (c_kv, k_rope) — the memory win of MLA."""
    m = cfg.mla
    dt = cfg.resolved_cache_dtype
    return {
        "c_kv": ParamMeta((batch, max_seq, m.kv_lora_rank),
                          ("batch", "seq_shard", None), dt, "zeros"),
        "k_rope": ParamMeta((batch, max_seq, m.qk_rope_dim),
                            ("batch", "seq_shard", None), dt, "zeros"),
    }


def mla_prefill(p, x, cfg, *, positions, max_seq: int, window: int = 0,
                prefix_len: int = 0):
    out = mla_attention(p, x, cfg, positions=positions)
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions)
    c_kv = c_kv.astype(cfg.resolved_cache_dtype)
    k_rope = k_rope.astype(cfg.resolved_cache_dtype)
    s = x.shape[1]
    pad = max_seq - s
    cache = {"c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
             "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))}
    return out, cache


def mla_decode(p, cache, x, cfg, *, pos, window: int = 0, attend_fn=None):
    """Absorbed-matmul decode on the latent cache (DeepSeek-V2 appendix).

    Per head: score = q_nopeᵀ·W_uk·c + q_ropeᵀ·k_rope, so W_uk is folded
    into q once per step and attention runs in the compressed space — the
    cache is (kv_lora + rope) wide instead of heads×(nope+v).
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    pos, pos_arr, per_slot = _decode_positions(pos, b)
    q_nope, q_rope = _mla_q(p, x, cfg, pos_arr)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]              # [B,H,*]
    c_kv_new, k_rope_new = _mla_kv_latent(p, x, cfg, pos_arr)
    c_kv_new = c_kv_new.astype(cache["c_kv"].dtype)
    k_rope_new = k_rope_new.astype(cache["k_rope"].dtype)
    if per_slot:
        new_c = _batched_cache_update(cache["c_kv"], c_kv_new, pos)
        new_r = _batched_cache_update(cache["k_rope"], k_rope_new, pos)
    else:
        new_c = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new,
                                             (0, pos, 0))
        new_r = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new,
                                             (0, pos, 0))
    # absorb W_uk into q
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_dim]                        # [C,H,N]
    w_uv = wkv_b[..., m.qk_nope_dim:]                         # [C,H,V]
    q_eff = jnp.einsum("bhn,chn->bhc", q_nope, w_uk)          # [B,H,C]
    q_cat = jnp.concatenate([q_eff, q_rope], -1)              # [B,H,C+R]
    kv_cat = jnp.concatenate([new_c, new_r], -1)[:, :, None, :]  # [B,S,1,C+R]
    vals = new_c[:, :, None, :]                               # [B,S,1,C]
    s_cache = new_c.shape[1]
    valid = decode_valid_mask(pos, s_cache)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    attend = (attend_fn or _session_kernels().decode_attention
              or plain_cache_attention)
    o_c = attend(q_cat, kv_cat, vals, valid, scale=scale)     # [B,H,C]
    o = jnp.einsum("bhc,chv->bhv", o_c.astype(jnp.float32),
                   w_uv.astype(jnp.float32)).astype(x.dtype)
    out = linear(o.reshape(b, 1, -1), p["wo"])
    return out, {"c_kv": new_c, "k_rope": new_r}


# ===========================================================================
# cross-attention (encoder-decoder)
# ===========================================================================

def cross_meta(cfg) -> dict[str, ParamMeta]:
    return gqa_meta(cfg)


def cross_attention(p, x, enc_kv, cfg) -> jax.Array:
    """x: [B,Sq,D]; enc_kv: dict with precomputed k,v [B,Sk,Kv,hd]."""
    b, sq, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, sq, h, hd)
    k, v = enc_kv["k"], enc_kv["v"]
    sk = k.shape[1]
    mask = jnp.ones((sq, sk), bool)
    out = _sdpa_ref(q, k, v, mask, 1.0 / math.sqrt(hd))
    return linear(out.reshape(b, sq, -1), p["wo"])


def cross_kv(p, enc_out, cfg):
    b, sk, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": linear(enc_out, p["wk"]).reshape(b, sk, kv, hd),
            "v": linear(enc_out, p["wv"]).reshape(b, sk, kv, hd)}


def cross_decode(p, x, enc_kv, cfg, attend_fn=None):
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"]).reshape(b, h, hd)
    k, v = enc_kv["k"], enc_kv["v"]
    valid = jnp.ones((k.shape[1],), bool)
    attend = (attend_fn or _session_kernels().decode_attention
              or plain_cache_attention)
    out = attend(q, k, v, valid, scale=1.0 / math.sqrt(hd))
    return linear(out.reshape(b, 1, -1), p["wo"])
