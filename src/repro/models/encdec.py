"""Encoder-decoder backbone (Whisper-family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings [B, S_enc, d_model].  Encoder layers
are bidirectional; decoder layers are causal self-attention + cross-
attention + FFN.  RoPE is used for both stacks (deviation from Whisper's
learned/sinusoidal embeddings — positional params would couple parameter
shapes to sequence length; noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from .blocks import DecoderLayer, LayerSig, Stage, _remat
from .layers import (apply_mlp, apply_norm, embed, embed_meta, mlp_meta,
                     norm_meta, unembed)
from .meta import ParamMeta, stack_tree, tree_init, tree_structs


class EncDecDecoderLayer:
    """Causal self-attention + cross-attention + FFN."""

    def __init__(self, cfg):
        self.cfg = cfg

    def abstract(self):
        cfg = self.cfg
        return {"norm1": norm_meta(cfg), "self_attn": attn.gqa_meta(cfg),
                "norm_x": norm_meta(cfg), "cross": attn.cross_meta(cfg),
                "norm2": norm_meta(cfg), "mlp": mlp_meta(cfg)}

    def apply(self, p, x, enc_out, *, positions):
        from repro.sharding.context import constrain_batch

        cfg = self.cfg
        x = constrain_batch(x)
        enc_out = constrain_batch(enc_out)
        h = apply_norm(p["norm1"], x, cfg)
        x = x + attn.gqa_attention(p["self_attn"], h, cfg,
                                   positions=positions)
        h = apply_norm(p["norm_x"], x, cfg)
        kv = attn.cross_kv(p["cross"], enc_out, cfg)
        x = x + attn.cross_attention(p["cross"], h, kv, cfg)
        h = apply_norm(p["norm2"], x, cfg)
        return x + apply_mlp(p["mlp"], h, cfg)

    def cache_spec(self, batch: int, max_seq: int, enc_len: int):
        cfg = self.cfg
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return {
            "self": attn.gqa_cache_spec(cfg, batch, max_seq),
            "cross_k": ParamMeta((batch, enc_len, kvh, hd),
                                 ("batch", None, "kv_heads", None),
                                 cfg.compute_dtype, "zeros"),
            "cross_v": ParamMeta((batch, enc_len, kvh, hd),
                                 ("batch", None, "kv_heads", None),
                                 cfg.compute_dtype, "zeros"),
        }

    def prefill(self, p, x, enc_out, *, positions, max_seq: int):
        cfg = self.cfg
        h = apply_norm(p["norm1"], x, cfg)
        h, self_cache = attn.gqa_prefill(p["self_attn"], h, cfg,
                                         positions=positions,
                                         max_seq=max_seq)
        x = x + h
        h = apply_norm(p["norm_x"], x, cfg)
        kv = attn.cross_kv(p["cross"], enc_out, cfg)
        x = x + attn.cross_attention(p["cross"], h, kv, cfg)
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
        return x, {"self": self_cache, "cross_k": kv["k"],
                   "cross_v": kv["v"]}

    def decode(self, p, cache, x, *, pos, attend_fn=None):
        cfg = self.cfg
        h = apply_norm(p["norm1"], x, cfg)
        h, self_cache = attn.gqa_decode(p["self_attn"], cache["self"], h,
                                        cfg, pos=pos, attend_fn=attend_fn)
        x = x + h
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + attn.cross_decode(p["cross"], h,
                                  {"k": cache["cross_k"],
                                   "v": cache["cross_v"]}, cfg)
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
        return x, {"self": self_cache, "cross_k": cache["cross_k"],
                   "cross_v": cache["cross_v"]}


class EncDecLM:
    """Whisper-style backbone; encoder input is stubbed frame embeddings."""

    def __init__(self, cfg):
        self.cfg = cfg
        enc_sig = LayerSig(kind="A", causal=False)
        self.encoder = Stage(cfg, DecoderLayer(cfg, enc_sig),
                             cfg.encoder_layers)
        self.dec_layer = EncDecDecoderLayer(cfg)
        self.n_dec = cfg.n_layers
        self.scan_dec = cfg.scan_layers and self.n_dec > 1

    # -- params -------------------------------------------------------------
    def abstract_params(self):
        cfg = self.cfg
        dec = self.dec_layer.abstract()
        return {
            "embed": embed_meta(cfg),
            "encoder": self.encoder.abstract(),
            "enc_norm": norm_meta(cfg),
            "decoder": (stack_tree(dec, self.n_dec) if self.scan_dec
                        else {f"r{i}": self.dec_layer.abstract()
                              for i in range(self.n_dec)}),
            "final_norm": norm_meta(cfg),
            "lm_head": ParamMeta((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), cfg.param_dtype,
                                 "normal", 0.02),
        }

    def init(self, key):
        return tree_init(self.abstract_params(), key)

    def param_structs(self):
        return tree_structs(self.abstract_params())

    # -- forward -----------------------------------------------------------------
    def encode(self, p, frames):
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        positions = jnp.arange(x.shape[1])
        x, _ = self.encoder.apply(p["encoder"], x, positions=positions)
        return apply_norm(p["enc_norm"], x, cfg)

    def _decode_trunk(self, p, x, enc_out, positions):
        if self.scan_dec:
            def body(h, layer_p):
                return self.dec_layer.apply(layer_p, h, enc_out,
                                            positions=positions), None

            body = _remat(body, self.cfg.remat)
            x, _ = jax.lax.scan(body, x, p["decoder"])
        else:
            for i in range(self.n_dec):
                x = self.dec_layer.apply(p["decoder"][f"r{i}"], x, enc_out,
                                         positions=positions)
        return x

    def forward(self, p, batch):
        cfg = self.cfg
        enc_out = self.encode(p, batch["frames"])
        x = embed(p["embed"], batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])
        x = self._decode_trunk(p, x, enc_out, positions)
        h = apply_norm(p["final_norm"], x, cfg)
        return unembed(h, p["lm_head"], cfg)

    def loss_fn(self, p, batch):
        from .transformer import cross_entropy_loss

        logits = self.forward(p, batch)
        loss = cross_entropy_loss(logits, batch["labels"])
        return loss, {"ce": loss}

    # -- serving --------------------------------------------------------------------
    def cache_spec(self, batch: int, max_seq: int, enc_len: int):
        spec = self.dec_layer.cache_spec(batch, max_seq, enc_len)
        if self.scan_dec:
            return stack_tree(spec, self.n_dec)
        return {f"r{i}": self.dec_layer.cache_spec(batch, max_seq, enc_len)
                for i in range(self.n_dec)}

    def init_cache(self, batch: int, max_seq: int, enc_len: int):
        return tree_init(self.cache_spec(batch, max_seq, enc_len),
                         jax.random.PRNGKey(0))

    def prefill(self, p, frames, tokens, *, max_seq: int):
        cfg = self.cfg
        enc_out = self.encode(p, frames)
        x = embed(p["embed"], tokens, cfg)
        positions = jnp.arange(x.shape[1])
        if self.scan_dec:
            def body(h, layer_p):
                return self.dec_layer.prefill(layer_p, h, enc_out,
                                              positions=positions,
                                              max_seq=max_seq)

            x, caches = jax.lax.scan(body, x, p["decoder"])
        else:
            caches = {}
            for i in range(self.n_dec):
                x, caches[f"r{i}"] = self.dec_layer.prefill(
                    p["decoder"][f"r{i}"], x, enc_out, positions=positions,
                    max_seq=max_seq)
        h = apply_norm(p["final_norm"], x[:, -1:], cfg)
        return unembed(h, p["lm_head"], cfg)[:, 0], caches

    def decode_step(self, p, cache, token, pos, *, attend_fn=None):
        cfg = self.cfg
        x = embed(p["embed"], token, cfg)
        if self.scan_dec:
            def body(h, inp):
                layer_p, layer_cache = inp
                return self.dec_layer.decode(layer_p, layer_cache, h,
                                             pos=pos, attend_fn=attend_fn)

            x, new_cache = jax.lax.scan(body, x, (p["decoder"], cache))
        else:
            new_cache = {}
            for i in range(self.n_dec):
                x, new_cache[f"r{i}"] = self.dec_layer.decode(
                    p["decoder"][f"r{i}"], cache[f"r{i}"], x, pos=pos,
                    attend_fn=attend_fn)
        h = apply_norm(p["final_norm"], x, cfg)
        return unembed(h, p["lm_head"], cfg)[:, 0], new_cache
