"""Mixture-of-experts layer with expert parallelism.

Two dispatch implementations behind ``cfg.moe_impl``:

* ``scatter`` (production): tokens are flattened, topk-routed, sorted by
  expert, placed into per-expert capacity buckets via scatter-add, expert
  FFNs run as one batched einsum over the expert axis (sharded over the
  ``model`` mesh axis → GSPMD inserts the all-to-alls), and gathered back.
  O(T·k) routing state — no dense [T, E, C] dispatch tensor.
* ``dense`` (oracle): per-expert masked einsum without capacity drops.
  Exact but O(T·E); used by smoke tests to validate ``scatter`` and by
  tiny-model training.

Load-balance auxiliary loss follows Switch/DeepSeek: mean(fraction of
tokens per expert × mean router prob per expert) · E · coef.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import linear
from .meta import ParamMeta


def moe_meta(cfg) -> dict[str, ParamMeta]:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.param_dtype
    out = {
        "router": ParamMeta((d, m.n_routed), ("embed", None), jnp.float32,
                            "normal", 0.02),
        "w_up": ParamMeta((m.n_routed, d, m.d_expert),
                          ("experts", "embed", "expert_mlp"), dt, "fan_in"),
        "w_gate": ParamMeta((m.n_routed, d, m.d_expert),
                            ("experts", "embed", "expert_mlp"), dt, "fan_in"),
        "w_down": ParamMeta((m.n_routed, m.d_expert, d),
                            ("experts", "expert_mlp", "embed"), dt, "fan_in"),
    }
    if m.n_shared > 0:
        ds = m.n_shared * m.d_expert
        out["shared_up"] = ParamMeta((d, ds), ("embed", "mlp"), dt, "fan_in")
        out["shared_gate"] = ParamMeta((d, ds), ("embed", "mlp"), dt,
                                       "fan_in")
        out["shared_down"] = ParamMeta((ds, d), ("mlp", "embed"), dt,
                                       "fan_in")
    return out


def _router(p, x2d, m):
    """Returns (weights [T,k], expert_idx [T,k], aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [T,E]
    weights, idx = jax.lax.top_k(probs, m.top_k)             # [T,k]
    weights = weights / jnp.maximum(
        weights.sum(-1, keepdims=True), 1e-9)                # renormalize
    # Switch aux loss
    t = x2d.shape[0]
    me = probs.mean(0)                                       # [E]
    ce = jnp.zeros((m.n_routed,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (t * m.top_k))
    aux = m.n_routed * jnp.sum(me * ce) * m.router_aux_coef
    return weights, idx, aux


def _expert_ffn(p, h):
    """h: [E, C, D] -> [E, C, D]; batched over the (sharded) expert axis."""
    up = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h,
                                  p["w_gate"]).astype(jnp.float32))
    return jnp.einsum("ecf,efd->ecd", (gate.astype(h.dtype) * up),
                      p["w_down"])


def _moe_scatter(p, x2d, m, cfg):
    t, d = x2d.shape
    k, e = m.top_k, m.n_routed
    weights, idx, aux = _router(p, x2d, m)
    cap = max(1, int(m.capacity_factor * t * k / e))
    cap = min(cap, t)  # never more slots than tokens

    flat_e = idx.reshape(-1)                                  # [T*k]
    tok_of = jnp.arange(t * k) // k
    # rank within expert via stable sort
    order = jnp.argsort(flat_e, stable=True)                  # [T*k]
    counts = jnp.bincount(flat_e, length=e)                   # [E]
    seg_start = jnp.cumsum(counts) - counts                   # [E]
    rank_sorted = jnp.arange(t * k) - seg_start[flat_e[order]]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap
    dest = flat_e * cap + jnp.minimum(rank, cap - 1)          # [T*k]

    buf = jnp.zeros((e * cap, d), x2d.dtype)
    contrib = jnp.where(keep[:, None], x2d[tok_of], 0).astype(x2d.dtype)
    buf = buf.at[dest].add(contrib)
    # pin the dispatch buffer to expert parallelism: experts on the model
    # axis, capacity slots on the batch axes (GSPMD emits the all-to-alls)
    from repro.sharding.context import constrain

    buf = constrain(buf.reshape(e, cap, d), ("model", ("pod", "data"), None))
    h = _expert_ffn(p, buf)
    h = constrain(h, ("model", ("pod", "data"), None)).reshape(e * cap, d)
    out_slots = h[dest]                                       # [T*k, D]
    w = (weights.reshape(-1) * keep).astype(x2d.dtype)
    out = jnp.zeros((t, d), x2d.dtype).at[tok_of].add(
        out_slots * w[:, None])
    out = constrain(out, (("pod", "data"), None))
    return out, aux


def _moe_dense(p, x2d, m, cfg):
    """Oracle: no capacity, exact top-k routing via dense mask."""
    t, d = x2d.shape
    weights, idx, aux = _router(p, x2d, m)
    mask = jax.nn.one_hot(idx, m.n_routed, dtype=x2d.dtype)   # [T,k,E]
    comb = (mask * weights[..., None].astype(x2d.dtype)).sum(1)  # [T,E]
    h = jnp.einsum("td,te->etd", x2d, comb)                   # [E,T,D] weighted
    # run each expert on ALL tokens (oracle-only cost)
    out_e = _expert_ffn(p, jnp.broadcast_to(x2d[None], (m.n_routed, t, d)))
    out = jnp.einsum("etd,te->td", out_e, comb)
    del h
    return out, aux


def _bucket_by(key, values, n_buckets, cap, fill):
    """Scatter ``values`` rows into [n_buckets*cap, D] capacity slots.

    Returns (buffer, slot, keep): slot[i] is where row i landed; rows past
    capacity are dropped (keep=False).  Pure local compute (sort+scatter).
    """
    n = key.shape[0]
    order = jnp.argsort(key, stable=True)
    counts = jnp.bincount(key, length=n_buckets)
    seg = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(n) - seg[key[order]]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap
    slot = key * cap + jnp.minimum(rank, cap - 1)
    buf = jnp.full((n_buckets * cap,) + values.shape[1:], fill,
                   values.dtype)
    buf = buf.at[slot].add(jnp.where(
        keep.reshape((-1,) + (1,) * (values.ndim - 1)), values, 0))
    return buf, slot, keep


def _moe_a2a(p, x2d, m, cfg, mesh, token_axes, expert_axis="model"):
    """Expert parallelism with explicit all-to-all token exchange.

    The GSPMD scatter formulation all-reduces the full [E·C, D] dispatch
    buffer per layer (measured: 17.7 TB/device/step on DeepSeek-V3 —
    §Perf log).  Here each token shard routes locally, exchanges only its
    own routed tokens (≈ cf·T_local·k·D bytes) over the expert axis, runs
    local expert FFNs, and reverses the exchange — the collective volume
    drops by ~E/ep·(T_global/T_local).
    """
    ep = mesh.shape[expert_axis]
    e_local = m.n_routed // ep
    tok_spec = (tuple(token_axes) if len(token_axes) != 1
                else token_axes[0]) or None

    def body(x_loc, router, w_up, w_gate, w_down):
        t_loc, d = x_loc.shape
        k = m.top_k
        logits = (x_loc.astype(jnp.float32) @ router)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, idx = jax.lax.top_k(probs, k)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True),
                                        1e-9)
        # load-balance aux from shard-local stats, averaged over shards
        me = probs.mean(0)
        ce = jnp.zeros((m.n_routed,), jnp.float32).at[idx.reshape(-1)].add(
            1.0 / (t_loc * k))
        aux = m.n_routed * jnp.sum(me * ce) * m.router_aux_coef
        if token_axes:
            aux = jax.lax.pmean(aux, tuple(token_axes))

        flat_e = idx.reshape(-1).astype(jnp.int32)           # [t*k]
        tok_of = jnp.arange(t_loc * k) // k
        dest = flat_e // e_local                             # owner shard
        cap = max(1, int(m.capacity_factor * t_loc * k / ep))
        send_tok, slot, keep = _bucket_by(dest, x_loc[tok_of], ep, cap,
                                          0)
        send_eid = jnp.full((ep * cap,), -1, jnp.int32).at[slot].max(
            jnp.where(keep, flat_e % e_local, -1))
        # exchange: shard j's block i goes to shard i
        recv_tok = jax.lax.all_to_all(
            send_tok.reshape(ep, cap, d), expert_axis, 0, 0)
        recv_eid = jax.lax.all_to_all(
            send_eid.reshape(ep, cap), expert_axis, 0, 0).reshape(-1)
        slots = recv_tok.reshape(ep * cap, d)
        valid = recv_eid >= 0
        # local per-expert capacity bucketing; invalid slots go to an
        # overflow bucket so they can't displace real tokens
        cap2 = (ep * cap) // e_local + 1
        buf, slot2, keep2 = _bucket_by(
            jnp.where(valid, recv_eid, e_local).astype(jnp.int32),
            jnp.where(valid[:, None], slots, 0), e_local + 1, cap2, 0)
        keep2 = keep2 & valid
        h = _expert_ffn({"w_up": w_up, "w_gate": w_gate, "w_down": w_down},
                        buf[: e_local * cap2].reshape(e_local, cap2, d))
        h_padded = jnp.concatenate(
            [h.reshape(e_local * cap2, d), jnp.zeros((cap2, d), h.dtype)])
        out_slots = h_padded[slot2]
        out_slots = jnp.where(keep2[:, None], out_slots, 0)
        # reverse exchange (all_to_all is its own inverse here)
        back = jax.lax.all_to_all(
            out_slots.reshape(ep, cap, d), expert_axis, 0, 0)
        back = back.reshape(ep * cap, d)[slot]               # [t*k, D]
        w = (weights.reshape(-1) * keep).astype(x_loc.dtype)
        out = jnp.zeros((t_loc, d), x_loc.dtype).at[tok_of].add(
            back * w[:, None])
        return out, aux

    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_spec, None), P(None, None),
                  P(expert_axis, None, None), P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=(P(tok_spec, None), P()),
        check_vma=False,
    )(x2d, p["router"], p["w_up"], p["w_gate"], p["w_down"])
    return out, aux


def _a2a_available(m, cfg, x2d):
    from repro.sharding.context import get_active_mesh, get_batch_axes

    mesh = get_active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    ep = mesh.shape["model"]
    if m.n_routed % ep != 0:
        return None
    # tokens stay sharded over ALL batch axes (incl. the expert axis: EP
    # exchanges between token shards; excluding it would replicate routing)
    token_axes = [a for a in get_batch_axes() if a in mesh.axis_names]
    total = 1
    for a in token_axes:
        total *= mesh.shape[a]
    while token_axes and x2d.shape[0] % total != 0:
        a = token_axes.pop()
        total //= mesh.shape[a]
    return mesh, tuple(token_axes)


def apply_moe(p, x, cfg):
    """x: [B, S, D] -> (out [B,S,D], aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    if cfg.moe_impl == "dense":
        out, aux = _moe_dense(p, x2d, m, cfg)
    elif cfg.moe_impl == "a2a":
        avail = _a2a_available(m, cfg, x2d)
        if avail is not None:
            out, aux = _moe_a2a(p, x2d, m, cfg, avail[0], avail[1])
        else:
            out, aux = _moe_scatter(p, x2d, m, cfg)
    else:
        out, aux = _moe_scatter(p, x2d, m, cfg)
    if m.n_shared > 0:
        up = linear(x2d, p["shared_up"])
        gate = jax.nn.silu(linear(x2d, p["shared_gate"]).astype(
            jnp.float32)).astype(x2d.dtype)
        out = out + linear(gate * up, p["shared_down"])
    return out.reshape(b, s, d), aux
