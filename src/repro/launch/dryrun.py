import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, with 512 placeholder host devices.

For each cell this records (to JSON under artifacts/dryrun/):
  * compile success, lower/compile wall time
  * compiled.memory_analysis() — per-device bytes (proves fit / flags
    over-budget cells)
  * compiled.cost_analysis() — per-device HLO FLOPs and bytes accessed
  * collective byte totals parsed from the post-SPMD HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)
  * MODEL_FLOPS = 6·N(·_active)·tokens for the §Roofline useful-compute ratio

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --variant fsdp ...     # §Perf variants
"""

import argparse
import json
import math
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

import repro
from repro.configs.base import ARCHS, SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.steps import BASELINE, CellPlan, Variant
from repro.models.meta import is_meta

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

VARIANTS = {
    "baseline": BASELINE,
    # §Perf hillclimb variants
    "fsdp": Variant(name="fsdp", sharding="fsdp"),
    "blockwise": Variant(name="blockwise", attention="blockwise"),
    "fsdp_blockwise": Variant(name="fsdp_blockwise", sharding="fsdp",
                              attention="blockwise"),
    "remat_dots": Variant(name="remat_dots", remat="dots"),
    "chunked_ce": Variant(name="chunked_ce", ce="chunked"),
    "gspmd_decode": Variant(name="gspmd_decode", decode="gspmd"),
    "fp8_cache": Variant(name="fp8_cache", cache="fp8"),
    "opt_bf16": Variant(name="opt_bf16", opt_dtype="bf16"),
    "best_train": Variant(name="best_train", sharding="fsdp",
                          attention="blockwise", ce="chunked",
                          remat="dots"),
    "best_train_full": Variant(name="best_train_full", sharding="fsdp",
                               attention="blockwise", ce="chunked",
                               remat="full"),
    # beyond-paper: drop dense TP, keep EP + vocab TP, FSDP everything else
    "ep_fsdp": Variant(name="ep_fsdp", sharding="ep_fsdp"),
    "ep_fsdp_blockwise": Variant(name="ep_fsdp_blockwise",
                                 sharding="ep_fsdp",
                                 attention="blockwise"),
    "best_ep": Variant(name="best_ep", sharding="ep_fsdp",
                       attention="blockwise", ce="chunked",
                       opt_dtype="bf16"),
    "best_ep_dots": Variant(name="best_ep_dots", sharding="ep_fsdp",
                            attention="blockwise", ce="chunked",
                            remat="dots", opt_dtype="bf16"),
    # beyond-paper: explicit all-to-all expert dispatch under shard_map
    "ep_a2a": Variant(name="ep_a2a", sharding="ep_fsdp", moe_impl="a2a"),
    "best_v3": Variant(name="best_v3", sharding="ep_fsdp", moe_impl="a2a",
                       opt_dtype="bf16"),
    "best_v3_dots": Variant(name="best_v3_dots", sharding="ep_fsdp",
                            moe_impl="a2a", opt_dtype="bf16", remat="dots"),
    "gemma_best": Variant(name="gemma_best", sharding="ep_fsdp",
                          opt_dtype="bf16"),
    "gemma_best_dots": Variant(name="gemma_best_dots", sharding="ep_fsdp",
                               opt_dtype="bf16", remat="dots"),
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum wire bytes per collective kind from post-SPMD HLO.

    Approximations (documented in EXPERIMENTS.md): all-reduce moves ~2×
    its per-device payload (reduce-scatter + all-gather phases of a ring);
    the others move ~1× their per-device result/operand.
    """
    defs: dict[str, int] = {}
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # which collective (if any) does this instruction run?
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in rhs or rhs.startswith(f"{c}("):
                op = c
                break
        # store result size of every instruction for operand lookups
        first_paren = rhs.find("(")
        type_str = rhs[:first_paren] if first_paren > 0 else rhs
        defs[name] = _type_bytes(type_str)
        if op is None:
            continue
        payload = defs[name]
        if op == "all-reduce":
            payload *= 2
        elif op == "reduce-scatter":
            # wire ≈ operand size; look it up
            args = rhs[rhs.find("(") + 1: rhs.find(")")]
            ops_bytes = 0
            for a in args.split(","):
                a = a.strip().lstrip("%")
                a = a.split(" ")[-1].lstrip("%")
                ops_bytes += defs.get(a, 0)
            payload = ops_bytes or payload
        totals[op] += payload
        counts[op] += 1
    return {"bytes": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def model_flops(plan: CellPlan) -> dict:
    """6·N·D with MoE-active accounting."""
    cfg, shape = plan.cfg, plan.shape
    total = active = 0
    for m in jax.tree.leaves(plan.param_metas, is_leaf=is_meta):
        n = math.prod(m.shape)
        total += n
        if "experts" in (m.axes or ()):
            frac = cfg.moe.top_k / cfg.moe.n_routed
            active += int(n * frac)
        else:
            active += n
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 3 if shape.kind == "train" else 1   # fwd+bwd vs fwd
    return {"params_total": total, "params_active": active,
            "tokens": tokens,
            "model_flops": 2 * mult * active * tokens}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             variant: Variant, out_dir: Path, keep_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant.name, "chips": mesh_chips(mesh)}
    try:
        plan = CellPlan(cfg, shape, mesh, variant)
        fn, args, in_sh, out_sh, donate = plan.lowerable()
        t0 = time.time()
        with repro.session(mesh=mesh,
                           batch_axes=plan.rules.mesh_axes_for("batch"),
                           sharding_rules=plan.rules,
                           tag=f"dryrun:{arch}/{shape_name}/{variant.name}"
                           ) as sess:
            rec["session"] = sess.describe()
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
        from repro.core.compat import cost_analysis

        cost = cost_analysis(compiled)
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float))
                       and k in ("flops", "bytes accessed",
                                 "bytes accessed output", "transcendentals")}
        hlo = compiled.as_text()
        rec["hlo_chars"] = len(hlo)
        rec["collectives"] = parse_collectives(hlo)   # raw (loop-blind)
        from repro.launch.hlo_analysis import analyze_hlo

        rec["hlo_analysis"] = analyze_hlo(hlo)        # loop-corrected
        rec.update(model_flops(plan))
        rec["sharding_warnings"] = sorted(set(plan.rules.warnings))[:20]
        rec["ok"] = True
        if keep_hlo:
            (out_dir / f"{arch}__{shape_name}.hlo.txt").write_text(hlo)
        del compiled, lowered, hlo
    except Exception as e:  # noqa: BLE001 - record and continue
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have artifacts")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--include-skipped", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16",
                       make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    variant = VARIANTS[args.variant]
    todo = cells()
    if args.arch:
        a = args.arch.replace("-", "_").replace(".", "")
        a = {"codeqwen15_7b": "codeqwen15_7b"}.get(a, a)
        todo = [c for c in todo if c[0] == a or c[0].replace("_", "-") ==
                args.arch]
    if args.shape:
        todo = [c for c in todo if c[1] == args.shape]

    for mesh_name, mesh in meshes:
        out_dir = ART / mesh_name / variant.name
        out_dir.mkdir(parents=True, exist_ok=True)
        for arch, shape_name, skip_reason in todo:
            path = out_dir / f"{arch}__{shape_name}.json"
            if path.exists() and not args.force:
                print(f"[skip-cached] {mesh_name} {arch} {shape_name}")
                continue
            if skip_reason and not args.include_skipped:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "variant": variant.name, "ok": True, "skipped": True,
                       "skip_reason": skip_reason}
                path.write_text(json.dumps(rec, indent=1))
                print(f"[skipped]  {mesh_name} {arch} {shape_name}: "
                      f"{skip_reason[:60]}")
                continue
            print(f"[compile]  {mesh_name} {variant.name} {arch} "
                  f"{shape_name} ...", flush=True)
            rec = run_cell(arch, shape_name, mesh, mesh_name, variant,
                           out_dir, keep_hlo=args.keep_hlo)
            path.write_text(json.dumps(rec, indent=1))
            status = "OK" if rec.get("ok") else "FAIL"
            print(f"[{status}]      {mesh_name} {arch} {shape_name} "
                  f"lower={rec.get('lower_s', '?')}s "
                  f"compile={rec.get('compile_s', '?')}s "
                  f"{rec.get('error', '')}", flush=True)


if __name__ == "__main__":
    main()
