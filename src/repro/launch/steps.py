"""Cell planning: (architecture × input shape × mesh × variant) → jit-able
step function with input/output shardings and ShapeDtypeStruct stand-ins.

This is the shared machinery for the multi-pod dry-run, the trainer, and
the serving engine.  A *variant* bundles the perf knobs hill-climbed in
EXPERIMENTS.md §Perf:

  sharding:   baseline (TP, replicated params over data) | fsdp
  decode:     gspmd (naive; GSPMD gathers the cache)     | flash (SP flash-decoding)
  remat:      none | dots | full
  attention:  ref | blockwise | pallas
  ce:         dense | chunked
  opt_dtype:  f32 | bf16 optimizer moments
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.optim import AdamW
from repro.models import build_model
from repro.models.meta import tree_structs
from repro.serving.decode_attention import make_flash_decode_attend
from repro.sharding.rules import ShardingRules, make_rules


@dataclass
class Variant:
    name: str = "baseline"
    sharding: str = "baseline"       # baseline | fsdp
    decode: str = "flash"            # gspmd | flash
    remat: str = "full"              # none | dots | full
    attention: str = "ref"           # ref | blockwise | pallas
    ce: str = "dense"                # dense | chunked
    opt_dtype: str = "f32"           # f32 | bf16
    cache: str = "compute"           # compute | fp8 quantized KV cache
    scan_layers: bool = True
    moe_impl: str = "scatter"

    def apply_to(self, cfg: ModelConfig) -> ModelConfig:
        return cfg.with_(remat=self.remat, attention_impl=self.attention,
                         ce_impl=self.ce, scan_layers=self.scan_layers,
                         moe_impl=self.moe_impl, cache_dtype=self.cache)


BASELINE = Variant()


class CellPlan:
    """Everything needed to lower one (arch, shape, mesh, variant) cell."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                 variant: Variant = BASELINE):
        self.variant = variant
        self.cfg = variant.apply_to(cfg)
        self.shape = shape
        self.mesh = mesh
        self.rules: ShardingRules = make_rules(variant.sharding)
        self.model = build_model(self.cfg)
        self.param_metas = self.model.abstract_params()
        self.optimizer = AdamW(
            lr=3e-4,
            state_dtype=jnp.bfloat16 if variant.opt_dtype == "bf16" else None)

    # -- shardings ------------------------------------------------------------
    def param_shardings(self):
        return self.rules.tree_shardings(self.param_metas, self.mesh)

    def param_structs(self):
        return tree_structs(self.param_metas)

    def opt_structs(self, param_structs):
        return jax.eval_shape(self.optimizer.init, param_structs)

    def opt_shardings(self, param_shardings):
        return jax.tree.map(self.optimizer.state_sharding_like,
                            param_shardings,
                            is_leaf=lambda x: isinstance(x, NamedSharding))

    def _spec(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.rules.spec(shape, axes,
                                                        self.mesh))

    def _replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- batch structs/shardings ------------------------------------------------
    def train_batch(self):
        cfg, s = self.cfg, self.shape
        b, sl = s.global_batch, s.seq_len
        tok = jax.ShapeDtypeStruct((b, sl), jnp.int32)
        structs: dict[str, Any] = {"tokens": tok, "labels": tok}
        shards = {"tokens": self._spec((b, sl), ("batch", None)),
                  "labels": self._spec((b, sl), ("batch", None))}
        if cfg.family == "encdec":
            half = sl // 2
            structs = {
                "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model),
                                               cfg.compute_dtype),
                "tokens": jax.ShapeDtypeStruct((b, half), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, half), jnp.int32)}
            shards = {
                "frames": self._spec((b, half, cfg.d_model),
                                     ("batch", None, None)),
                "tokens": self._spec((b, half), ("batch", None)),
                "labels": self._spec((b, half), ("batch", None))}
        elif cfg.family == "vlm":
            txt = sl - cfg.num_image_tokens
            structs = {
                "image_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.num_image_tokens, cfg.d_model),
                    cfg.compute_dtype),
                "tokens": jax.ShapeDtypeStruct((b, txt), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, txt), jnp.int32)}
            shards = {
                "image_embeds": self._spec(
                    (b, cfg.num_image_tokens, cfg.d_model),
                    ("batch", None, None)),
                "tokens": self._spec((b, txt), ("batch", None)),
                "labels": self._spec((b, txt), ("batch", None))}
        return structs, shards

    # -- step functions ------------------------------------------------------------
    def make_train_step(self):
        model, opt = self.model, self.optimizer

        def train_step(params, opt_state, step, batch):
            def loss_of(p):
                return model.loss_fn(p, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params)
            new_p, new_s = opt.apply_with_count(params, grads, opt_state,
                                                3e-4, step)
            metrics = dict(metrics, loss=loss)
            return new_p, new_s, metrics

        return train_step

    def train_args(self):
        """(structs, in_shardings, out_shardings, donate) for train_step."""
        p_structs = self.param_structs()
        p_shard = self.param_shardings()
        o_structs = self.opt_structs(p_structs)
        o_shard = self.opt_shardings(p_shard)
        b_structs, b_shard = self.train_batch()
        step = jax.ShapeDtypeStruct((), jnp.int32)
        rep = self._replicated()
        metrics_shard = None  # inferred (scalars)
        in_sh = (p_shard, o_shard, rep, b_shard)
        out_sh = (p_shard, o_shard, metrics_shard)
        return ((p_structs, o_structs, step, b_structs), in_sh, out_sh)

    # -- serving ----------------------------------------------------------------------
    def _cache_metas(self):
        cfg, s = self.cfg, self.shape
        b = s.global_batch
        if cfg.family == "encdec":
            return self.model.cache_spec(b, s.seq_len // 2,
                                         enc_len=s.seq_len // 2)
        return self.model.cache_spec(b, s.seq_len)

    def _decode_attend_fn(self):
        if self.variant.decode != "flash":
            return None
        b = self.shape.global_batch
        batch_axes = []
        rem = b
        for ax in ("pod", "data"):
            if ax in self.mesh.axis_names and rem % self.mesh.shape[ax] == 0:
                batch_axes.append(ax)
                rem //= self.mesh.shape[ax]
        seq_axes = [a for a in self.rules.mesh_axes_for("seq_shard")
                    if a in self.mesh.axis_names
                    and self.shape.seq_len % self.mesh.shape[a] == 0]
        if not seq_axes:
            return None
        return make_flash_decode_attend(self.mesh, seq_axes=seq_axes,
                                        batch_axes=batch_axes)

    def make_serve_step(self):
        model = self.model
        attend_fn = self._decode_attend_fn()

        def serve_step(params, cache, token, pos):
            logits, new_cache = model.decode_step(params, cache, token, pos,
                                                  attend_fn=attend_fn)
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_token[:, None], new_cache

        return serve_step

    def serve_args(self):
        cfg, s = self.cfg, self.shape
        b = s.global_batch
        p_structs = self.param_structs()
        p_shard = self.param_shardings()
        cache_metas = self._cache_metas()
        c_structs = tree_structs(cache_metas)
        c_shard = self.rules.tree_shardings(cache_metas, self.mesh)
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tok_shard = self._spec((b, 1), ("batch", None))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        rep = self._replicated()
        in_sh = (p_shard, c_shard, tok_shard, rep)
        out_sh = (tok_shard, c_shard)
        return ((p_structs, c_structs, token, pos), in_sh, out_sh)

    def make_prefill_step(self):
        model = self.model
        cfg, s = self.cfg, self.shape

        if cfg.family == "encdec":
            def prefill_step(params, frames, tokens):
                return model.prefill(params, frames, tokens,
                                     max_seq=s.seq_len // 2)
        else:
            def prefill_step(params, tokens):
                return model.prefill(params, tokens, max_seq=s.seq_len)

        return prefill_step

    def prefill_args(self):
        cfg, s = self.cfg, self.shape
        b = s.global_batch
        p_structs = self.param_structs()
        p_shard = self.param_shardings()
        cache_metas = self._cache_metas()
        c_shard = self.rules.tree_shardings(cache_metas, self.mesh)
        logits_shard = None
        if cfg.family == "encdec":
            half = s.seq_len // 2
            frames = jax.ShapeDtypeStruct((b, half, cfg.d_model),
                                          cfg.compute_dtype)
            tokens = jax.ShapeDtypeStruct((b, half), jnp.int32)
            in_sh = (p_shard,
                     self._spec((b, half, cfg.d_model), ("batch", None, None)),
                     self._spec((b, half), ("batch", None)))
            return ((p_structs, frames, tokens), in_sh,
                    (logits_shard, c_shard))
        tokens = jax.ShapeDtypeStruct((b, s.seq_len), jnp.int32)
        in_sh = (p_shard, self._spec((b, s.seq_len), ("batch", None)))
        return ((p_structs, tokens), in_sh, (logits_shard, c_shard))

    # -- unified entry --------------------------------------------------------------
    def lowerable(self):
        """Returns (fn, args_structs, in_shardings, out_shardings, donate)."""
        kind = self.shape.kind
        if kind == "train":
            args, in_sh, out_sh = self.train_args()
            return self.make_train_step(), args, in_sh, out_sh, (0, 1)
        if kind == "decode":
            args, in_sh, out_sh = self.serve_args()
            return self.make_serve_step(), args, in_sh, out_sh, (1,)
        args, in_sh, out_sh = self.prefill_args()
        return self.make_prefill_step(), args, in_sh, out_sh, ()
