"""Loop-aware analysis of post-SPMD HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``lax.scan`` body
*once* — for a 61-layer scanned model it under-reports FLOPs and
collective traffic by ~the layer count.  The partitioned HLO, however,
records ``backend_config={"known_trip_count":{"n":...}}`` on every while
op, so exact totals are recoverable:

  1. split the module into computations,
  2. per computation: matmul FLOPs from every ``dot`` (2·|out|·|contract|)
     and wire bytes from every collective,
  3. propagate call-graph multipliers (while bodies × trip count,
     fusions/calls × 1) from the entry computation,
  4. totals = Σ per-computation value × multiplier.

Everything here is per-device (post-SPMD shapes are local shards).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_SINGLE_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=%([\w\.\-]+)")
_CALLEE_LIST_RE = re.compile(
    r"(?:calls|branch_computations)=\{([^}]*)\}")


def _callees(rhs: str) -> list[str]:
    out = [m.group(1) for m in _CALLEE_SINGLE_RE.finditer(rhs)]
    for m in _CALLEE_LIST_RE.finditer(rhs):
        for item in m.group(1).split(","):
            item = item.strip().lstrip("%")
            if item:
                out.append(item)
    return out
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_of(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Comp:
    name: str
    dot_flops: int = 0
    coll_bytes: dict = field(default_factory=lambda: defaultdict(int))
    coll_counts: dict = field(default_factory=lambda: defaultdict(int))
    calls: list = field(default_factory=list)   # (callee, multiplier)


def analyze_hlo(hlo_text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    defs: dict[str, int] = {}          # instruction -> result bytes
    shapes: dict[str, list] = {}       # instruction -> first result shape
    entry: str | None = None

    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            defs, shapes = {}, {}
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        first_paren = rhs.find("(")
        type_str = rhs[:first_paren] if first_paren > 0 else rhs
        defs[name] = _bytes_of(type_str)
        sh = _shapes_of(type_str)
        shapes[name] = sh[0][1] if sh else []

        opcode_m = re.search(r"\}?\s*([\w\-]+)\(", rhs)
        opcode = opcode_m.group(1) if opcode_m else ""

        if opcode == "dot":
            cm = _CONTRACT_RE.search(rhs)
            contract = 1
            if cm:
                args = rhs[rhs.find("(") + 1: rhs.find(")")]
                first_op = args.split(",")[0].strip().split(" ")[-1] \
                    .lstrip("%")
                lhs_shape = shapes.get(first_op, [])
                for idx in (int(i) for i in cm.group(1).split(",") if i):
                    if idx < len(lhs_shape):
                        contract *= lhs_shape[idx]
            out_elems = 1
            for d in shapes[name]:
                out_elems *= d
            cur.dot_flops += 2 * out_elems * contract
        elif opcode in _COLLECTIVES:
            payload = defs[name]
            if opcode == "all-reduce":
                payload *= 2          # ring: reduce-scatter + all-gather
            elif opcode == "reduce-scatter":
                args = rhs[rhs.find("(") + 1: rhs.find(")")]
                ob = 0
                for a in args.split(","):
                    ob += defs.get(a.strip().split(" ")[-1].lstrip("%"), 0)
                payload = ob or payload
            cur.coll_bytes[opcode] += payload
            cur.coll_counts[opcode] += 1

        # call-graph edges
        if opcode == "while":
            tm = _TRIP_RE.search(rhs)
            trip = int(tm.group(1)) if tm else 1
            for callee in _callees(rhs):
                cur.calls.append((callee, trip))
        elif ("calls=" in rhs or "to_apply=" in rhs
              or "branch_computations=" in rhs):
            for callee in _callees(rhs):
                cur.calls.append((callee, 1))

    # propagate multipliers from the entry (fixpoint over the call DAG)
    mult: dict[str, float] = {c: 0.0 for c in comps}
    if entry is None and comps:
        referenced = {callee for c in comps.values() for callee, _ in c.calls}
        roots = [c for c in comps if c not in referenced] or list(comps)
        for r in roots:
            mult[r] = 1.0
    else:
        mult[entry] = 1.0
    for _ in range(len(comps) + 2):
        changed = False
        new = {c: (1.0 if c == entry else 0.0) for c in comps}
        if entry is None:
            for c in comps:
                if mult[c] and not any(
                        c == callee for cc in comps.values()
                        for callee, _ in cc.calls):
                    new[c] = 1.0
        for cname, comp in comps.items():
            for callee, k in comp.calls:
                if callee in new:
                    new[callee] += mult[cname] * k
        if new != mult:
            mult = new
            changed = True
        if not changed:
            break

    flops = 0
    coll = defaultdict(int)
    counts = defaultdict(int)
    n_while = 0
    for cname, comp in comps.items():
        m = max(mult.get(cname, 0.0), 0.0)
        flops += comp.dot_flops * m
        for k, v in comp.coll_bytes.items():
            coll[k] += v * m
        for k, v in comp.coll_counts.items():
            counts[k] += v * m
        n_while += sum(1 for _, t in comp.calls if t > 1)

    return {
        "dot_flops": int(flops),
        "collective_bytes": {k: int(coll.get(k, 0)) for k in _COLLECTIVES},
        "collective_total_bytes": int(sum(coll.values())),
        "collective_counts": {k: int(counts.get(k, 0))
                              for k in _COLLECTIVES},
        "n_computations": len(comps),
        "n_loops": n_while,
    }
