"""Production mesh construction (TPU v5e pods; 256 chips/pod).

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before first jax init while tests see the real 1-CPU world.
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType (explicit-sharding era)
    when available, plain Auto meshes on older releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CI / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
