"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-27b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

Full configs target the production mesh (real TPU pods); ``--reduced``
runs the same code path end-to-end on the host mesh.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs.base import get_config
from repro.core.data import (BatchDataset, PackedLMDataset, PrefetchDataset,
                             ShuffleDataset, synthetic_corpus)
from repro.core.optim import AdamW
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.sharding.rules import make_rules
from repro.training.train_loop import TrainConfig, train


def make_batches(cfg, batch_size: int, seq: int, steps: int, seed: int = 0):
    """Data pipeline: synthetic corpus -> packed tokens -> shuffled batches
    -> background prefetch; token ids are folded into the model vocab."""
    docs = synthetic_corpus(n_docs=512, seed=seed)
    ds = PackedLMDataset(docs, seq_len=seq)
    ds = ShuffleDataset(ds, seed=seed)
    batched = PrefetchDataset(BatchDataset(ds, batch_size), buffer_size=4)
    epoch = 0
    produced = 0
    while produced < steps:
        for tokens, labels in batched:
            tokens = np.asarray(tokens) % cfg.vocab_size
            labels = np.asarray(labels) % cfg.vocab_size
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            if cfg.family == "encdec":
                rng = np.random.default_rng(produced)
                batch["frames"] = jnp.asarray(
                    rng.standard_normal(
                        (tokens.shape[0], seq // 2, cfg.d_model)),
                    dtype=cfg.compute_dtype)
                batch["tokens"] = batch["tokens"][:, : seq // 2]
                batch["labels"] = batch["labels"][:, : seq // 2]
            elif cfg.family == "vlm":
                rng = np.random.default_rng(produced)
                batch["image_embeds"] = jnp.asarray(
                    rng.standard_normal(
                        (tokens.shape[0], cfg.num_image_tokens,
                         cfg.d_model)),
                    dtype=cfg.compute_dtype)
            yield batch
            produced += 1
            if produced >= steps:
                return
        epoch += 1
        ds.reshuffle(epoch)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    print(f"[train] arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(mesh.shape)}")

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] params: {n_params/1e6:.2f}M")

    rules = make_rules("baseline")
    tcfg = TrainConfig(steps=args.steps, base_lr=args.lr,
                       checkpoint_dir=args.ckpt,
                       warmup=max(2, args.steps // 20))
    batches = make_batches(cfg, args.batch, args.seq, args.steps)
    with repro.session(mesh=mesh, sharding_rules=rules,
                       tag=f"train:{cfg.name}"):
        params, history = train(model, params, batches, tcfg,
                                optimizer=AdamW(lr=args.lr))
    first = np.mean([h["loss"] for h in history[:5]])
    last = np.mean([h["loss"] for h in history[-5:]])
    print(f"[train] loss {first:.4f} -> {last:.4f} over {len(history)} steps")
    return history


if __name__ == "__main__":
    main()
