"""The mutation corpus: deliberately seeded defects, one per rule.

Static analyses rot silently — a refactor loosens a rule and nothing
notices until a real miscompile slips through.  This module pins every
defect *class* the suite claims to catch to the rule that must catch it:
each :class:`Mutation` starts from a clean program (usually a selfcheck
corpus graph), seeds exactly one defect, runs the relevant checker, and
asserts the finding set is **exactly** ``{expected_rule}`` at WARNING
severity and above.  Run via ``python -m repro.analysis`` (CI) or
:func:`run_mutations`.

A mutation that stops firing means the rule regressed; a mutation that
fires *extra* rules means a checker lost precision (false positives on
defects are how false positives on clean code start).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:
    from repro.compiler.graph import Graph, Node
    from repro.compiler.lowering import Executable
    from repro.runtime.policies import AnalysisPolicy

    from .serving import CacheSnapshot


@dataclass(frozen=True)
class Mutation:
    """One seeded defect: ``build()`` seeds it and runs the checker."""

    name: str
    rule: str                 # the rule that must (exclusively) fire
    defect: str               # human description of the seeded bug
    build: Callable[[], DiagnosticReport]


def _graph(name: str, pipeline: tuple[str, ...] = ()) -> "Graph":
    """A fresh selfcheck-corpus graph, optionally optimized."""
    from repro.compiler.passes import PassManager
    from repro.compiler.selfcheck import _build
    from repro.runtime.policies import CompilerPolicy

    g, _sources = _build(name)
    if pipeline:
        PassManager.from_policy(CompilerPolicy(pipeline=pipeline)).run(g)
    return g


def _policy(level: str = "default", **kw: Any) -> "AnalysisPolicy":
    from repro.runtime.policies import AnalysisPolicy

    return AnalysisPolicy(level=level, **kw)


def _check(g: "Graph", **kw: Any) -> DiagnosticReport:
    from .shapes import check_graph

    return check_graph(g, _policy(), **kw)


def _last_compute(g: "Graph") -> "Node":
    """The final compute node — no consumers, so corrupting its metadata
    trips exactly its own derived check and nothing downstream."""
    for uid in reversed(g.order):
        if g.nodes[uid].op not in ("input", "const"):
            return g.nodes[uid]
    raise AssertionError("corpus graph has no compute node")


# -- graph / shape / dtype / alias -------------------------------------------


def _shape_corrupted() -> DiagnosticReport:
    g = _graph("chain")
    node = _last_compute(g)
    node.shape = tuple(s + 1 for s in node.shape) or (7,)
    return _check(g)


def _dtype_corrupted() -> DiagnosticReport:
    g = _graph("chain")
    _last_compute(g).dtype = np.dtype(np.int32)
    return _check(g)


def _broadcast_violated() -> DiagnosticReport:
    # diamond ends in mul(left, broadcast_to(right, left.shape)); retarget
    # the broadcast to a shape its input cannot expand to
    g = _graph("diamond")
    for uid in g.order:
        n = g.nodes[uid]
        if n.op == "broadcast_to":
            src = g.nodes[n.inputs[0]].shape
            bad = tuple(s + 1 for s in src) + (3,)
            n.attrs = (bad,)
            n.shape = bad
            # keep the consumer consistent so only the broadcast trips
            for c in g.order:
                if uid in g.nodes[c].inputs:
                    g.nodes[c].shape = bad
            break
    else:
        raise AssertionError("diamond has no broadcast_to")
    return _check(g)


def _alias_double_write() -> DiagnosticReport:
    # CSE merges the duplicate subexpression (alias src -> rep, src node
    # removed); resurrect the merged node — now two writers exist
    g = _graph("shared_subexpr", pipeline=("cse",))
    assert g.alias, "cse produced no alias on shared_subexpr"
    src, dst = next(iter(g.alias.items()))
    rep = g.nodes[g.resolve(dst)]
    g.add(dataclasses.replace(rep, uid=src))
    return _check(g)


def _alias_dangling() -> DiagnosticReport:
    g = _graph("shared_subexpr", pipeline=("cse",))
    assert g.alias
    src = next(iter(g.alias))
    g.alias[src] = 10 ** 9          # chain now ends at a nonexistent node
    return _check(g)


def _use_before_def() -> DiagnosticReport:
    # schedule a node before its producer (a broken pass reordering)
    g = _graph("chain")
    last = g.order[-1]
    g.order.remove(last)
    g.order.insert(0, last)
    return _check(g)


def _orphan_output() -> DiagnosticReport:
    g = _graph("chain")
    g.outputs = g.outputs + (10 ** 9,)
    return _check(g)


# -- clusters / liveness / lowered schedule ----------------------------------


def _cluster_output_dropped() -> DiagnosticReport:
    from .liveness import check_clusters

    g = _graph("chain", pipeline=("fuse",))
    assert g.clusters, "fuse produced no cluster on chain"
    cl = g.clusters[0]
    assert cl.outputs, "cluster has no outputs to drop"
    cl.outputs = cl.outputs[:-1]
    return check_clusters(g, _policy())


def _vmem_over_budget() -> DiagnosticReport:
    from .liveness import check_clusters

    g = _graph("chain", pipeline=("fuse",))
    assert g.clusters
    return check_clusters(g, _policy(vmem_limit_bytes=1))


def _exec_double_write() -> DiagnosticReport:
    from .liveness import check_executable

    exe = _lowered("chain", lowering="eager")
    op_steps = [s for s in exe.steps if hasattr(s, "uid")]
    assert op_steps, "eager lowering produced no op steps"
    exe.steps.append(op_steps[-1])            # same value written twice
    return check_executable(exe)


def _exec_war() -> DiagnosticReport:
    from .liveness import check_executable

    exe = _lowered("chain", lowering="jit", pipeline=("fuse",))
    for s in exe.steps:
        if hasattr(s, "outputs"):             # a ClusterStep
            s.inputs = tuple(s.inputs) + (s.outputs[0],)
            break
    else:
        raise AssertionError("no cluster step to corrupt")
    return check_executable(exe)


def _plan_double_free() -> DiagnosticReport:
    from .liveness import check_memory_plan

    exe = _lowered("chain", lowering="eager")
    assert exe.frees, "chain frees nothing?"
    return check_memory_plan(exe.allocs, exe.frees + (exe.frees[0],))


def _lowered(name: str, lowering: str = "eager",
             pipeline: tuple[str, ...] = ()) -> "Executable":
    from repro.compiler.lowering import lower, memory_plan, snapshot_logical
    from repro.compiler.passes import PassManager
    from repro.compiler.selfcheck import _build
    from repro.runtime.policies import CompilerPolicy

    g, _sources = _build(name)
    cpol = CompilerPolicy(pipeline=pipeline, lowering=lowering)
    snap = snapshot_logical(g)
    report = PassManager.from_policy(cpol).run(g)
    return lower(g, cpol, report, interpret=True,
                 plan=memory_plan(snap, g))


# -- matcher clusters / fused-kernel contracts --------------------------------


def _attention_kind_mismatch() -> DiagnosticReport:
    from .liveness import check_clusters

    # the attention matcher claimed softmax(QK^T)V; a buggy rewrite
    # relabels the cluster elementwise — lowering would replay both
    # matmuls through the whole-array body
    g = _graph("softmax_attention", pipeline=("attention", "fuse"))
    attn = [cl for cl in g.clusters if cl.kind == "attention"]
    assert attn, "attention matcher claimed nothing on softmax_attention"
    attn[0].kind = "elementwise"
    return check_clusters(g, _policy())


def _epilogue_partial_row() -> DiagnosticReport:
    from .tiles import check_kernel_call

    # a reducing epilogue (softmax/rmsnorm denominator) launched with a
    # partial-row n tile: each program reduces over bn=128 of n=256
    return check_kernel_call("matmul_epilogue", m=256, k=256, n=256,
                             bm=128, bn=128, bk=128, reduce=True)


def _attention_template_oob() -> DiagnosticReport:
    from .tiles import check_kernel_call

    # the template never masks: sq=192 with bq=128 leaves a 64-row
    # overhang the final program reads out of bounds
    return check_kernel_call("attention_template", sq=192, sk=256, d=64,
                             bq=128, bk=128)


# -- kernel tile contracts ----------------------------------------------------


def _tile_oob() -> DiagnosticReport:
    from .tiles import check_kernel_call

    # k = 384 is lane-aligned (no alignment note) but 384 % bk=256 != 0
    # and matmul's k loop does not mask — the last program reads OOB
    return check_kernel_call("matmul", m=256, k=384, n=256,
                             bm=128, bn=128, bk=256)


def _tile_oversize() -> DiagnosticReport:
    from .tiles import check_kernel_call

    # flash_attention clamps bq/bk to s, so oversize must be seeded
    # through the raw tiling checker (a contract bypass / new kernel)
    from .tiles import TileDim, check_tiling

    return check_tiling("custom", [TileDim("rows", 64, 128)])


# -- paged KV cache -----------------------------------------------------------


def _snap(table: Any, held: dict[int, list[int]], live: set[int],
          num_blocks: int = 8,
          refcounts: dict[int, int] | None = None,
          shared_len: dict[int, int] | None = None,
          prepared: dict[int, tuple[int, int]] | None = None,
          prefix_blocks: set[int] | None = None,
          committed: dict[int, int] | None = None,
          forks: dict[int, int] | None = None) -> "CacheSnapshot":
    from .serving import CacheSnapshot

    return CacheSnapshot(num_blocks=num_blocks, block_size=4,
                         block_bytes=1024, table=np.asarray(table, np.int32),
                         held={s: tuple(b) for s, b in held.items()},
                         live_blocks=frozenset(live), manager="seeded",
                         refcounts=refcounts,
                         shared_len=shared_len or {},
                         prepared=prepared or {},
                         prefix_blocks=frozenset(prefix_blocks or ()),
                         committed=committed or {},
                         forks=forks or {})


def _kv_check(snap: "CacheSnapshot") -> DiagnosticReport:
    from .serving import check_paged_cache

    return check_paged_cache(snap)


def _kv_leak() -> DiagnosticReport:
    # block 3 live in the allocator, mapped by no slot
    return _kv_check(_snap([[1, 2, 0], [0, 0, 0]],
                           {0: [1, 2]}, live={0, 1, 2, 3}))


def _kv_double_free() -> DiagnosticReport:
    # slot 0 still maps block 2 but the allocator already freed it
    return _kv_check(_snap([[1, 2, 0], [0, 0, 0]],
                           {0: [1, 2]}, live={0, 1}))


def _kv_trash_block() -> DiagnosticReport:
    # slot 1 was handed physical block 0 — the reserved trash block
    return _kv_check(_snap([[1, 0, 0], [0, 3, 0]],
                           {0: [1], 1: [0, 3]}, live={0, 1, 3}))


def _kv_double_map() -> DiagnosticReport:
    # both slots map block 2: decode writes corrupt each other
    return _kv_check(_snap([[1, 2, 0], [2, 0, 0]],
                           {0: [1, 2], 1: [2]}, live={0, 1, 2}))


def _kv_table_stale() -> DiagnosticReport:
    # release() forgot to zero the table row past the held prefix
    return _kv_check(_snap([[1, 5, 0], [0, 0, 0]],
                           {0: [1]}, live={0, 1}))


def _kv_refcount_underflow() -> DiagnosticReport:
    # two slots share block 1 through the prefix index, but a buggy
    # release already decremented it to 1 — the next release frees it
    # while slot 1 still reads through it
    return _kv_check(_snap([[1, 2, 0], [1, 0, 0]],
                           {0: [1, 2], 1: [1]}, live={0, 1, 2},
                           refcounts={1: 1, 2: 1}))


def _kv_shared_write() -> DiagnosticReport:
    # slot 1 shares block 1 (refcount 2) up to position 2 but prepared a
    # divergent write at position 3 without copy-on-write
    return _kv_check(_snap([[1, 2, 0], [1, 0, 0]],
                           {0: [1, 2], 1: [1]}, live={0, 1, 2},
                           refcounts={1: 2, 2: 1},
                           shared_len={0: 8, 1: 2}, prepared={1: (3, 3)}))


def _kv_rollback_dangling() -> DiagnosticReport:
    # speculative verify grew slot 0 to 3 blocks for a wide write, the
    # round rejected the suffix (committed length 5, write intent
    # through position 4 = 2 blocks of 4) — but rollback never
    # truncated the block table, leaving block 3 dangling
    return _kv_check(_snap([[1, 2, 3], [0, 0, 0]],
                           {0: [1, 2, 3]}, live={0, 1, 2, 3},
                           refcounts={1: 1, 2: 1, 3: 1},
                           prepared={0: (0, 4)}, committed={0: 5}))


def _kv_fork_refcount() -> DiagnosticReport:
    # slot 1 forked from slot 0 (copy-on-write beam): both map block 1,
    # but the fork forgot its refcount++ — the first release frees
    # memory the sibling beam still reads
    return _kv_check(_snap([[1, 2, 0], [1, 3, 0]],
                           {0: [1, 2], 1: [1, 3]}, live={0, 1, 2, 3},
                           refcounts={1: 1, 2: 1, 3: 1},
                           forks={1: 0}))


def _kv_prefix_stale() -> DiagnosticReport:
    # the radix tree still advertises block 3 after the allocator freed
    # it — the next match maps recycled memory into a fresh request
    return _kv_check(_snap([[1, 0, 0]],
                           {0: [1]}, live={0, 1},
                           refcounts={1: 1}, prefix_blocks={3}))


# -- numerics -----------------------------------------------------------------


def _bf16_accum() -> DiagnosticReport:
    from .numerics import check_numerics
    from repro.compiler import graph as graph_mod
    from repro.core.tensor import ops
    from repro.core.tensor.lazy_backend import LazyBackend
    from repro.runtime import session

    import jax.numpy as jnp

    lb = LazyBackend()
    with session(backend=lb):
        x = lb._lift(jnp.ones((64, 64), jnp.bfloat16))
        y = ops.sum(ops.mul(x, x), axis=None, keepdims=False)
    g, _sources = graph_mod.trace([y])
    return check_numerics(g)


MUTATIONS: tuple[Mutation, ...] = (
    Mutation("shape_corrupted", "shape.mismatch",
             "a pass rewrote a node but recorded the wrong shape",
             _shape_corrupted),
    Mutation("dtype_corrupted", "dtype.mismatch",
             "a pass recorded the wrong dtype on a rewritten node",
             _dtype_corrupted),
    Mutation("broadcast_violated", "shape.broadcast",
             "broadcast_to retargeted to a shape its input cannot reach",
             _broadcast_violated),
    Mutation("alias_double_write", "alias.double-write",
             "CSE wrote the alias but left the merged node in the graph",
             _alias_double_write),
    Mutation("alias_dangling", "alias.dangling",
             "an alias chain ends at a node no pass kept alive",
             _alias_dangling),
    Mutation("use_before_def", "graph.use-before-def",
             "a pass reordered the schedule ahead of a producer",
             _use_before_def),
    Mutation("orphan_output", "graph.orphan-output",
             "a program output resolves to no live node",
             _orphan_output),
    Mutation("cluster_output_dropped", "cluster.output-missing",
             "fusion forgot a member that is consumed outside the cluster",
             _cluster_output_dropped),
    Mutation("vmem_over_budget", "vmem.over-budget",
             "a fused cluster's peak residency exceeds the VMEM budget",
             _vmem_over_budget),
    Mutation("exec_double_write", "exec.double-write",
             "the lowered schedule writes one logical value twice",
             _exec_double_write),
    Mutation("exec_war", "exec.war",
             "a cluster kernel reads a value it also writes",
             _exec_war),
    Mutation("plan_double_free", "plan.double-free",
             "the memory plan frees the same allocation twice",
             _plan_double_free),
    Mutation("attention_kind_mismatch", "cluster.kind-mismatch",
             "a matched attention cluster relabeled elementwise",
             _attention_kind_mismatch),
    Mutation("epilogue_partial_row", "tile.epilogue-row",
             "a reducing matmul epilogue tiled with partial rows",
             _epilogue_partial_row),
    Mutation("attention_template_oob", "tile.oob",
             "attention template launched with sq not divisible by bq",
             _attention_template_oob),
    Mutation("tile_oob", "tile.oob",
             "matmul launched with k not divisible by bk (unmasked)",
             _tile_oob),
    Mutation("tile_oversize", "tile.oversize",
             "a block larger than the array extent it tiles",
             _tile_oversize),
    Mutation("kv_leak", "kv.leak",
             "a live allocator block mapped by no slot",
             _kv_leak),
    Mutation("kv_double_free", "kv.double-free",
             "a mapped block already freed in the allocator",
             _kv_double_free),
    Mutation("kv_trash_block", "kv.trash-block",
             "a slot holds reserved physical block 0",
             _kv_trash_block),
    Mutation("kv_double_map", "kv.double-map",
             "one physical block mapped by two slots",
             _kv_double_map),
    Mutation("kv_table_stale", "kv.table-stale",
             "release() left a nonzero table entry past the held prefix",
             _kv_table_stale),
    Mutation("kv_refcount_underflow", "kv.refcount-underflow",
             "a shared block's refcount fell below its reference count",
             _kv_refcount_underflow),
    Mutation("kv_shared_write", "kv.shared-write",
             "a divergent write prepared into a shared block without COW",
             _kv_shared_write),
    Mutation("kv_prefix_stale", "kv.prefix-stale",
             "the radix tree advertises a block the allocator freed",
             _kv_prefix_stale),
    Mutation("kv_rollback_dangling", "kv.rollback-dangling",
             "speculative rollback left rejected-suffix blocks mapped",
             _kv_rollback_dangling),
    Mutation("kv_fork_refcount", "kv.fork-refcount",
             "a beam fork mapped parent blocks without refcount++",
             _kv_fork_refcount),
    Mutation("bf16_accum", "numerics.bf16-accum",
             "a long reduction accumulating in bfloat16",
             _bf16_accum),
)


def run_mutations() -> list[dict]:
    """Run every mutation; each must be flagged by exactly its rule.

    Returns one result row per mutation:
    ``{"name", "rule", "caught", "exact", "found": [...]}`` where
    ``caught`` means the intended rule fired and ``exact`` means no
    *other* rule fired at WARNING severity or above.
    """
    results = []
    for m in MUTATIONS:
        report = m.build()
        found = sorted({d.rule for d in report.at_least(Severity.WARNING)})
        results.append({
            "name": m.name,
            "rule": m.rule,
            "defect": m.defect,
            "caught": m.rule in found,
            "exact": found == [m.rule],
            "found": found,
        })
    return results
