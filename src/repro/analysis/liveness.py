"""Liveness / alias analysis over fusion clusters and lowered programs.

Three checkers:

* :func:`check_clusters` — audits the fusion partition *before* lowering:
  membership integrity, external-input/output edge sets recomputed from
  scratch (a member consumed outside the cluster but missing from
  ``Cluster.outputs`` would be silently dropped by lowering), **kind
  consistency** (``cluster.kind-mismatch`` — the declared ``Cluster.kind``
  must agree with the member ops, since lowering dispatches the kernel
  strategy on it: an attention cluster mislabeled elementwise would replay
  two matmuls through the whole-array body), atomicity
  (the condensed graph must be acyclic — Kahn's algorithm is re-run here,
  so an illegal partition is a diagnostic instead of a lowering crash),
  and a per-cluster **peak-live-bytes estimate against the VMEM budget**:
  the generated kernel holds every external input, every external output,
  and the live span of each intermediate simultaneously resident.
* :func:`check_executable` — audits a lowered step schedule: every read
  is preceded by its write (``exec.use-before-def``), no value is written
  twice (``exec.double-write`` — the defect a buggy CSE alias write-back
  introduces), and no cluster kernel writes a value it also reads
  (``exec.war`` — an in-kernel write-after-read hazard, since generated
  bodies read all inputs up front only by convention).
* :func:`check_memory_plan` — the alloc/free schedule invariants the
  selfcheck used to test by hand, as rules: unique allocs, unique frees,
  every free paired with an alloc.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:
    from repro.compiler.graph import Graph
    from repro.compiler.lowering import Executable
    from repro.runtime.policies import AnalysisPolicy


def _cluster_peak_bytes(graph: "Graph", node_ids: tuple[int, ...],
                        inputs: tuple[int, ...],
                        outputs: tuple[int, ...]) -> int:
    """Estimated peak VMEM residency of the generated cluster kernel.

    External inputs and outputs are resident for the whole kernel (read
    once up front / written once at the end); each intermediate is live
    from its defining member to its last in-cluster use.
    """
    members = set(node_ids)
    out_set = set(outputs)
    base = sum(graph.nodes[u].nbytes() for u in inputs)
    base += sum(graph.nodes[u].nbytes() for u in outputs)
    last_use: dict[int, int] = {}
    for i, uid in enumerate(node_ids):
        for d in graph.nodes[uid].inputs:
            if d in members:
                last_use[d] = i
    live = 0
    peak = 0
    dead_at: dict[int, list[int]] = {}
    for d, i in last_use.items():
        dead_at.setdefault(i, []).append(d)
    for i, uid in enumerate(node_ids):
        if uid not in out_set:                   # outputs already counted
            live += graph.nodes[uid].nbytes()
        peak = max(peak, live)
        for d in dead_at.get(i, ()):
            if d not in out_set and d in members:
                live -= graph.nodes[d].nbytes()
    return base + peak


def _kind_violation(kind: str, n_matmul: int, n_reduce: int,
                    meta: dict) -> str | None:
    """Why ``kind`` disagrees with the member ops; None when consistent."""
    if kind == "elementwise":
        if n_matmul or n_reduce:
            return (f"contains {n_matmul} matmul / {n_reduce} reduction "
                    "member(s) — the whole-array elementwise body would "
                    "replay them per-element")
    elif kind == "reduction":
        if n_matmul:
            return f"contains {n_matmul} matmul member(s)"
        if not n_reduce:
            return "contains no reduction member"
    elif kind == "epilogue":
        if n_matmul != 1:
            return (f"epilogue lowering fuses exactly one matmul, cluster "
                    f"has {n_matmul}")
    elif kind == "attention":
        if n_matmul != 2:
            return (f"attention template needs the QK^T and PV matmuls, "
                    f"cluster has {n_matmul}")
        if meta.get("mode") not in ("softmax", "sigmoid"):
            return f"meta mode {meta.get('mode')!r} is not a template mode"
    else:
        return f"unknown cluster kind {kind!r}"
    return None


def check_clusters(graph: "Graph", policy: "AnalysisPolicy | None" = None,
                   where: str | None = None) -> DiagnosticReport:
    """Verify the fusion partition, cluster-kind consistency, and
    per-cluster VMEM budgets."""
    from repro.compiler.graph import REDUCTION_OPS
    from repro.runtime.policies import AnalysisPolicy

    policy = policy or AnalysisPolicy()
    report = DiagnosticReport()
    if not policy.enabled or not graph.clusters:
        return report
    consumers = graph.consumers()
    out_set = {graph.resolve(o) for o in graph.outputs}
    for cl in graph.clusters:
        members = set(cl.node_ids)
        prov = dict(cluster=cl.cid, where=where)
        for uid in cl.node_ids:
            node = graph.nodes.get(uid)
            if node is None:
                report.add("cluster.member-missing", Severity.ERROR,
                           f"member %{uid} is not in the graph", node=uid,
                           **prov)
                continue
            if node.cluster != cl.cid:
                report.add("cluster.member-mismatch", Severity.ERROR,
                           f"member %{uid} tagged cluster {node.cluster}",
                           node=uid, op=node.op, **prov)
        for uid in cl.inputs:
            if uid in members:
                report.add("cluster.input-internal", Severity.ERROR,
                           f"external input %{uid} is a cluster member",
                           node=uid, **prov)
            elif uid not in graph.nodes:
                report.add("cluster.input-missing", Severity.ERROR,
                           f"external input %{uid} is not in the graph",
                           node=uid, **prov)
        for uid in cl.outputs:
            if uid not in members:
                report.add("cluster.output-foreign", Severity.ERROR,
                           f"output %{uid} is not a cluster member",
                           node=uid, **prov)
        # kind consistency: lowering dispatches the kernel strategy on
        # Cluster.kind, so a mislabel silently picks the wrong lowering
        member_ops = [graph.nodes[u].op for u in cl.node_ids
                      if u in graph.nodes]
        why = _kind_violation(
            cl.kind, sum(op == "matmul" for op in member_ops),
            sum(op in REDUCTION_OPS for op in member_ops), cl.meta)
        if why is not None:
            report.add("cluster.kind-mismatch", Severity.ERROR,
                       f"cluster declared kind={cl.kind!r} but {why}",
                       **prov)
        # recompute the escape set: members consumed outside, or program
        # outputs, must be materialized by the kernel
        for uid in cl.node_ids:
            if uid not in graph.nodes:
                continue
            escapes = (uid in out_set
                       or any(c not in members for c in consumers.get(uid, ())))
            if escapes and uid not in cl.outputs:
                report.add("cluster.output-missing", Severity.ERROR,
                           f"member %{uid} is consumed outside the cluster "
                           "but is not a cluster output — lowering would "
                           "drop it", node=uid,
                           op=graph.nodes[uid].op, **prov)
        if all(u in graph.nodes for u in cl.node_ids + cl.inputs + cl.outputs):
            peak = _cluster_peak_bytes(graph, cl.node_ids, cl.inputs,
                                       cl.outputs)
            if peak > policy.vmem_limit_bytes:
                report.add("vmem.over-budget", Severity.WARNING,
                           f"estimated peak residency {peak} B exceeds the "
                           f"per-cluster VMEM budget "
                           f"{policy.vmem_limit_bytes} B", **prov)
    # atomicity: the condensed graph (clusters contracted) must be acyclic
    unit_of: dict[int, tuple[str, int]] = {}
    for uid in graph.order:
        node = graph.nodes[uid]
        if node.op in ("input", "const"):
            continue
        unit_of[uid] = (("c", node.cluster) if node.cluster is not None
                        else ("n", uid))
    units = list(dict.fromkeys(unit_of.values()))
    deps: dict[tuple[str, int], set[tuple[str, int]]] = {u: set()
                                                         for u in units}
    for uid, unit in unit_of.items():
        for d in graph.nodes[uid].inputs:
            du = unit_of.get(d)
            if du is not None and du != unit:
                deps[unit].add(du)
    done: set[tuple[str, int]] = set()
    pending = list(units)
    while pending:
        ready = [u for u in pending if deps[u] <= done]
        if not ready:
            stuck = sorted(c for k, c in pending if k == "c")
            report.add("cluster.cycle", Severity.ERROR,
                       "condensed graph has a cycle — the fusion partition "
                       f"is not atomic (clusters involved: {stuck})",
                       where=where)
            break
        done.update(ready)
        pending = [u for u in pending if u not in done]
    return report


def check_executable(exe: "Executable",
                     where: str | None = None) -> DiagnosticReport:
    """Schedule verification of a lowered program (write-once, defs
    precede uses, no in-kernel write-after-read)."""
    from repro.compiler.lowering import ClusterStep, OpStep

    report = DiagnosticReport()
    defined: set[int] = set(exe.consts) | set(exe.inputs)
    for i, step in enumerate(exe.steps):
        war: set[int] = set()
        if isinstance(step, OpStep):
            reads, writes = step.inputs, (step.uid,)
            tag: dict[str, Any] = {"op": step.op}
        elif isinstance(step, ClusterStep):
            reads, writes = step.inputs, tuple(step.outputs)
            tag = {"op": f"cluster[{step.kind}]"}
            war = set(step.outputs) & set(step.inputs)
            for uid in sorted(war):
                report.add("exec.war", Severity.ERROR,
                           f"step {i} writes %{uid} which it also reads — "
                           "in-kernel write-after-read hazard", node=uid,
                           where=where, **tag)
        else:  # pragma: no cover - future step kinds
            continue
        # a WAR uid is by construction also use-before-def (not yet
        # written) or double-write (already written); report only the
        # root cause, not its cascade
        for d in reads:
            if d not in defined and d not in war:
                report.add("exec.use-before-def", Severity.ERROR,
                           f"step {i} reads %{d} before any step defines it",
                           node=d, where=where, **tag)
        for w in writes:
            if w in defined and w not in war:
                report.add("exec.double-write", Severity.ERROR,
                           f"step {i} writes %{w} which is already defined "
                           "— two writers for one logical value", node=w,
                           where=where, **tag)
            defined.add(w)
    for o in exe.outputs:
        if exe.resolve(o) not in defined:
            report.add("exec.undefined-output", Severity.ERROR,
                       f"program output %{o} is never defined", node=o,
                       where=where)
    return report


def check_memory_plan(allocs: tuple[tuple[int, int, str], ...],
                      frees: tuple[int, ...],
                      where: str | None = None) -> DiagnosticReport:
    """Alloc/free schedule invariants (exactly-once telemetry events)."""
    report = DiagnosticReport()
    alloc_uids = [a[0] for a in allocs]
    seen: set[int] = set()
    for uid in alloc_uids:
        if uid in seen:
            report.add("plan.double-alloc", Severity.ERROR,
                       f"%{uid} allocated twice", node=uid, where=where)
        seen.add(uid)
    fseen: set[int] = set()
    for uid in frees:
        if uid in fseen:
            report.add("plan.double-free", Severity.ERROR,
                       f"%{uid} freed twice", node=uid, where=where)
        fseen.add(uid)
        if uid not in seen:
            report.add("plan.free-unalloced", Severity.ERROR,
                       f"%{uid} freed but never allocated", node=uid,
                       where=where)
    return report
