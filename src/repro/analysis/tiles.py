"""Tile-divisibility and masked-OOB checks for Pallas kernel launches.

A ``pallas_call`` whose grid × block extent disagrees with the array
extent reads or writes out of bounds unless the kernel body masks the
overhang.  The hand-written kernels in ``repro/kernels/`` each declare a
**contract** here — the same clamping arithmetic their launch wrappers
perform, plus which dimensions are masked in-kernel — so a bad launch
shape is a structured diagnostic *before* the kernel traps (or worse,
silently wraps under ``interpret=True``).

:func:`check_kernel_call` evaluates a named contract; generated cluster
kernels are covered separately (:func:`check_cluster_specs`) because
their specs are synthesized: one whole-array block per operand, which is
trivially divisible but must agree across every member of the cluster.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable

import jax.numpy as jnp

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:
    from repro.compiler.graph import Graph
    from repro.runtime.policies import AnalysisPolicy

#: second-minor / minor tiling the TPU VPU/MXU layouts want (fp32).
_SUBLANE, _LANE = 8, 128


@dataclass(frozen=True)
class TileDim:
    """One tiled dimension of a kernel launch: ``size`` split into
    ``block``-sized programs; ``masked`` means the kernel guards the
    overhang in-kernel, so non-divisible sizes are legal."""

    name: str
    size: int
    block: int
    masked: bool = False


def check_tiling(kernel: str, dims: list[TileDim],
                 vmem_bytes: int | None = None,
                 vmem_limit: int | None = None) -> DiagnosticReport:
    """Divisibility/overhang rules shared by every contract."""
    report = DiagnosticReport()
    for d in dims:
        prov = dict(where=f"{kernel}({d.name}={d.size}, block={d.block})")
        if d.block < 1:
            report.add("tile.empty", Severity.ERROR,
                       f"{d.name}: block size {d.block} < 1", **prov)
            continue
        if d.block > d.size:
            report.add("tile.oversize", Severity.ERROR,
                       f"{d.name}: block {d.block} exceeds extent {d.size}",
                       **prov)
            continue
        if d.size % d.block != 0 and not d.masked:
            last = (d.size // d.block) * d.block
            report.add(
                "tile.oob", Severity.ERROR,
                f"{d.name}: extent {d.size} is not a multiple of block "
                f"{d.block} and the kernel does not mask the overhang — "
                f"the final program reads [{last}:{last + d.block}), "
                f"{last + d.block - d.size} elements out of bounds", **prov)
    if vmem_bytes is not None and vmem_limit is not None \
            and vmem_bytes > vmem_limit:
        report.add("vmem.over-budget", Severity.WARNING,
                   f"per-program VMEM estimate {vmem_bytes} B exceeds the "
                   f"budget {vmem_limit} B", where=kernel)
    return report


# -- declared contracts for the hand-written kernels -------------------------


def _flash_attention(*, b: int, h: int, s: int, d: int, bq: int = 128,
                     bk: int = 128, dtype: Any = jnp.float32,
                     vmem_limit: int | None = None) -> DiagnosticReport:
    bq, bk = min(bq, s), min(bk, s)
    itemsize = jnp.dtype(dtype).itemsize
    # q tile + k tile + v tile + scores + fp32 (m, l, acc) scratch
    vmem = (bq * d + 2 * bk * d) * itemsize \
        + (bq * bk + bq * (d + 2)) * 4
    return check_tiling(
        "flash_attention",
        [TileDim("seq/bq", s, bq), TileDim("seq/bk", s, bk)],
        vmem_bytes=vmem, vmem_limit=vmem_limit)


def _flash_decode(*, n: int, s: int, d: int, bk: int = 512,
                  dtype: Any = jnp.float32,
                  vmem_limit: int | None = None) -> DiagnosticReport:
    bk = min(bk, s)
    itemsize = jnp.dtype(dtype).itemsize
    vmem = (d + 2 * bk * d) * itemsize + (bk + d + 2) * 4
    # the validity mask handles cache-depth raggedness *within* the
    # grid, but the grid itself must cover the cache exactly
    return check_tiling("flash_decode", [TileDim("cache/bk", s, bk)],
                        vmem_bytes=vmem, vmem_limit=vmem_limit)


def _flash_verify(*, n: int, t: int, s: int, d: int, bk: int = 512,
                  dtype: Any = jnp.float32,
                  vmem_limit: int | None = None) -> DiagnosticReport:
    """Wide-verify flash decoding (``kernels/flash_decode.flash_verify``):
    ``flash_decode`` with ``t`` query tokens per row sharing each
    streamed KV tile.  The [t, bk] validity mask handles causal/ragged
    structure within the grid; the grid must cover the cache exactly,
    and the whole t-span (queries + fp32 statistics) is VMEM-resident
    per program."""
    bk = min(bk, s)
    itemsize = jnp.dtype(dtype).itemsize
    # q span + k tile + v tile + [t, bk] mask/scores + fp32 (m, l, acc)
    vmem = (t * d + 2 * bk * d) * itemsize + (t * bk + t * (d + 2)) * 4
    return check_tiling("flash_verify", [TileDim("cache/bk", s, bk)],
                        vmem_bytes=vmem, vmem_limit=vmem_limit)


def _matmul(*, m: int, k: int, n: int, bm: int = 128, bn: int = 128,
            bk: int = 128, dtype: Any = jnp.float32,
            vmem_limit: int | None = None) -> DiagnosticReport:
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    itemsize = jnp.dtype(dtype).itemsize
    vmem = (bm * bk + bk * bn + bm * bn) * itemsize + bm * bn * 4
    report = check_tiling(
        "matmul",
        [TileDim("m", m, bm), TileDim("n", n, bn), TileDim("k", k, bk)],
        vmem_bytes=vmem, vmem_limit=vmem_limit)
    if n % _LANE != 0 or k % _LANE != 0:
        report.add("tile.lane-misaligned", Severity.INFO,
                   f"contraction/minor dims ({k}, {n}) are not multiples "
                   f"of {_LANE}; the MXU pads to full lanes",
                   where="matmul")
    return report


def _rms_norm(*, n: int, d: int, bn: int = 256,
              dtype: Any = jnp.float32,
              vmem_limit: int | None = None) -> DiagnosticReport:
    bn = min(bn, n)
    while n % bn != 0:        # the launch wrapper shrinks bn to divide n
        bn -= 1
    itemsize = jnp.dtype(dtype).itemsize
    vmem = (2 * bn * d + d) * itemsize
    return check_tiling("rms_norm", [TileDim("rows", n, bn)],
                        vmem_bytes=vmem, vmem_limit=vmem_limit)


def _attention_template(*, sq: int, sk: int, d: int, dv: int | None = None,
                        bq: int = 128, bk: int = 128,
                        dtype: Any = jnp.float32,
                        vmem_limit: int | None = None) -> DiagnosticReport:
    """The parameterized attention template the attention matcher lowers
    to (``kernels/flash_attention.attention_template``): like
    ``flash_attention`` but q and kv sequence lengths may differ and the
    template never masks — both grids must divide exactly."""
    dv = d if dv is None else dv
    bq, bk = min(bq, sq), min(bk, sk)
    itemsize = jnp.dtype(dtype).itemsize
    # q tile + k tile + v tile + scores + bias tile + fp32 (m, l, acc)
    vmem = (bq * d + bk * d + bk * dv) * itemsize \
        + (2 * bq * bk + bq * (dv + 2)) * 4
    return check_tiling(
        "attention_template",
        [TileDim("sq/bq", sq, bq), TileDim("sk/bk", sk, bk)],
        vmem_bytes=vmem, vmem_limit=vmem_limit)


def _matmul_epilogue(*, m: int, k: int, n: int, bm: int = 128,
                     bn: int = 128, bk: int = 128, reduce: bool = False,
                     n_extra: int = 0, dtype: Any = jnp.float32,
                     vmem_limit: int | None = None) -> DiagnosticReport:
    """The fused matmul-epilogue kernel (``kernels/matmul.matmul_epilogue``):
    matmul tiling rules, plus — when the epilogue body contains a row
    reduction (``reduce=True``) — the output tile must hold complete rows
    (``bn == n``), or each program reduces over a partial row and the
    softmax/rmsnorm denominator is silently wrong."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    itemsize = jnp.dtype(dtype).itemsize
    vmem = (bm * bk + bk * bn + (1 + n_extra) * bm * bn) * itemsize \
        + bm * bn * 4
    report = check_tiling(
        "matmul_epilogue",
        [TileDim("m", m, bm), TileDim("n", n, bn), TileDim("k", k, bk)],
        vmem_bytes=vmem, vmem_limit=vmem_limit)
    if reduce and bn != n:
        report.add(
            "tile.epilogue-row", Severity.ERROR,
            f"epilogue body reduces over rows but the n tile is {bn} < "
            f"{n} — each program sees a partial row, so the reduction "
            "result is wrong (plan_epilogue must force bn == n)",
            where=f"matmul_epilogue(n={n}, bn={bn})")
    return report


def _reduction_cluster(*, shape: tuple[int, ...], n_operands: int = 2,
                       dtype: Any = jnp.float32,
                       vmem_limit: int | None = None) -> DiagnosticReport:
    """A generated reduction-cluster kernel: whole-array blocks (the body
    replays the subgraph on full operands), so divisibility is trivial —
    the contract is that the whole working set is VMEM-resident."""
    itemsize = jnp.dtype(dtype).itemsize
    size = 1
    for s in shape:
        size *= s
    dims = [TileDim(f"axis{i}", s, s) for i, s in enumerate(shape)]
    return check_tiling("reduction_cluster", dims,
                        vmem_bytes=n_operands * size * itemsize,
                        vmem_limit=vmem_limit)


KERNEL_CONTRACTS: dict[str, Callable[..., DiagnosticReport]] = {
    "flash_attention": _flash_attention,
    "flash_decode": _flash_decode,
    "flash_verify": _flash_verify,
    "matmul": _matmul,
    "rms_norm": _rms_norm,
    "attention_template": _attention_template,
    "matmul_epilogue": _matmul_epilogue,
    "reduction_cluster": _reduction_cluster,
}


def check_kernel_call(kernel: str, **params: Any) -> DiagnosticReport:
    """Evaluate a declared kernel contract against launch parameters.

    ``check_kernel_call("matmul", m=256, k=130, n=256, bk=128)`` →
    ``tile.oob`` (130 % 128 != 0 and nothing masks the overhang).
    """
    try:
        contract = KERNEL_CONTRACTS[kernel]
    except KeyError:
        raise KeyError(f"no declared contract for kernel {kernel!r}; "
                       f"known: {sorted(KERNEL_CONTRACTS)}") from None
    return contract(**params)


# -- generated cluster kernels ----------------------------------------------


def check_cluster_specs(graph: "Graph",
                        policy: "AnalysisPolicy | None" = None,
                        on_tpu: bool = False,
                        where: str | None = None) -> DiagnosticReport:
    """Audit the specs the cluster lowering would generate.

    ``elementwise``/``reduction`` clusters use one whole-array BlockSpec
    per operand, so the only tiling risks are TPU-specific: shape
    disagreement across members and lane/sublane misalignment both force
    the jit fallback there (off-TPU the interpreted whole-array body
    handles any shape mix exactly), so they are INFO provenance notes.
    ``epilogue``/``attention`` clusters carry their own tiled specs whose
    contracts the matcher pre-validated (``plan_epilogue`` /
    ``template_supported``); their launch parameters are covered by the
    named :data:`KERNEL_CONTRACTS` instead.
    """
    from repro.runtime.policies import AnalysisPolicy

    policy = policy or AnalysisPolicy()
    report = DiagnosticReport()
    if not policy.enabled:
        return report
    for cl in graph.clusters:
        if cl.kind in ("epilogue", "attention"):
            continue
        if not on_tpu:
            continue
        nodes = [graph.nodes[u] for u in cl.node_ids if u in graph.nodes]
        ins = [graph.nodes[u] for u in cl.inputs if u in graph.nodes]
        shapes = {tuple(n.shape) for n in nodes} | {tuple(n.shape)
                                                    for n in ins}
        if len(shapes) > 1:
            # TPU lowering falls back to jit for these; only a
            # hand-forced pallas path would be OOB, so INFO provenance
            report.add("tile.shape-divergent", Severity.INFO,
                       f"cluster spans shapes {sorted(shapes)}; pallas "
                       "path unavailable on TPU (jit fallback)",
                       cluster=cl.cid, where=where)
            continue
        if not shapes:
            continue
        (shape,) = shapes
        if len(shape) < 2 or shape[-1] % _LANE or shape[-2] % _SUBLANE:
            report.add("tile.unaligned", Severity.INFO,
                       f"cluster shape {shape} is not ({_SUBLANE}k, "
                       f"{_LANE}k)-tileable on TPU; jit fallback",
                       cluster=cl.cid, where=where)
    return report


def estimate_grid(size: int, block: int) -> int:
    """Programs needed to cover ``size`` with ``block`` (helper for
    contracts and tests)."""
    return math.ceil(size / block)
