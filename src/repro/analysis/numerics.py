"""Numerics lint: precision hazards that type-check but destroy accuracy.

Rules:

``numerics.bf16-accum``
    a reduction (``sum`` / ``prod`` / ``cumsum``) or ``matmul`` whose
    operands *and* result are 16-bit floats — the accumulation happens in
    the storage precision, so long reductions lose low-order bits.  The
    fix is an f32 accumulator (``astype`` before the reduction, or
    ``preferred_element_type`` on the contraction).  WARNING: legitimate
    for short reductions, fatal for long ones — strict mode promotes it.

``numerics.fp8-arith``
    an fp8 value (``float8_e4m3*`` / ``float8_e5m2*``) flowing through
    any compute op other than a cast.  In this codebase fp8 is a
    *storage-only* format (the paged KV cache stores fp8 payload next to
    f32 scales and dequantizes before attention); arithmetic directly on
    fp8 means a missing dequantize/scale step.

``numerics.fp8-no-scale``
    a cast straight from fp8 to a compute dtype whose result feeds
    arithmetic without any multiplicative rescale on the path — the
    scale factor the fp8 KV convention requires was dropped.  Only
    flagged when the cast's consumer is arithmetic (a bare cast feeding
    an output is how a checkpoint dump looks and stays clean).
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import jax.numpy as jnp

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:
    from repro.compiler.graph import Graph

_ACCUM_OPS = frozenset({"sum", "prod", "cumsum", "matmul"})
_RESCALE_OPS = frozenset({"mul", "div"})


def _is_16bit_float(dtype: object) -> bool:
    d = jnp.dtype(dtype)
    return jnp.issubdtype(d, jnp.floating) and d.itemsize == 2


def _is_fp8(dtype: object) -> bool:
    return "float8" in jnp.dtype(dtype).name


def check_numerics(graph: "Graph",
                   where: str | None = None) -> DiagnosticReport:
    """Lint one graph for low-precision accumulation and fp8 misuse."""
    report = DiagnosticReport()
    consumers = graph.consumers()
    for uid in graph.order:
        node = graph.nodes[uid]
        if node.op in ("input", "const"):
            continue
        prov = dict(node=uid, op=node.op, src_op=node.src_op,
                    cluster=node.cluster, where=where)
        in_dtypes = [graph.nodes[d].dtype for d in node.inputs
                     if d in graph.nodes]
        if (node.op in _ACCUM_OPS and _is_16bit_float(node.dtype)
                and in_dtypes and all(map(_is_16bit_float, in_dtypes))):
            report.add(
                "numerics.bf16-accum", Severity.WARNING,
                f"{node.op} accumulates in "
                f"{jnp.dtype(node.dtype).name} — cast the operand to f32 "
                "(or use an f32 accumulator) and round once at the end",
                **prov)
        if node.op != "astype" and (
                _is_fp8(node.dtype) or any(map(_is_fp8, in_dtypes))):
            report.add(
                "numerics.fp8-arith", Severity.WARNING,
                "arithmetic on an fp8 value — fp8 is storage-only here; "
                "dequantize (cast + scale) before computing", **prov)
        if (node.op == "astype" and in_dtypes and _is_fp8(in_dtypes[0])
                and not _is_fp8(node.dtype)):
            users = [graph.nodes[c] for c in consumers.get(uid, ())]
            arith = [u for u in users if u.op not in ("astype",)]
            if arith and not any(u.op in _RESCALE_OPS for u in arith):
                report.add(
                    "numerics.fp8-no-scale", Severity.WARNING,
                    "fp8 payload cast up and consumed without a "
                    "multiplicative rescale — the stored scale factor "
                    "appears to be dropped", **prov)
    return report
