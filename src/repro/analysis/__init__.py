"""repro.analysis — static verification over the Graph IR, Pallas
lowering, and paged serving runtime.

Nothing here executes the program: every checker is a pure function from
IR / launch parameters / cache snapshots to structured
:class:`Diagnostic`s, so it can run between compiler passes, at trace
time, and in CI without numerics in the loop.

    check_graph        structural IR + shape/dtype abstract interpretation
    check_clusters     fusion-partition integrity, liveness, VMEM budgets
    check_executable   lowered schedule: write-once, defs-before-uses
    check_memory_plan  alloc/free exactly-once invariants
    check_numerics     bf16 accumulation / fp8 storage-only lint
    check_kernel_call  declared tile contracts for hand-written kernels
    check_paged_cache  KV block-table leak / double-free / trash audits
    analyze_graph      the whole suite over one compiled program

Selection is session-scoped: ``repro.session(analysis={"level":
"strict"})`` (see :class:`repro.runtime.AnalysisPolicy`), or per-call via
``repro.compile(fn, check="strict")``.  ``python -m repro.analysis``
runs the suite over the compiler selfcheck corpus plus a *mutation
corpus* of deliberately seeded defects that every rule must catch.
"""

from repro.runtime.policies import AnalysisPolicy

from .diagnostics import (AnalysisError, Diagnostic, DiagnosticReport,
                          Severity)
from .liveness import check_clusters, check_executable, check_memory_plan
from .numerics import check_numerics
from .serving import CacheSnapshot, check_paged_cache, snapshot_cache
from .shapes import check_graph, infer_node
from .suite import analyze_and_raise, analyze_graph
from .tiles import (KERNEL_CONTRACTS, TileDim, check_cluster_specs,
                    check_kernel_call, check_tiling)

__all__ = [
    "AnalysisPolicy", "AnalysisError", "Diagnostic", "DiagnosticReport",
    "Severity",
    "check_graph", "infer_node",
    "check_clusters", "check_executable", "check_memory_plan",
    "check_numerics",
    "check_kernel_call", "check_tiling", "check_cluster_specs",
    "KERNEL_CONTRACTS", "TileDim",
    "CacheSnapshot", "snapshot_cache", "check_paged_cache",
    "analyze_graph", "analyze_and_raise",
]
