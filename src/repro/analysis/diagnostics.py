"""Structured diagnostics: the currency every static analysis trades in.

A :class:`Diagnostic` is one finding — a stable rule id (``"shape.mismatch"``,
``"kv.leak"``), a :class:`Severity`, a human-readable message, and source-op
provenance (node uid, op, the pre-folding ``src_op``, cluster id, and a
free-form ``where`` naming the pass / kernel / slot it was found in).  A
:class:`DiagnosticReport` aggregates findings from several analyses and
decides — under an :class:`~repro.runtime.AnalysisPolicy` — whether they are
fatal (:meth:`DiagnosticReport.raise_if_errors` → :class:`AnalysisError`).

Rule-id convention: ``<area>.<defect>``, where the area names the analysis
family (``graph`` / ``shape`` / ``dtype`` / ``alias`` / ``cluster`` /
``vmem`` / ``exec`` / ``plan`` / ``tile`` / ``numerics`` / ``kv``).  Rule
ids are API: the mutation corpus (``repro.analysis.mutations``) pins each
seeded defect class to the rule that must catch it.
"""

from __future__ import annotations

import enum
import json
from dataclasses import asdict, dataclass, field
from typing import Any, Iterable, Iterator


class Severity(enum.IntEnum):
    """Ordered so policies can threshold (``>= ERROR`` is fatal)."""

    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:
        return self.name


@dataclass(frozen=True)
class Diagnostic:
    """One finding from one rule, with source-op provenance."""

    rule: str
    severity: Severity
    message: str
    node: int | None = None        # graph node uid (``%uid`` in dumps)
    op: str | None = None          # node op at analysis time
    src_op: str | None = None      # original op (survives constant folding)
    cluster: int | None = None     # fusion-cluster id, if relevant
    where: str | None = None       # pass / kernel / slot / corpus location

    def format(self) -> str:
        loc = ""
        if self.node is not None:
            op = self.op or "?"
            if self.src_op and self.src_op != self.op:
                op = f"{op}<-{self.src_op}"
            loc = f" %{self.node} ({op})"
        if self.cluster is not None:
            loc += f" [cluster {self.cluster}]"
        tail = f"  ({self.where})" if self.where else ""
        return f"{self.severity.name:<7} {self.rule}:{loc} {self.message}{tail}"

    def to_json(self) -> dict[str, Any]:
        d = asdict(self)
        d["severity"] = self.severity.name
        return d


class AnalysisError(RuntimeError):
    """Raised when a report holds fatal diagnostics; carries the report."""

    def __init__(self, report: "DiagnosticReport", context: str = "") -> None:
        self.report = report
        head = f"static analysis failed{f' ({context})' if context else ''}"
        lines = [head] + ["  " + d.format() for d in report]
        super().__init__("\n".join(lines))


@dataclass
class DiagnosticReport:
    """An ordered collection of diagnostics with severity accounting."""

    diagnostics: list[Diagnostic] = field(default_factory=list)

    # -- building -----------------------------------------------------------
    def add(self, rule: str, severity: Severity, message: str,
            **provenance: Any) -> Diagnostic:
        d = Diagnostic(rule, severity, message, **provenance)
        self.diagnostics.append(d)
        return d

    def extend(self, other: "DiagnosticReport | Iterable[Diagnostic]"
               ) -> "DiagnosticReport":
        items = other.diagnostics if isinstance(other, DiagnosticReport) \
            else list(other)
        self.diagnostics.extend(items)
        return self

    # -- querying -----------------------------------------------------------
    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __bool__(self) -> bool:
        return bool(self.diagnostics)

    @property
    def rules(self) -> set[str]:
        return {d.rule for d in self.diagnostics}

    def by_rule(self, rule: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.rule == rule]

    def at_least(self, severity: Severity) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity >= severity]

    @property
    def errors(self) -> list[Diagnostic]:
        return self.at_least(Severity.ERROR)

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == Severity.WARNING]

    def max_severity(self) -> Severity | None:
        if not self.diagnostics:
            return None
        return max(d.severity for d in self.diagnostics)

    # -- enforcement --------------------------------------------------------
    def raise_if_errors(self, threshold: Severity = Severity.ERROR,
                        context: str = "") -> None:
        """Raise :class:`AnalysisError` if any finding reaches
        ``threshold`` (strict mode thresholds at WARNING)."""
        fatal = self.at_least(threshold)
        if fatal:
            raise AnalysisError(DiagnosticReport(fatal), context)

    # -- presentation -------------------------------------------------------
    def dump(self) -> str:
        if not self.diagnostics:
            return "no diagnostics"
        return "\n".join(d.format() for d in self.diagnostics)

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {s.name: 0 for s in Severity}
        for d in self.diagnostics:
            out[d.severity.name] += 1
        return out

    def to_json(self) -> dict[str, Any]:
        return {"counts": self.counts(),
                "diagnostics": [d.to_json() for d in self.diagnostics]}

    def dumps(self, indent: int = 2) -> str:
        return json.dumps(self.to_json(), indent=indent)
