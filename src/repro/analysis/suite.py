"""Orchestration: run the full analysis suite over one compiled program.

:func:`analyze_graph` is the hook ``compile_graph`` / the lazy backend's
materialize call under a Session's :class:`~repro.runtime.AnalysisPolicy`:
structural+shape verification, cluster/liveness/VMEM checks, the numerics
lint, and — in strict mode — the lowered-schedule and memory-plan checks.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from .diagnostics import DiagnosticReport
from .liveness import check_clusters, check_executable, check_memory_plan
from .numerics import check_numerics
from .shapes import check_graph
from .tiles import check_cluster_specs

if TYPE_CHECKING:
    from repro.compiler.graph import Graph
    from repro.compiler.lowering import Executable
    from repro.runtime.policies import AnalysisPolicy

__all__ = ["analyze_graph", "analyze_and_raise"]


def analyze_graph(graph: "Graph", policy: "AnalysisPolicy | None" = None,
                  exe: "Executable | None" = None,
                  where: str | None = None,
                  on_tpu: bool = False) -> DiagnosticReport:
    """Run every applicable analysis; returns the merged report.

    Enforcement (raising on fatal findings) is the caller's decision via
    ``report.raise_if_errors(policy.error_threshold)`` — so callers that
    only want the report (benchmarks, the CLI) never catch exceptions.
    """
    from repro.runtime.policies import AnalysisPolicy

    policy = policy or AnalysisPolicy()
    report = DiagnosticReport()
    if not policy.enabled:
        return report
    report.extend(check_graph(graph, policy, where=where))
    report.extend(check_clusters(graph, policy, where=where))
    report.extend(check_cluster_specs(graph, policy, on_tpu=on_tpu,
                                      where=where))
    report.extend(check_numerics(graph, where=where))
    if exe is not None and policy.strict:
        report.extend(check_executable(exe, where=where))
        report.extend(check_memory_plan(exe.allocs, exe.frees, where=where))
    return report


def analyze_and_raise(graph: "Graph", policy: "AnalysisPolicy",
                      exe: "Executable | None" = None,
                      where: str | None = None) -> DiagnosticReport:
    """:func:`analyze_graph` + enforcement at the policy's threshold."""
    report = analyze_graph(graph, policy, exe=exe, where=where)
    report.raise_if_errors(policy.error_threshold, context=where or "")
    return report
