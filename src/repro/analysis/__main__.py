"""``python -m repro.analysis`` — the static-analysis gauntlet.

Two halves, both must pass:

* **clean corpus** — every selfcheck graph through every pass-pipeline
  permutation with the structured verifier running *between passes*
  (``PassManager(verify=...)``), then the full suite over the optimized
  graph and its lowered executable.  Zero findings at WARNING severity
  or above, at both ``default`` and ``strict`` levels: the analyses
  must not cry wolf on correct programs.
* **mutation corpus** — every deliberately seeded defect in
  ``repro.analysis.mutations`` must be flagged by exactly its intended
  rule: the analyses must not go blind, and must not cascade.

``--json PATH`` writes the full machine-readable result (per-case
diagnostics + mutation table) for the CI artifact.
"""

from __future__ import annotations

import argparse
import json
import sys

from .diagnostics import Severity
from .mutations import MUTATIONS, run_mutations
from .suite import analyze_graph


def run_clean_corpus(level: str) -> tuple[list[dict], list[str]]:
    """All (graph, pipeline) cells with between-pass verification; returns
    (per-cell results, failure strings)."""
    from repro.compiler.lowering import lower, memory_plan, snapshot_logical
    from repro.compiler.passes import PassManager
    from repro.compiler.selfcheck import CORPUS, PIPELINES, _build
    from repro.runtime.policies import AnalysisPolicy, CompilerPolicy

    apol = AnalysisPolicy(level=level)
    cells: list[dict] = []
    failures: list[str] = []
    for gname in CORPUS:
        for pipeline in PIPELINES:
            where = f"{gname} / {'+'.join(pipeline) or 'identity'}"
            graph, _ = _build(gname)
            cpol = CompilerPolicy(pipeline=pipeline)
            snap = snapshot_logical(graph)
            cell = {"graph": gname, "pipeline": list(pipeline),
                    "level": level, "diagnostics": []}
            try:
                report = PassManager.from_policy(cpol).run(graph,
                                                           verify=apol)
                plan = memory_plan(snap, graph)
                exe = lower(graph, cpol, report, interpret=True, plan=plan)
                diags = analyze_graph(graph, apol, exe=exe, where=where)
            except Exception as e:  # noqa: BLE001 - a failure IS the result
                failures.append(f"{where}: {type(e).__name__}: {e}")
                cell["error"] = str(e)
                cells.append(cell)
                continue
            cell["diagnostics"] = [d.to_json() for d in diags]
            cells.append(cell)
            loud = diags.at_least(Severity.WARNING)
            for d in loud:
                failures.append(f"{where}: false positive: {d.format()}")
    return cells, failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis selfcheck: clean corpus (zero false "
                    "positives) + mutation corpus (every seeded defect "
                    "caught by exactly its rule)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results to PATH")
    args = ap.parse_args(argv)

    from repro.compiler.selfcheck import CORPUS, PIPELINES

    print(f"repro.analysis: {len(CORPUS)} graphs x {len(PIPELINES)} "
          f"pipelines x 2 levels (clean) + {len(MUTATIONS)} mutations")

    all_cells: list[dict] = []
    failures: list[str] = []
    for level in ("default", "strict"):
        cells, fails = run_clean_corpus(level)
        all_cells += cells
        failures += fails
        n_diags = sum(len(c["diagnostics"]) for c in cells)
        print(f"  clean corpus [{level:<7}]: {len(cells)} cells, "
              f"{n_diags} non-silent finding(s), "
              f"{len(fails)} failure(s)")

    mut = run_mutations()
    for r in mut:
        if not r["caught"]:
            failures.append(f"mutation {r['name']}: rule {r['rule']} did "
                            f"not fire (found: {r['found']})")
        elif not r["exact"]:
            failures.append(f"mutation {r['name']}: expected exactly "
                            f"{r['rule']}, found {r['found']}")
    n_ok = sum(1 for r in mut if r["caught"] and r["exact"])
    print(f"  mutation corpus: {n_ok}/{len(mut)} defects pinned to their "
          "rule")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"clean": all_cells, "mutations": mut,
                       "failures": failures, "ok": not failures}, f,
                      indent=2)
        print(f"  wrote {args.json}")

    if failures:
        print(f"\n{len(failures)} failure(s):")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    print("all analyses hold: no false positives, no escaped mutants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
