"""Structural IR verification + shape/dtype abstract interpretation.

:func:`check_graph` promotes the compiler's stringly ``Graph.validate()``
into structured :class:`~repro.analysis.diagnostics.Diagnostic`s — and
``Graph.validate()`` now delegates here, so there is one verifier.

Two layers:

* **structural rules** — topo order, dangling deps, orphan outputs, alias
  integrity (including the *double-write* case: a CSE-merged node left in
  the graph next to its surviving representative, so both would compute
  and write back the same logical value);
* **abstract interpretation** — shapes and dtypes are re-derived from the
  node's inputs without executing anything.  Ops with closed-form rules
  (the elementwise/broadcast set, reductions, shape ops) are re-derived at
  every level from pure-Python broadcast arithmetic; at ``strict`` level
  the remaining non-opaque ops are re-derived through ``jax.eval_shape``.
  A recorded shape/dtype a rewrite silently corrupted surfaces as
  ``shape.mismatch`` / ``dtype.mismatch`` instead of wrong numerics.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import jax
import jax.numpy as jnp
import numpy as np

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:  # import only for annotations: keeps import-time acyclic
    from repro.compiler.graph import Graph, Node
    from repro.runtime.policies import AnalysisPolicy

_COMPARISONS = frozenset({"eq", "ne", "lt", "le", "gt", "ge"})
_LOGICAL = frozenset({"logical_and", "logical_or", "logical_not", "isnan"})
_REDUCTIONS = frozenset({"sum", "max", "min", "prod"})
#: ops whose dtype rule is "same as (equal-dtyped) inputs" is unsafe
_DTYPE_OPAQUE = frozenset({"div", "argmax"})


def _elementwise_ops() -> frozenset[str]:
    from repro.compiler.graph import ELEMENTWISE_OPS

    return ELEMENTWISE_OPS


def _reduce_shape(shape: tuple[int, ...], axis: Any,
                  keepdims: bool) -> tuple[int, ...]:
    if axis is None:
        axes = tuple(range(len(shape)))
    else:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        axes = tuple(a % len(shape) for a in axes)
    if keepdims:
        return tuple(1 if i in axes else s for i, s in enumerate(shape))
    return tuple(s for i, s in enumerate(shape) if i not in axes)


def infer_node(node: "Node", in_shapes: list[tuple[int, ...]],
               in_dtypes: list[Any]
               ) -> tuple[tuple[int, ...] | None, Any | None]:
    """Closed-form shape/dtype re-derivation for ops we have rules for.

    Returns ``(shape, dtype)`` with ``None`` meaning "no rule — do not
    check" (soundness over coverage: a rule must never disagree with what
    the op actually produces).  Raises ``ValueError`` on broadcast
    violations — the caller reports those as ``shape.broadcast``.
    """
    op, attrs = node.op, node.attrs
    shape: tuple[int, ...] | None = None
    dtype: Any | None = None
    if op in _elementwise_ops():
        shape = tuple(np.broadcast_shapes(*in_shapes)) if in_shapes else None
        if op in _COMPARISONS or op in _LOGICAL:
            dtype = jnp.dtype(bool)
        elif op == "astype" and attrs:
            dtype = jnp.dtype(attrs[0])
        elif op == "where":
            if (len(in_dtypes) == 3
                    and jnp.dtype(in_dtypes[1]) == jnp.dtype(in_dtypes[2])):
                dtype = jnp.dtype(in_dtypes[1])
        elif op not in _DTYPE_OPAQUE and in_dtypes:
            uniq = {jnp.dtype(d) for d in in_dtypes}
            if len(uniq) == 1 and next(iter(uniq)) != jnp.dtype(bool):
                dtype = next(iter(uniq))
    elif op in _REDUCTIONS and attrs is not None and len(attrs) == 2:
        axis, keepdims = attrs
        shape = _reduce_shape(in_shapes[0], axis, bool(keepdims))
        if jnp.issubdtype(in_dtypes[0], jnp.floating):
            dtype = jnp.dtype(in_dtypes[0])
    elif op == "cumsum":
        shape = in_shapes[0]
        if jnp.issubdtype(in_dtypes[0], jnp.floating):
            dtype = jnp.dtype(in_dtypes[0])
    elif op == "argmax" and attrs is not None and len(attrs) == 1:
        shape = _reduce_shape(in_shapes[0], attrs[0], False)
    elif op == "reshape" and attrs is not None and len(attrs) == 1:
        new = tuple(attrs[0])
        if -1 not in new:
            if int(np.prod(new or (1,))) != int(np.prod(in_shapes[0] or (1,))):
                raise ValueError(
                    f"reshape {in_shapes[0]} -> {new} changes element count")
            shape, dtype = new, jnp.dtype(in_dtypes[0])
    elif op == "transpose" and attrs is not None and len(attrs) == 1:
        axes = attrs[0]
        src = in_shapes[0]
        if axes is None:
            axes = tuple(reversed(range(len(src))))
        shape = tuple(src[a] for a in axes)
        dtype = jnp.dtype(in_dtypes[0])
    elif op == "broadcast_to" and attrs is not None and len(attrs) == 1:
        target = tuple(attrs[0])
        np.broadcast_shapes(in_shapes[0], target)   # raises if illegal
        shape, dtype = target, jnp.dtype(in_dtypes[0])
    elif op == "full" and attrs is not None and len(attrs) == 3:
        shape, dtype = tuple(attrs[0]), jnp.dtype(attrs[2])
    elif op == "iota" and attrs is not None and len(attrs) == 3:
        shape, dtype = tuple(attrs[1]), jnp.dtype(attrs[0])
    return shape, dtype


def _check_derived(report: DiagnosticReport, graph: "Graph", node: "Node",
                  strict: bool, where: str | None) -> None:
    """Compare the node's recorded shape/dtype against a re-derivation."""
    in_shapes = [graph.nodes[d].shape for d in node.inputs]
    in_dtypes = [graph.nodes[d].dtype for d in node.inputs]
    prov = dict(node=node.uid, op=node.op, src_op=node.src_op,
                cluster=node.cluster, where=where)
    try:
        shape, dtype = infer_node(node, in_shapes, in_dtypes)
    except ValueError as e:
        report.add("shape.broadcast", Severity.ERROR,
                   f"operands do not broadcast: {e}", **prov)
        return
    if shape is None and dtype is None and strict and node.fn is not None:
        # no closed-form rule: re-derive through the op itself
        try:
            structs = [jax.ShapeDtypeStruct(s, d)
                       for s, d in zip(in_shapes, in_dtypes)]
            out = jax.eval_shape(node.fn, *structs)
            shape, dtype = tuple(out.shape), jnp.dtype(out.dtype)
        except Exception as e:  # noqa: BLE001 - report, don't crash
            report.add("shape.infer-failed", Severity.ERROR,
                       f"shape inference failed: {e}", **prov)
            return
    if shape is not None and tuple(shape) != tuple(node.shape):
        report.add("shape.mismatch", Severity.ERROR,
                   f"recorded shape {tuple(node.shape)} but op derives "
                   f"{tuple(shape)}", **prov)
    if dtype is not None and jnp.dtype(dtype) != jnp.dtype(node.dtype):
        report.add("dtype.mismatch", Severity.ERROR,
                   f"recorded dtype {jnp.dtype(node.dtype).name} but op "
                   f"derives {jnp.dtype(dtype).name}", **prov)


def check_graph(graph: "Graph", policy: "AnalysisPolicy | None" = None,
                where: str | None = None) -> DiagnosticReport:
    """Structural + shape/dtype verification of one :class:`Graph`."""
    from repro.runtime.policies import AnalysisPolicy

    policy = policy or AnalysisPolicy()
    report = DiagnosticReport()
    if not policy.enabled:
        return report
    strict = policy.strict
    seen: set[int] = set()
    if set(graph.order) != set(graph.nodes):
        report.add("graph.order", Severity.ERROR,
                   "order and nodes disagree on membership", where=where)
    for uid in graph.order:
        node = graph.nodes.get(uid)
        if node is None:
            continue
        prov = dict(node=uid, op=node.op, src_op=node.src_op,
                    cluster=node.cluster, where=where)
        dangling = False
        for d in node.inputs:
            if d not in graph.nodes:
                report.add("graph.dangling-dep", Severity.ERROR,
                           f"dangling dep %{d}", **prov)
                dangling = True
            elif d not in seen:
                report.add("graph.use-before-def", Severity.ERROR,
                           f"dep %{d} not scheduled before use", **prov)
        if node.op in ("input", "const"):
            if node.op == "const" and node.value is None:
                report.add("graph.const-no-value", Severity.ERROR,
                           "const without a value", **prov)
        elif node.fn is None:
            report.add("graph.no-fn", Severity.ERROR,
                       "compute node without fn", **prov)
        elif node.attrs is not None and not dangling:
            _check_derived(report, graph, node, strict, where)
        seen.add(uid)
    for o in graph.outputs:
        if graph.resolve(o) not in graph.nodes:
            report.add("graph.orphan-output", Severity.ERROR,
                       f"output %{o} resolves to no live node",
                       node=o, where=where)
    for src, dst in graph.alias.items():
        if src in graph.nodes:
            report.add("alias.double-write", Severity.ERROR,
                       f"alias source %{src} still present — the merged "
                       f"node and its representative %{dst} would both "
                       "compute and write back", node=src, where=where)
        if graph.resolve(dst) not in graph.nodes:
            report.add("alias.dangling", Severity.ERROR,
                       f"alias target of %{src} dangles (chain ends at a "
                       "removed node)", node=src, where=where)
    return report
