"""Serving-runtime checker: audit a :class:`PagedKVCache` state snapshot.

The paged cache's correctness rests on three views agreeing: the host
block tables (what the jitted steps will read/write through), the
per-slot held-block lists (what the engine thinks each slot owns), and
the memory manager's live-allocation set (what the allocator will hand
out next).  :func:`check_paged_cache` cross-checks a
:class:`CacheSnapshot` of all three:

``kv.trash-block``     physical block 0 is the reserved trash block —
                       idle-slot writes land there; a slot *holding* it
                       (or the allocator freeing it) means real KV data
                       is being written to / read from the dump site.
``kv.double-map``      one physical block mapped by two slots (or twice
                       by one): decode writes from either slot corrupt
                       the other's cache.
``kv.double-free``     a block still mapped in a table but free in the
                       allocator: the next admission can be handed the
                       same block → silent cross-request corruption.
``kv.leak``            a block live in the allocator but unreferenced by
                       any slot: capacity shrinks until spurious
                       preemption / OOM.
``kv.table-stale``     the device table disagrees with the held-block
                       list (wrong id, or a nonzero entry past the held
                       prefix — reads beyond the slot's length would hit
                       a block it no longer owns).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Mapping, Sequence

import numpy as np

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:
    from repro.serving.kv_cache import PagedKVCache


@dataclass(frozen=True)
class CacheSnapshot:
    """A host-side moment-in-time view of a paged KV cache."""

    num_blocks: int
    block_size: int
    block_bytes: int
    table: Any                                   # int array [slots, max_blocks]
    held: Mapping[int, tuple[int, ...]] = field(default_factory=dict)
    live_blocks: frozenset[int] = frozenset()    # allocator's live view
    manager: str = ""

    def to_json(self) -> dict[str, Any]:
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "block_bytes": self.block_bytes,
                "held": {int(s): [int(b) for b in bs]
                         for s, bs in self.held.items()},
                "live_blocks": sorted(int(b) for b in self.live_blocks),
                "manager": self.manager}


def _live_offsets(manager: Any) -> Sequence[int]:
    """Live arena offsets from a memory manager's internal block map.

    Both built-in managers expose one (``_live`` for caching, ``_blocks``
    for bump); a custom manager can provide ``live_offsets()``.
    """
    fn = getattr(manager, "live_offsets", None)
    if callable(fn):
        return tuple(fn())
    for attr in ("_live", "_blocks"):
        blocks = getattr(manager, attr, None)
        if isinstance(blocks, dict):
            return tuple(off for off, b in blocks.items()
                         if not getattr(b, "free", False))
    return ()


def snapshot_cache(cache: "PagedKVCache") -> CacheSnapshot:
    """Capture the three views of a live :class:`PagedKVCache`."""
    live = frozenset(off // cache.block_bytes
                     for off in _live_offsets(cache.manager))
    held = {slot: tuple(bid for bid, _ptr in blocks)
            for slot, blocks in cache._blocks.items()}
    return CacheSnapshot(num_blocks=cache.num_blocks,
                         block_size=cache.block_size,
                         block_bytes=cache.block_bytes,
                         table=np.array(cache.table, copy=True),
                         held=held, live_blocks=live,
                         manager=type(cache.manager).__name__)


def check_paged_cache(snap: CacheSnapshot,
                      where: str | None = None) -> DiagnosticReport:
    """Audit one snapshot; every rule above is a pure function of it."""
    report = DiagnosticReport()
    table = np.asarray(snap.table)
    owner: dict[int, int] = {}
    for slot, blocks in sorted(snap.held.items()):
        n = len(blocks)
        for i, bid in enumerate(blocks):
            if bid == 0:
                report.add("kv.trash-block", Severity.ERROR,
                           f"slot {slot} holds physical block 0 (the "
                           "reserved trash block) at logical index "
                           f"{i} — its KV writes collide with every idle "
                           "slot's dump writes", where=where or f"slot {slot}")
                continue
            if not 0 <= bid < snap.num_blocks:
                report.add("kv.bad-block", Severity.ERROR,
                           f"slot {slot} holds out-of-range block {bid} "
                           f"(pool has {snap.num_blocks})",
                           where=where or f"slot {slot}")
                continue
            if bid in owner:
                report.add("kv.double-map", Severity.ERROR,
                           f"block {bid} mapped by slot {owner[bid]} and "
                           f"slot {slot} — decode writes from one corrupt "
                           "the other's cache", where=where or f"slot {slot}")
            else:
                owner[bid] = slot
            if snap.live_blocks and bid not in snap.live_blocks:
                report.add("kv.double-free", Severity.ERROR,
                           f"block {bid} is mapped by slot {slot} but free "
                           "in the allocator — it can be handed out again "
                           "while still in use", where=where or f"slot {slot}")
        if slot < table.shape[0]:
            row = table[slot]
            for i in range(min(n, table.shape[1])):
                if int(row[i]) != blocks[i]:
                    report.add("kv.table-stale", Severity.ERROR,
                               f"slot {slot} table[{i}]={int(row[i])} but "
                               f"the slot holds block {blocks[i]} there",
                               where=where or f"slot {slot}")
            for i in range(n, table.shape[1]):
                if int(row[i]) != 0:
                    report.add("kv.table-stale", Severity.ERROR,
                               f"slot {slot} table[{i}]={int(row[i])} past "
                               f"the {n} held blocks — reads beyond the "
                               "slot's length hit a block it does not own",
                               where=where or f"slot {slot}")
    # table rows for slots with no held blocks must be all-trash
    held_slots = set(snap.held)
    for slot in range(table.shape[0]):
        if slot in held_slots:
            continue
        nz = np.flatnonzero(table[slot])
        if nz.size:
            report.add("kv.table-stale", Severity.ERROR,
                       f"idle slot {slot} table still maps block "
                       f"{int(table[slot][nz[0]])} at index {int(nz[0])}",
                       where=where or f"slot {slot}")
    if snap.live_blocks:
        if 0 not in snap.live_blocks:
            report.add("kv.trash-block", Severity.ERROR,
                       "the allocator freed physical block 0 — the trash "
                       "block must stay reserved for idle-slot writes",
                       where=where)
        for bid in sorted(snap.live_blocks - {0} - set(owner)):
            report.add("kv.leak", Severity.ERROR,
                       f"block {bid} is live in the allocator but mapped "
                       "by no slot — leaked capacity", where=where)
    return report
