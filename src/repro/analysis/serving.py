"""Serving-runtime checker: audit a :class:`PagedKVCache` state snapshot.

The paged cache's correctness rests on three views agreeing: the host
block tables (what the jitted steps will read/write through), the
per-slot held-block lists (what the engine thinks each slot owns), and
the memory manager's live-allocation set (what the allocator will hand
out next).  :func:`check_paged_cache` cross-checks a
:class:`CacheSnapshot` of all three:

``kv.trash-block``     physical block 0 is the reserved trash block —
                       idle-slot writes land there; a slot *holding* it
                       (or the allocator freeing it) means real KV data
                       is being written to / read from the dump site.
``kv.double-map``      one physical block mapped by two slots (or twice
                       by one): decode writes from either slot corrupt
                       the other's cache.
``kv.double-free``     a block still mapped in a table but free in the
                       allocator: the next admission can be handed the
                       same block → silent cross-request corruption.
``kv.leak``            a block live in the allocator but unreferenced by
                       any slot: capacity shrinks until spurious
                       preemption / OOM.
``kv.table-stale``     the device table disagrees with the held-block
                       list (wrong id, or a nonzero entry past the held
                       prefix — reads beyond the slot's length would hit
                       a block it no longer owns).

Prefix sharing (``serving/prefix.py``) adds a fourth view — per-block
refcounts plus the radix tree's block set — and three rules over it.
They only engage when ``snap.refcounts`` is present; legacy snapshots
keep the exclusive-ownership semantics above.

``kv.refcount-underflow``  a block has fewer recorded references than
                           things referencing it (slot mappings + tree)
                           — one release away from freeing memory that
                           is still read through a live table.
``kv.shared-write``        a slot prepared a write at/past its shared
                           prefix into a block other sharers still
                           reference, without copy-on-write — the write
                           corrupts every sharer's cache.
``kv.prefix-stale``        the radix tree advertises a block the
                           allocator freed — the next match maps
                           recycled memory into a fresh request.

Speculative decoding and beam forking (``serving/speculative.py``,
``serving/beam.py``) add two more views — per-slot committed lengths
maintained by the engine's rollback path, and the child→parent fork
map — and two rules over them:

``kv.rollback-dangling``   a slot holds blocks past its committed
                           length with no declared write intent — the
                           rejected-suffix rollback failed to truncate
                           the block table, so rejected KV garbage
                           stays mapped (and readable) forever.
                           Engages only when committed lengths are
                           recorded (speculative engines).
``kv.fork-refcount``       a block shared between a forked child and
                           its parent has fewer recorded references
                           than mappings — the fork forgot its
                           refcount++, so the first release frees
                           memory the sibling still reads.  Reported
                           instead of ``kv.refcount-underflow`` when
                           the block belongs to a live fork pair.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Mapping, Sequence

import numpy as np

from .diagnostics import DiagnosticReport, Severity

if TYPE_CHECKING:
    from repro.serving.kv_cache import PagedKVCache


@dataclass(frozen=True)
class CacheSnapshot:
    """A host-side moment-in-time view of a paged KV cache."""

    num_blocks: int
    block_size: int
    block_bytes: int
    table: Any                                   # int array [slots, max_blocks]
    held: Mapping[int, tuple[int, ...]] = field(default_factory=dict)
    live_blocks: frozenset[int] = frozenset()    # allocator's live view
    manager: str = ""
    # prefix-sharing views (None/empty = legacy exclusive-ownership cache)
    refcounts: Mapping[int, int] | None = None   # block -> reference count
    shared_len: Mapping[int, int] = field(default_factory=dict)
    prepared: Mapping[int, tuple[int, int]] = field(default_factory=dict)
    prefix_blocks: frozenset[int] = frozenset()  # radix tree's block set
    # speculative / forking views (empty = plain decode)
    committed: Mapping[int, int] = field(default_factory=dict)
    forks: Mapping[int, int] = field(default_factory=dict)  # child -> parent

    def to_json(self) -> dict[str, Any]:
        return {"num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "block_bytes": self.block_bytes,
                "held": {int(s): [int(b) for b in bs]
                         for s, bs in self.held.items()},
                "live_blocks": sorted(int(b) for b in self.live_blocks),
                "manager": self.manager,
                "refcounts": (None if self.refcounts is None else
                              {int(b): int(c)
                               for b, c in self.refcounts.items()}),
                "shared_len": {int(s): int(v)
                               for s, v in self.shared_len.items()},
                "prepared": {int(s): [int(v[0]), int(v[1])]
                             for s, v in self.prepared.items()},
                "prefix_blocks": sorted(int(b)
                                        for b in self.prefix_blocks),
                "committed": {int(s): int(v)
                              for s, v in self.committed.items()},
                "forks": {int(c): int(p) for c, p in self.forks.items()}}


def _live_offsets(manager: Any) -> Sequence[int]:
    """Live arena offsets from a memory manager's internal block map.

    Both built-in managers expose one (``_live`` for caching, ``_blocks``
    for bump); a custom manager can provide ``live_offsets()``.
    """
    fn = getattr(manager, "live_offsets", None)
    if callable(fn):
        return tuple(fn())
    for attr in ("_live", "_blocks"):
        blocks = getattr(manager, attr, None)
        if isinstance(blocks, dict):
            return tuple(off for off, b in blocks.items()
                         if not getattr(b, "free", False))
    return ()


def snapshot_cache(cache: "PagedKVCache") -> CacheSnapshot:
    """Capture the three views of a live :class:`PagedKVCache`."""
    live = frozenset(off // cache.block_bytes
                     for off in _live_offsets(cache.manager))
    held = {slot: tuple(bid for bid, _ptr in blocks)
            for slot, blocks in cache._blocks.items()}
    index = getattr(cache, "prefix_index", None)
    return CacheSnapshot(num_blocks=cache.num_blocks,
                         block_size=cache.block_size,
                         block_bytes=cache.block_bytes,
                         table=np.array(cache.table, copy=True),
                         held=held, live_blocks=live,
                         manager=type(cache.manager).__name__,
                         refcounts=dict(getattr(cache, "refcount", None)
                                        or {}) or None,
                         shared_len=dict(getattr(cache, "_shared_len", {})),
                         prepared=dict(getattr(cache, "_prepared", {})),
                         prefix_blocks=(index.blocks() if index is not None
                                        else frozenset()),
                         committed=dict(getattr(cache, "_committed", {})),
                         forks=dict(getattr(cache, "_forks", {})))


def check_paged_cache(snap: CacheSnapshot,
                      where: str | None = None) -> DiagnosticReport:
    """Audit one snapshot; every rule above is a pure function of it."""
    report = DiagnosticReport()
    table = np.asarray(snap.table)
    rc = snap.refcounts
    owner: dict[int, int] = {}                   # first mapper (legacy rule)
    refs: dict[int, int] = {}                    # block -> slot mappings
    for slot, blocks in sorted(snap.held.items()):
        n = len(blocks)
        seen: set[int] = set()
        for i, bid in enumerate(blocks):
            if bid == 0:
                report.add("kv.trash-block", Severity.ERROR,
                           f"slot {slot} holds physical block 0 (the "
                           "reserved trash block) at logical index "
                           f"{i} — its KV writes collide with every idle "
                           "slot's dump writes", where=where or f"slot {slot}")
                continue
            if not 0 <= bid < snap.num_blocks:
                report.add("kv.bad-block", Severity.ERROR,
                           f"slot {slot} holds out-of-range block {bid} "
                           f"(pool has {snap.num_blocks})",
                           where=where or f"slot {slot}")
                continue
            refs[bid] = refs.get(bid, 0) + 1
            if rc is None:
                # exclusive ownership: any second mapping is corruption
                if bid in owner:
                    report.add("kv.double-map", Severity.ERROR,
                               f"block {bid} mapped by slot {owner[bid]} "
                               f"and slot {slot} — decode writes from one "
                               "corrupt the other's cache",
                               where=where or f"slot {slot}")
                else:
                    owner[bid] = slot
            else:
                # refcounted sharing: cross-slot mappings are legal (the
                # refcount rule below checks they are accounted for),
                # but one slot aliasing a block at two logical indices
                # is still corruption
                owner.setdefault(bid, slot)
                if bid in seen:
                    report.add("kv.double-map", Severity.ERROR,
                               f"slot {slot} maps block {bid} at two "
                               "logical indices — two cache positions "
                               "alias the same physical rows",
                               where=where or f"slot {slot}")
                seen.add(bid)
            if snap.live_blocks and bid not in snap.live_blocks:
                report.add("kv.double-free", Severity.ERROR,
                           f"block {bid} is mapped by slot {slot} but free "
                           "in the allocator — it can be handed out again "
                           "while still in use", where=where or f"slot {slot}")
        if slot < table.shape[0]:
            row = table[slot]
            for i in range(min(n, table.shape[1])):
                if int(row[i]) != blocks[i]:
                    report.add("kv.table-stale", Severity.ERROR,
                               f"slot {slot} table[{i}]={int(row[i])} but "
                               f"the slot holds block {blocks[i]} there",
                               where=where or f"slot {slot}")
            for i in range(n, table.shape[1]):
                if int(row[i]) != 0:
                    report.add("kv.table-stale", Severity.ERROR,
                               f"slot {slot} table[{i}]={int(row[i])} past "
                               f"the {n} held blocks — reads beyond the "
                               "slot's length hit a block it does not own",
                               where=where or f"slot {slot}")
    # table rows for slots with no held blocks must be all-trash
    held_slots = set(snap.held)
    for slot in range(table.shape[0]):
        if slot in held_slots:
            continue
        nz = np.flatnonzero(table[slot])
        if nz.size:
            report.add("kv.table-stale", Severity.ERROR,
                       f"idle slot {slot} table still maps block "
                       f"{int(table[slot][nz[0]])} at index {int(nz[0])}",
                       where=where or f"slot {slot}")
    # -- prefix-sharing rules (refcounted snapshots only) --------------------
    stale: set[int] = set()
    if rc is not None:
        if 0 in snap.prefix_blocks:
            report.add("kv.trash-block", Severity.ERROR,
                       "the radix tree advertises physical block 0 (the "
                       "reserved trash block) as cached prefix content",
                       where=where)
        if snap.live_blocks:
            for bid in sorted(snap.prefix_blocks - {0}):
                if bid not in snap.live_blocks:
                    stale.add(bid)
                    report.add("kv.prefix-stale", Severity.ERROR,
                               f"the radix tree advertises block {bid} but "
                               "the allocator freed it — the next prefix "
                               "match maps recycled memory into a fresh "
                               "request", where=where)
        # blocks shared between a forked child and its parent: an
        # under-count there is a forgotten fork refcount++, reported as
        # kv.fork-refcount instead of the generic underflow
        fork_shared: set[int] = set()
        for child, parent in sorted(snap.forks.items()):
            both = (set(snap.held.get(child, ()))
                    & set(snap.held.get(parent, ())))
            fork_shared |= both - {0}
        for bid in sorted(set(refs) | (snap.prefix_blocks - {0})):
            if bid in stale:
                continue                 # already fatal; don't double-report
            expect = refs.get(bid, 0) + (1 if bid in snap.prefix_blocks
                                         else 0)
            have = int(rc.get(bid, 0))
            if have < expect:
                if bid in fork_shared:
                    report.add("kv.fork-refcount", Severity.ERROR,
                               f"block {bid} is shared by a forked child "
                               f"and its parent but has refcount {have} "
                               f"for {expect} mappings — the fork forgot "
                               "its refcount++, so the first release "
                               "frees memory the sibling beam still "
                               "reads", where=where)
                else:
                    report.add("kv.refcount-underflow", Severity.ERROR,
                               f"block {bid} has refcount {have} but "
                               f"{expect} references (slot mappings"
                               f"{' + radix tree' if bid in snap.prefix_blocks else ''})"
                               " — one release away from freeing memory "
                               "still read through a live table",
                               where=where)
            elif have > expect:
                report.add("kv.leak", Severity.ERROR,
                           f"block {bid} has refcount {have} but only "
                           f"{expect} references — the excess can never "
                           "be released, leaking capacity", where=where)
        bs = snap.block_size
        for slot, (lo, hi) in sorted(snap.prepared.items()):
            sh = int(snap.shared_len.get(slot, 0))
            blocks = snap.held.get(slot, ())
            if hi < sh or not blocks:
                continue                 # idempotent rewrite of the prefix
            for j in range(max(int(lo), sh) // bs,
                           min(int(hi) // bs, len(blocks) - 1) + 1):
                bid = blocks[j]
                if int(rc.get(bid, 0)) > 1:
                    report.add("kv.shared-write", Severity.ERROR,
                               f"slot {slot} prepared a divergent write "
                               f"(range [{lo}, {hi}], shared prefix {sh}) "
                               f"into block {bid} which "
                               f"{int(rc.get(bid, 0)) - 1} other sharer(s) "
                               "still reference — no copy-on-write "
                               "happened", where=where)
    # -- speculative rollback rule (committed lengths recorded only) ---------
    if snap.committed:
        bs = snap.block_size
        for slot, length in sorted(snap.committed.items()):
            blocks = snap.held.get(slot, ())
            if not blocks:
                continue
            # blocks past the committed content are legitimate only
            # while covered by a declared write intent (the engine's
            # begin_write before a verify round grows the mapping)
            hi = int(snap.prepared.get(slot, (0, int(length) - 1))[1])
            limit = max(int(length) - 1, hi) // bs + 1
            if len(blocks) > limit:
                report.add("kv.rollback-dangling", Severity.ERROR,
                           f"slot {slot} holds {len(blocks)} blocks but "
                           f"its committed length {length} (+ write "
                           f"intent through position {hi}) justifies "
                           f"only {limit} — the rejected-suffix rollback "
                           "failed to truncate the block table, leaving "
                           "rejected KV garbage mapped", where=where)
    if snap.live_blocks:
        if 0 not in snap.live_blocks:
            report.add("kv.trash-block", Severity.ERROR,
                       "the allocator freed physical block 0 — the trash "
                       "block must stay reserved for idle-slot writes",
                       where=where)
        keep = set(owner) | (snap.prefix_blocks if rc is not None
                             else frozenset())
        for bid in sorted(snap.live_blocks - {0} - keep):
            report.add("kv.leak", Severity.ERROR,
                       f"block {bid} is live in the allocator but mapped "
                       "by no slot and cached by no prefix — leaked "
                       "capacity", where=where)
    return report
