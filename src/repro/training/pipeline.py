"""Pipeline parallelism: GPipe-style microbatch schedule over a ``stage``
mesh axis with collective-permute handoff.

Used when depth exceeds what DP×TP can feed (≥ multi-pod scale); the
40-cell dry-run uses DP×TP×EP(+SP) which is the right fit for ≤512 chips,
so PP ships as a tested, composable feature rather than a default.

Implementation: ``shard_map`` over the ``stage`` axis; each stage holds
its own layer stack (params stacked on a leading stage axis).  The
schedule runs ``n_micro + n_stages - 1`` ticks; on each tick every stage
processes one microbatch and ``ppermute``s activations to its successor.
Bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params,
                   x_micro: jax.Array, *, axis: str = "stage") -> jax.Array:
    """Run microbatches through pipeline stages.

    stage_fn(params, x) -> x : one stage's computation.
    stage_params: pytree with leading [n_stages] axis (sharded over
        ``axis``).
    x_micro: [n_micro, micro_batch, ...] activations.
    Returns [n_micro, micro_batch, ...] outputs (from the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1

    def per_stage(params, xs):
        # params: this stage's slice (leading axis 1) ; xs: all microbatches
        params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])
        outputs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (when in range)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            incoming = jnp.where(stage_id == 0, xs[mb_idx], buf)
            active = (t - stage_id >= 0) & (t - stage_id < n_micro)
            y = stage_fn(params, incoming)
            y = jnp.where(active, y, incoming)
            # last stage writes its finished microbatch
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            write = active & (stage_id == n_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(write, y, outputs[out_idx]), out_idx, 0)
            # hand off to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outputs), None

        (buf, outputs), _ = jax.lax.scan(tick, (buf, outputs),
                                         jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, 0.0), axis)
        return outputs

    from repro.core.compat import shard_map

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_p, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
