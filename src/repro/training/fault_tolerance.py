"""Failure handling & straggler mitigation for long-running jobs.

On a real multi-pod deployment the failure domain is a host (8 chips on
v5e); the policies here are host-side and hardware-agnostic, so the same
code drives CPU CI and TPU pods:

* :class:`StragglerMonitor` — rolling step-time statistics with a robust
  (median + MAD) threshold; flags slow steps/hosts, and its
  ``should_checkpoint_now`` hook triggers a preemptive checkpoint when
  step times degrade persistently (a leading indicator of failing hosts).
* :class:`HeartbeatTracker` — rank-liveness bookkeeping for the elastic
  controller: ranks that miss ``timeout`` are declared dead; the job then
  restores the latest checkpoint onto the surviving mesh (see
  ``CheckpointManager.restore``'s elastic resharding).
* :func:`run_with_retries` — supervisor loop: on any step exception,
  restore from the newest checkpoint and continue; gives crash-consistency
  end-to-end (exercised in tests with injected failures).
"""

from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class StragglerMonitor:
    window: int = 64
    threshold: float = 3.0          # MADs above median = straggler
    degrade_patience: int = 8       # consecutive slow steps -> checkpoint

    _times: deque = field(default_factory=lambda: deque(maxlen=256))
    _slow_streak: int = 0
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        self._times.append(seconds)
        if len(self._times) < 8:
            return False
        med = statistics.median(self._times)
        mad = statistics.median(abs(t - med) for t in self._times) or 1e-9
        is_slow = seconds > med + self.threshold * mad * 1.4826
        if is_slow:
            self.flagged.append((step, seconds, med))
            self._slow_streak += 1
        else:
            self._slow_streak = 0
        return is_slow

    def should_checkpoint_now(self) -> bool:
        return self._slow_streak >= self.degrade_patience

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


@dataclass
class HeartbeatTracker:
    world_size: int
    timeout: float = 60.0
    _last: dict = field(default_factory=dict)

    def beat(self, rank: int, now: float | None = None) -> None:
        self._last[rank] = now if now is not None else time.time()

    def dead_ranks(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [r for r in range(self.world_size)
                if now - self._last.get(r, 0.0) > self.timeout]

    def alive(self, now: float | None = None) -> int:
        return self.world_size - len(self.dead_ranks(now))


def run_with_retries(step_fn: Callable[[int, Any], Any], state: Any,
                     n_steps: int, *, save_fn: Callable[[int, Any], None],
                     restore_fn: Callable[[], tuple[int, Any]],
                     max_failures: int = 3,
                     checkpoint_every: int = 50) -> tuple[Any, dict]:
    """Supervisor loop: run steps, checkpoint periodically, and on any
    exception restore the latest checkpoint and resume."""
    failures = 0
    recovered = 0
    step = 0
    while step < n_steps:
        try:
            state = step_fn(step, state)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step, state)
        except Exception:  # noqa: BLE001 - the supervisor's whole job
            failures += 1
            if failures > max_failures:
                raise
            step, state = restore_fn()
            recovered += 1
    return state, {"failures": failures, "recovered": recovered,
                   "final_step": step}
