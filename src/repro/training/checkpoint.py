"""Sharded, fault-tolerant checkpointing with elastic restore.

Design points for 1000+-node deployments:

* **Sharded manifests** — each parameter is stored as one ``.npy`` per
  *logical shard group* with a JSON manifest recording the global shape,
  dtype, and PartitionSpec.  On restore, each host reads only the slices
  its devices need.
* **Elastic resharding** — restore onto a *different* mesh shape than the
  checkpoint was written from: the manifest stores global arrays' layout,
  so a 512-chip checkpoint restores onto 256 chips (or 1 CPU) by
  re-slicing.  This is the checkpoint/restart story for node failures and
  elastic scaling.
* **Atomicity** — writes go to ``<dir>.tmp`` then ``os.replace`` onto the
  final name; a crash mid-save never corrupts the previous checkpoint.
* **Async** — ``save_async`` snapshots to host memory synchronously
  (cheap) and writes to disk on a background thread, overlapping I/O with
  the next training steps.
* **Retention** — ``keep`` newest step directories are retained.
"""

from __future__ import annotations

import json
import math
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(_path_elem(p) for p in path)
        out.append((key, leaf))
    return out


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _sanitize(key: str) -> str:
    return key.replace("/", "__")


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> Path:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        for key, leaf in _leaf_paths(host_tree):
            arr = np.asarray(leaf)
            fname = _sanitize(key) + ".npy"
            np.save(tmp / fname, arr)
            manifest["arrays"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional matching tree of NamedShardings — arrays
        are placed (and therefore re-sharded *elastically*) onto whatever
        mesh those shardings reference, regardless of the mesh shape at
        save time.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = manifest["arrays"]

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, like), sh in zip(flat, shard_flat):
            key = "/".join(_path_elem(p) for p in path)
            if key not in arrays:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = np.load(d / arrays[key]["file"])
            if arr.dtype.kind == "V":
                # extended dtypes (bfloat16, fp8) round-trip through npy as
                # raw void bytes; re-view via the manifest's dtype string
                arr = arr.view(np.dtype(arrays[key]["dtype"]))
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != expected "
                    f"{tuple(like.shape)}")
            if sh is not None:
                leaves.append(jax.device_put(arr.astype(like.dtype), sh))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
        return treedef.unflatten(leaves), manifest["extra"]
