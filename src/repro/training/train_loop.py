"""Training loop: gradient accumulation, clipping, LR schedule, metrics,
checkpoint/restart, straggler monitoring.

Works at any scale: host mesh on CPU (examples/CI) or the production mesh
(via launch/train.py).  The step function is pjit'd with rule-derived
shardings; fault tolerance comes from CheckpointManager + the supervisor
hooks in fault_tolerance.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core.optim import AdamW, clip_by_global_norm, cosine_schedule
from repro.runtime import current_session
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import StragglerMonitor


@dataclass
class TrainConfig:
    steps: int = 100
    base_lr: float = 3e-4
    warmup: int = 10
    grad_clip: float = 1.0
    accum: int = 1                   # gradient accumulation microsteps
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    log_every: int = 10


def make_step_fn(model, optimizer, tcfg: TrainConfig):
    schedule = cosine_schedule(tcfg.base_lr, tcfg.warmup, tcfg.steps)

    def step_fn(params, opt_state, step, batch):
        def loss_of(p, mb):
            loss, metrics = model.loss_fn(p, mb)
            return loss, metrics

        if tcfg.accum > 1:
            def micro(i, carry):
                gsum, lsum = carry
                mb = jax.tree.map(
                    lambda x: x.reshape(tcfg.accum, -1, *x.shape[1:])[i],
                    batch)
                (loss, _), g = jax.value_and_grad(loss_of,
                                                  has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(
                0, tcfg.accum, micro, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / tcfg.accum, grads)
            loss = loss / tcfg.accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = schedule(step)
        new_p, new_s = optimizer.apply_with_count(params, grads, opt_state,
                                                  lr, step + 1)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_p, new_s, metrics

    return step_fn


def train(model, params, batches: Iterator[Any], tcfg: TrainConfig,
          optimizer=None, jit_kwargs: dict | None = None,
          log_fn: Callable[[str], None] = print):
    """Returns (params, history). Resumes from checkpoint_dir if present.

    Runs under the ambient runtime Session (mesh, backend, kernels …);
    its ``describe()`` snapshot is logged once for provenance so a
    history can always be tied back to the configuration it ran under.
    """
    sess = current_session()
    log_fn(f"[train] session {sess.describe()}")
    optimizer = optimizer or AdamW(lr=tcfg.base_lr)
    opt_state = optimizer.init(params)
    start_step = 0
    ckpt = None
    if tcfg.checkpoint_dir:
        ckpt = CheckpointManager(tcfg.checkpoint_dir)
        if ckpt.latest_step() is not None:
            (params, opt_state), extra = ckpt.restore((params, opt_state))
            start_step = int(extra.get("step", ckpt.latest_step()))
            log_fn(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_step_fn(model, optimizer, tcfg),
                      donate_argnums=(0, 1), **(jit_kwargs or {}))
    monitor = StragglerMonitor()
    history: list[dict] = []
    step = start_step
    for batch in batches:
        if step >= tcfg.steps:
            break
        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, jnp.int32(step), batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        monitor.record(step, dt)
        history.append({"step": step, "loss": loss, "sec": dt})
        if step % tcfg.log_every == 0:
            log_fn(f"[train] step {step:5d} loss {loss:8.4f} "
                   f"({dt*1e3:6.1f} ms)")
        step += 1
        if ckpt and (step % tcfg.checkpoint_every == 0
                     or monitor.should_checkpoint_now()):
            ckpt.save_async(step, (params, opt_state), {"step": step})
    if ckpt:
        ckpt.save(step, (params, opt_state), {"step": step})
        ckpt.wait()
    return params, history
