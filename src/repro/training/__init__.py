from .checkpoint import CheckpointManager
from .fault_tolerance import (HeartbeatTracker, StragglerMonitor,
                              run_with_retries)
from .pipeline import bubble_fraction, pipeline_apply
from .train_loop import TrainConfig, make_step_fn, train

__all__ = ["CheckpointManager", "HeartbeatTracker", "StragglerMonitor",
           "run_with_retries", "bubble_fraction", "pipeline_apply",
           "TrainConfig", "make_step_fn", "train"]
