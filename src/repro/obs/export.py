"""Chrome-trace-event JSON export and schema validation.

The exported object follows the Trace Event Format (the "JSON Array
Format" wrapped in an object container), which both ``chrome://tracing``
and Perfetto load directly:

* spans      -> ``"ph": "X"`` complete events with ``ts``/``dur`` in µs
* instants   -> ``"ph": "i"`` with thread scope (``"s": "t"``)
* samples    -> ``"ph": "C"`` counter tracks
* metadata   -> ``"ph": "M"`` process/thread names

Extra top-level keys (``metrics``, ``metadata``) are permitted by the
format and carry the metrics snapshot alongside the events.
"""

from __future__ import annotations

import json
import os
from typing import Any

from repro.obs.trace import Tracer

__all__ = ["to_chrome_trace", "save_trace", "validate_chrome_trace"]

_PHASES = {"X", "B", "E", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}
_INSTANT_SCOPES = {"g", "p", "t"}


def _us(tracer: Tracer, t: float) -> float:
    # Round to ns so artifacts are compact and diff-stable.
    return round((t - tracer.epoch) * 1e6, 3)


def to_chrome_trace(tracer: Tracer) -> dict[str, Any]:
    """Render a tracer's events as a Perfetto-loadable trace object."""
    pid = os.getpid()
    events: list[dict[str, Any]] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": "repro"},
    }]
    with tracer._lock:
        spans = list(tracer.spans)
        instants = list(tracer.instants)
        samples = list(tracer.samples)
        thread_names = dict(tracer.thread_names)
        dropped = tracer.dropped
    for tid, tname in sorted(thread_names.items()):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": tname}})
    for sp in sorted(spans, key=lambda s: s.start):
        args: dict[str, Any] = dict(sp.attrs)
        args["span_id"] = sp.sid
        if sp.parent is not None:
            args["parent_id"] = sp.parent
        events.append({
            "ph": "X", "name": sp.name, "cat": sp.cat or "default",
            "ts": _us(tracer, sp.start),
            "dur": max(round(sp.duration * 1e6, 3), 0.0),
            "pid": pid, "tid": sp.tid, "args": args,
        })
    for ev in sorted(instants, key=lambda e: e.ts):
        events.append({
            "ph": "i", "s": "t", "name": ev.name,
            "cat": ev.cat or "default", "ts": _us(tracer, ev.ts),
            "pid": pid, "tid": ev.tid, "args": dict(ev.attrs),
        })
    for sm in sorted(samples, key=lambda s: s.ts):
        events.append({
            "ph": "C", "name": sm.name, "ts": _us(tracer, sm.ts),
            "pid": pid, "tid": 0, "args": {"value": sm.value},
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "tool": "repro.obs",
            "clock": "perf_counter",
            "epoch_s": tracer.epoch,
            "dropped_events": dropped,
        },
        "metrics": tracer.metrics.snapshot(),
    }


def save_trace(tracer: Tracer, path: str) -> dict[str, Any]:
    """Export and write a trace JSON; returns the exported object."""
    obj = to_chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=str)
    return obj


def validate_chrome_trace(obj: Any) -> list[str]:
    """Check an object against the Chrome trace-event schema.

    Returns a list of human-readable problems (empty == valid).  Covers
    the subset of the format Perfetto's JSON importer requires: the
    ``traceEvents`` container, per-event phase/name/ts/pid/tid typing,
    ``dur`` on complete events, scopes on instants, numeric counter
    args, and end-to-end JSON serializability.
    """
    errs: list[str] = []
    if not isinstance(obj, dict):
        return ["top-level value is not an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-array 'traceEvents'"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or ph not in _PHASES:
            errs.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"{where}: missing string 'name'")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errs.append(f"{where}: missing integer {key!r}")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errs.append(f"{where}: bad 'ts' {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: complete event with bad 'dur'")
        if ph in ("i", "I"):
            if ev.get("s", "t") not in _INSTANT_SCOPES:
                errs.append(f"{where}: bad instant scope {ev.get('s')!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                errs.append(f"{where}: counter event needs numeric args")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"{where}: 'args' is not an object")
    try:
        json.dumps(obj)
    except (TypeError, ValueError) as e:
        errs.append(f"not JSON-serializable: {e}")
    return errs
