"""Monotonic clock for every latency measurement in the repo.

Wall-clock (``time.time``) is subject to NTP slew and manual adjustment,
which skews TTFT / inter-token latency measurements taken across a step
boundary.  All tracing and serving latency code uses :func:`now` instead,
which reads the process-wide monotonic performance counter.  Values are
only meaningful as *differences* within one process.
"""

from __future__ import annotations

import time

__all__ = ["now"]


def now() -> float:
    """Seconds on the process-wide monotonic clock (``perf_counter``)."""
    return time.perf_counter()
