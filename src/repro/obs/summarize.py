"""Offline analysis of an exported trace.

Given a Chrome-trace JSON produced by :mod:`repro.obs.export`, compute:

* a span tree aggregated by call path (count / total / self time);
* top spans by *self* time (duration minus direct children — the
  "kernels" view: where time is actually spent, not just contained);
* per-request serving latency: TTFT (``request.submit`` ->
  ``request.first_token``) and inter-token gaps (consecutive
  ``request.token`` events per uid), with mean / p50 / p90 / p99.

Percentiles use the same linear-interpolation method as numpy so trace
summaries agree with benchmark-side math to float precision.
"""

from __future__ import annotations

import json
from typing import Any

from repro.obs.metrics import percentile

__all__ = ["load_trace", "summarize", "render"]


def load_trace(path: str) -> dict[str, Any]:
    with open(path) as f:
        obj = json.load(f)
    if not isinstance(obj, dict):
        raise ValueError(f"{path}: trace top level is not an object")
    return obj


def _dist(values: list[float]) -> dict[str, Any]:
    vals = sorted(values)
    return {
        "count": len(vals),
        "mean": (sum(vals) / len(vals)) if vals else None,
        "p50": percentile(vals, 50.0),
        "p90": percentile(vals, 90.0),
        "p99": percentile(vals, 99.0),
        "min": vals[0] if vals else None,
        "max": vals[-1] if vals else None,
    }


def summarize(trace: dict[str, Any]) -> dict[str, Any]:
    """Aggregate a loaded trace object into a JSON-friendly summary."""
    events = trace.get("traceEvents", [])
    spans = [e for e in events if isinstance(e, dict) and e.get("ph") == "X"]
    instants = [e for e in events
                if isinstance(e, dict) and e.get("ph") in ("i", "I")]

    # Self time: duration minus the sum of direct children's durations.
    child_dur: dict[int, float] = {}
    for ev in spans:
        args = ev.get("args") or {}
        parent = args.get("parent_id")
        if isinstance(parent, int):
            child_dur[parent] = child_dur.get(parent, 0.0) \
                + float(ev.get("dur", 0.0))

    by_name: dict[str, dict[str, Any]] = {}
    by_path: dict[tuple[str, ...], dict[str, Any]] = {}
    names: dict[int, str] = {}
    parents: dict[int, int | None] = {}
    for ev in spans:
        args = ev.get("args") or {}
        sid = args.get("span_id")
        if isinstance(sid, int):
            names[sid] = str(ev.get("name"))
            par = args.get("parent_id")
            parents[sid] = par if isinstance(par, int) else None
    for ev in spans:
        args = ev.get("args") or {}
        sid = args.get("span_id")
        dur = float(ev.get("dur", 0.0))
        self_dur = max(dur - child_dur.get(sid, 0.0), 0.0) \
            if isinstance(sid, int) else dur
        name = str(ev.get("name"))
        agg = by_name.setdefault(name, {
            "name": name, "cat": ev.get("cat", ""),
            "count": 0, "total_us": 0.0, "self_us": 0.0})
        agg["count"] += 1
        agg["total_us"] += dur
        agg["self_us"] += self_dur
        # Path = chain of ancestor names, for the rendered span tree.
        path: list[str] = [name]
        cur = parents.get(sid) if isinstance(sid, int) else None
        hops = 0
        while isinstance(cur, int) and hops < 64:
            path.append(names.get(cur, "?"))
            cur = parents.get(cur)
            hops += 1
        key = tuple(reversed(path))
        pagg = by_path.setdefault(key, {"count": 0, "total_us": 0.0})
        pagg["count"] += 1
        pagg["total_us"] += dur

    # Request lifecycle latency from serving instants.
    submits: dict[Any, float] = {}
    firsts: dict[Any, float] = {}
    tokens: dict[Any, list[float]] = {}
    dones: dict[Any, float] = {}
    for ev in instants:
        name = ev.get("name")
        uid = (ev.get("args") or {}).get("uid")
        ts = float(ev.get("ts", 0.0))
        if name == "request.submit":
            submits[uid] = ts
        elif name == "request.first_token":
            firsts[uid] = ts
        elif name == "request.token":
            tokens.setdefault(uid, []).append(ts)
        elif name == "request.done":
            dones[uid] = ts
    ttft_s = [(firsts[u] - submits[u]) / 1e6
              for u in firsts if u in submits]
    inter_s: list[float] = []
    for ts_list in tokens.values():
        ts_list.sort()
        inter_s.extend((b - a) / 1e6 for a, b in zip(ts_list, ts_list[1:]))

    top = sorted(by_name.values(), key=lambda a: -float(a["self_us"]))
    tree = [{"path": list(k), "count": v["count"],
             "total_us": round(float(v["total_us"]), 3)}
            for k, v in sorted(by_path.items())]
    meta = trace.get("metadata")
    return {
        "spans": {"total": len(spans), "by_name": top},
        "tree": tree,
        "requests": {
            "submitted": len(submits),
            "completed": len(dones),
            "ttft_s": _dist(ttft_s),
            "inter_token_s": _dist(inter_s),
        },
        "instants": len(instants),
        "metrics": trace.get("metrics"),
        "dropped_events": (meta or {}).get("dropped_events", 0)
        if isinstance(meta, dict) else 0,
    }


def _fmt_dist(d: dict[str, Any]) -> str:
    def ms(v: Any) -> str:
        return f"{v * 1e3:.3f}ms" if isinstance(v, (int, float)) else "-"
    return (f"n={d['count']} mean={ms(d['mean'])} p50={ms(d['p50'])} "
            f"p90={ms(d['p90'])} p99={ms(d['p99'])} max={ms(d['max'])}")


def render(summary: dict[str, Any], top: int = 10) -> str:
    """Human-readable report for the ``python -m repro.obs`` CLI."""
    lines: list[str] = []
    spans = summary["spans"]
    lines.append(f"spans: {spans['total']}  "
                 f"instants: {summary['instants']}  "
                 f"dropped: {summary['dropped_events']}")
    lines.append("")
    lines.append("span tree (count, total):")
    for node in summary["tree"]:
        path = node["path"]
        indent = "  " * (len(path) - 1)
        lines.append(f"  {indent}{path[-1]}  x{node['count']}  "
                     f"{node['total_us'] / 1e3:.3f}ms")
    lines.append("")
    lines.append(f"top {top} spans by self time:")
    for agg in spans["by_name"][:top]:
        lines.append(f"  {agg['name']:<40} x{agg['count']:<6} "
                     f"self {agg['self_us'] / 1e3:>10.3f}ms  "
                     f"total {agg['total_us'] / 1e3:>10.3f}ms")
    req = summary["requests"]
    lines.append("")
    lines.append(f"requests: {req['submitted']} submitted, "
                 f"{req['completed']} completed")
    lines.append(f"  ttft:        {_fmt_dist(req['ttft_s'])}")
    lines.append(f"  inter-token: {_fmt_dist(req['inter_token_s'])}")
    metrics = summary.get("metrics")
    if isinstance(metrics, dict):
        counters = metrics.get("counters") or {}
        if counters:
            lines.append("")
            lines.append("counters:")
            for name, val in counters.items():
                lines.append(f"  {name:<44} {val:g}")
    return "\n".join(lines)
