"""``repro.obs`` — unified tracing + metrics across compiler, serving, memory.

One event model for the whole stack: monotonic-clock :class:`Span` trees,
instant events, and a :class:`MetricsRegistry` of counters / gauges /
histograms, recorded into a session-scoped :class:`Tracer` and exported
as Chrome-trace-event JSON loadable in Perfetto / ``chrome://tracing``.

Gating: observability is **off by default**.  Enable per session::

    with repro.session(obs=True):          # or obs={"max_events": 50_000}
        ...

Instrumentation sites call :func:`get_tracer`, which returns ``None``
unless the ambient session's :class:`ObservabilityPolicy` is enabled —
the off path is a single attribute check.  Sessions derived from an
enabled one (nested ``repro.session(...)``) share the same tracer, so
compiler, serving, and memory events land in one stream.

Summarize a trace offline::

    python -m repro.obs summarize trace.json
"""

from __future__ import annotations

from contextlib import AbstractContextManager, nullcontext
from typing import Any

from repro.obs.clock import now
from repro.obs.export import save_trace, to_chrome_trace, validate_chrome_trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Instant, Sample, Span, Tracer

__all__ = [
    "now",
    "get_tracer",
    "span",
    "instant",
    "Span",
    "Instant",
    "Sample",
    "Tracer",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "to_chrome_trace",
    "save_trace",
    "validate_chrome_trace",
]


def get_tracer(sess: Any | None = None) -> Tracer | None:
    """The tracer of ``sess`` (default: the ambient session), or ``None``
    when its observability policy is disabled or absent."""
    if sess is None:
        from repro.runtime import current_session
        sess = current_session()
    policy = getattr(sess, "obs", None)
    if policy is None:
        return None
    tracer = policy.tracer()
    return tracer if isinstance(tracer, Tracer) else None


def span(name: str, cat: str = "",
         **attrs: Any) -> AbstractContextManager[Span | None]:
    """Context manager recording a span on the ambient session's tracer;
    a no-op (yielding ``None``) when observability is off."""
    tracer = get_tracer()
    if tracer is None:
        return nullcontext(None)
    return tracer.span(name, cat, **attrs)


def instant(name: str, cat: str = "", ts: float | None = None,
            **attrs: Any) -> None:
    """Record an instant event on the ambient session's tracer, if any."""
    tracer = get_tracer()
    if tracer is not None:
        tracer.instant(name, cat, ts=ts, **attrs)
