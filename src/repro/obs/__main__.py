"""CLI: ``python -m repro.obs summarize trace.json [--json] [--top N]``.

Also: ``python -m repro.obs validate trace.json`` checks a trace against
the Chrome trace-event schema and exits non-zero on problems.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_chrome_trace
from repro.obs.summarize import load_trace, render, summarize


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # Bare `python -m repro.obs trace.json` means summarize.
    if argv and argv[0] not in ("summarize", "validate", "-h", "--help"):
        argv.insert(0, "summarize")
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sp = sub.add_parser("summarize", help="report on an exported trace")
    sp.add_argument("trace", help="path to a Chrome-trace JSON")
    sp.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    sp.add_argument("--top", type=int, default=10,
                    help="rows in the self-time table")
    vp = sub.add_parser("validate", help="schema-check an exported trace")
    vp.add_argument("trace", help="path to a Chrome-trace JSON")
    args = ap.parse_args(argv)

    trace = load_trace(args.trace)
    if args.cmd == "validate":
        errs = validate_chrome_trace(trace)
        for e in errs:
            print(e, file=sys.stderr)
        print(f"{args.trace}: "
              + ("OK" if not errs else f"{len(errs)} problem(s)"))
        return 1 if errs else 0
    summary = summarize(trace)
    if args.json:
        print(json.dumps(summary, indent=2, default=str))
    else:
        print(render(summary, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
