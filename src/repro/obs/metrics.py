"""Counters, gauges, and histograms for the observability layer.

A :class:`MetricsRegistry` hangs off a :class:`~repro.obs.trace.Tracer`
(one per enabled :class:`~repro.runtime.policies.ObservabilityPolicy`),
so metrics share the trace's lifetime and land in the same exported
artifact.  All instruments are thread-safe behind one registry lock;
gauge updates additionally emit a counter-track sample into the trace so
Perfetto renders them over time.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Any

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.obs.trace import Tracer

# ``threading.RLock`` is a factory function, not a class, so it cannot
# appear in annotations; instruments only enter the lock as a context
# manager anyway.
_RLock = Any

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

# Raw histogram samples kept for exact percentiles; beyond this the
# histogram still tracks count/total/min/max but drops raw values.
_HIST_MAX_SAMPLES = 65_536


class Counter:
    """Monotonically increasing count."""

    def __init__(self, name: str, lock: _RLock) -> None:
        self.name = name
        self.value = 0.0
        self._lock = lock

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins value; each ``set`` also samples a counter track."""

    def __init__(self, name: str, lock: _RLock,
                 tracer: "Tracer | None") -> None:
        self.name = name
        self.value = 0.0
        self._lock = lock
        self._tracer = tracer

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)
        if self._tracer is not None:
            self._tracer.sample(self.name, float(value))


class Histogram:
    """Distribution of observed values with exact percentiles.

    Raw samples are bounded (``dropped_samples`` counts the overflow);
    count/total/min/max stay exact regardless.
    """

    def __init__(self, name: str, lock: _RLock) -> None:
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.values: list[float] = []
        self.dropped_samples = 0
        self._lock = lock

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)
            if len(self.values) < _HIST_MAX_SAMPLES:
                self.values.append(v)
            else:
                self.dropped_samples += 1

    def percentile(self, q: float) -> float | None:
        """Linearly-interpolated percentile (numpy's default method)."""
        with self._lock:
            vals = sorted(self.values)
        return percentile(vals, q)

    def summary(self) -> dict[str, Any]:
        with self._lock:
            vals = sorted(self.values)
            count, total = self.count, self.total
            vmin, vmax = self.vmin, self.vmax
        out: dict[str, Any] = {
            "count": count,
            "total": total,
            "mean": (total / count) if count else None,
            "min": vmin if count else None,
            "max": vmax if count else None,
        }
        for q in (50.0, 90.0, 99.0):
            out[f"p{int(q)}"] = percentile(vals, q)
        return out


def percentile(sorted_vals: list[float], q: float) -> float | None:
    """Linear-interpolation percentile over pre-sorted values.

    Matches ``numpy.percentile``'s default method so benchmark-side
    numbers (numpy) and trace-side numbers (this helper) agree exactly.
    """
    n = len(sorted_vals)
    if n == 0:
        return None
    if n == 1:
        return sorted_vals[0]
    rank = (q / 100.0) * (n - 1)
    lo = int(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class MetricsRegistry:
    """Get-or-create registry of named instruments (thread-safe)."""

    def __init__(self, tracer: "Tracer | None" = None) -> None:
        self._tracer = tracer
        self._lock = threading.RLock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, self._lock)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, self._lock, self._tracer)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, self._lock)
            return h

    def snapshot(self) -> dict[str, Any]:
        """JSON-serializable point-in-time view of every instrument."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(histograms.items())},
        }
