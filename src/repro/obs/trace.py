"""Core tracing primitives: spans, instants, counter samples.

A :class:`Tracer` is a thread-safe, bounded event sink with a monotonic
clock (:mod:`repro.obs.clock`).  Spans nest per thread: each thread keeps
its own open-span stack, so a span started on thread A never becomes the
parent of one started on thread B.  All recorded events carry the native
thread id and are exported on separate tracks.

The tracer never allocates past ``max_events`` retained events — beyond
that, finished events are dropped and counted (``dropped``), keeping
obs-on cost bounded on long runs.
"""

from __future__ import annotations

import itertools
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Iterator

from repro.obs.clock import now
from repro.obs.metrics import MetricsRegistry

__all__ = ["Span", "Instant", "Sample", "Tracer"]


@dataclass(eq=False)
class Span:
    """A named duration with structured attributes.

    ``sid``/``parent`` express nesting; ``tid`` is the recording thread.
    ``attrs`` may be updated until export (handy for filling in results
    computed inside the span).
    """

    name: str
    cat: str
    start: float
    end: float
    sid: int
    parent: int | None
    tid: int
    attrs: dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclass(eq=False)
class Instant:
    """A point-in-time event (request lifecycle edges, alloc/free, ...)."""

    name: str
    cat: str
    ts: float
    tid: int
    attrs: dict[str, Any] = field(default_factory=dict)


@dataclass(eq=False)
class Sample:
    """One point on a counter track (gauge value over time)."""

    name: str
    ts: float
    value: float


class _ThreadState(threading.local):
    """Per-thread open-span stack."""

    def __init__(self) -> None:
        self.stack: list[Span] = []


class Tracer:
    """Thread-safe bounded sink for spans, instants, and samples."""

    def __init__(self, max_events: int = 200_000) -> None:
        self.max_events = max_events
        self.epoch = now()
        self.spans: list[Span] = []
        self.instants: list[Instant] = []
        self.samples: list[Sample] = []
        self.dropped = 0
        self.thread_names: dict[int, str] = {}
        self.metrics = MetricsRegistry(self)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._tls = _ThreadState()

    # ------------------------------------------------------------- events

    def begin(self, name: str, cat: str = "", **attrs: Any) -> Span:
        """Open a span; it becomes the parent of spans opened after it
        on the same thread until :meth:`finish`."""
        stack = self._tls.stack
        parent = stack[-1].sid if stack else None
        sp = Span(name=name, cat=cat, start=now(), end=0.0,
                  sid=next(self._ids), parent=parent, tid=self._tid(),
                  attrs=dict(attrs))
        stack.append(sp)
        return sp

    def finish(self, sp: Span) -> None:
        sp.end = now()
        stack = self._tls.stack
        if stack and stack[-1] is sp:
            stack.pop()
        else:  # mis-nested finish: unwind down to (and including) sp
            for i, open_sp in enumerate(stack):
                if open_sp is sp:
                    del stack[i:]
                    break
        with self._lock:
            if self._n_events() < self.max_events:
                self.spans.append(sp)
            else:
                self.dropped += 1

    @contextmanager
    def span(self, name: str, cat: str = "", **attrs: Any) -> Iterator[Span]:
        sp = self.begin(name, cat, **attrs)
        try:
            yield sp
        finally:
            self.finish(sp)

    def instant(self, name: str, cat: str = "", ts: float | None = None,
                **attrs: Any) -> None:
        """Record a point event.  Pass ``ts`` (from :func:`repro.obs.now`)
        to stamp it with a moment measured by the caller — the serving
        engine does this so trace timestamps and benchmark-side latency
        math read the very same clock sample."""
        ev = Instant(name=name, cat=cat, ts=now() if ts is None else ts,
                     tid=self._tid(), attrs=dict(attrs))
        with self._lock:
            if self._n_events() < self.max_events:
                self.instants.append(ev)
            else:
                self.dropped += 1

    def sample(self, name: str, value: float,
               ts: float | None = None) -> None:
        ev = Sample(name=name, ts=now() if ts is None else ts,
                    value=float(value))
        with self._lock:
            if self._n_events() < self.max_events:
                self.samples.append(ev)
            else:
                self.dropped += 1

    # ------------------------------------------------------------ helpers

    def _n_events(self) -> int:
        return len(self.spans) + len(self.instants) + len(self.samples)

    def _tid(self) -> int:
        tid = threading.get_ident()
        if tid not in self.thread_names:
            with self._lock:
                self.thread_names[tid] = threading.current_thread().name
        return tid

    def describe(self) -> dict[str, Any]:
        with self._lock:
            return {
                "spans": len(self.spans),
                "instants": len(self.instants),
                "samples": len(self.samples),
                "dropped": self.dropped,
                "threads": len(self.thread_names),
            }
