from .beam import BeamResult, beam_decode
from .decode_attention import make_flash_decode_attend
from .engine import Request, ServeEngine
from .kv_cache import BlockTable, OutOfMemory, PagedKVCache
from .prefix import PrefixIndex, PrefixNode
from .speculative import (FixedProposer, ModelDraft, NGramProposer, Proposer,
                          make_proposer)
from .router import (LeastLoadedRouting, PrefixAffinityRouting,
                     RoundRobinRouting, Router, RoutingPolicy, make_routing,
                     serve, timed_stream)
from .scheduler import (FifoScheduler, PriorityScheduler, Scheduler,
                        ShortestPromptScheduler, make_scheduler)

__all__ = ["make_flash_decode_attend", "Request", "ServeEngine",
           "BlockTable", "PagedKVCache", "OutOfMemory", "Scheduler",
           "FifoScheduler", "ShortestPromptScheduler", "PriorityScheduler",
           "make_scheduler", "PrefixIndex", "PrefixNode",
           "RoutingPolicy", "RoundRobinRouting", "LeastLoadedRouting",
           "PrefixAffinityRouting", "make_routing", "Router", "serve",
           "timed_stream", "Proposer", "NGramProposer", "FixedProposer",
           "ModelDraft", "make_proposer", "beam_decode", "BeamResult"]
