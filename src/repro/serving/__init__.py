from .decode_attention import make_flash_decode_attend
from .engine import Request, ServeEngine

__all__ = ["make_flash_decode_attend", "Request", "ServeEngine"]
