"""Beam search on the serving engine via copy-on-write sequence forks.

A beam is a slot.  Expanding a hypothesis into several continuations is
``ServeEngine.fork``: the child slot's block table maps the parent's
physical blocks (refcount++ per block, no data copied), and the first
divergent token write triggers copy-on-write for just the block it
lands in through the same ``prepare_write`` barrier prefix sharing
uses.  A beam that falls off the frontier is ``release`` — refcounted,
so blocks shared with surviving siblings stay live.

This is the same primitive speculative decoding's rollback builds on,
and it gives ``bench_beamsearch.py`` a real engine path: beam search
over a width-W frontier costs one batched decode per step plus
O(blocks) refcount bumps per fork, not W separate sequence caches.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro import obs


@dataclass
class BeamResult:
    tokens: list[int]               # generated tokens of the best beam
    score: float                    # sum of next-token log-probs
    beams: list[tuple[list[int], float]] = field(default_factory=list)
    stats: dict = field(default_factory=dict)


def beam_decode(engine, prompt: list[int], *, width: int, max_new: int,
                eos_id: int | None = None) -> BeamResult:
    """Beam-search ``max_new`` tokens from ``prompt`` on an idle engine.

    The frontier lives in engine slots: the prompt prefills into one
    slot, every step decodes all live beams in one batched call, the
    global top-``width`` (score, parent, token) continuations are
    selected, and parents with several surviving children fork.  A
    ``width`` of 1 is exactly greedy decode.
    """
    if engine.active or len(engine.scheduler):
        raise ValueError("beam_decode needs an idle engine")
    if not engine.paged:
        raise ValueError("beam_decode requires the paged KV cache "
                         "(forks are block-table clones)")
    if not 1 <= width <= engine.slots:
        raise ValueError(f"width {width} not in [1, {engine.slots} slots]")
    kv = engine.kv

    # -- prefill the prompt into slot 0 --------------------------------------
    root = 0
    n = len(prompt) - 1
    kv.begin_write(root, 0, max(n - 1, 0))
    kv.ensure(root, max(n - 1, 0))
    engine.slot_pos[root] = n
    engine.slot_tok[root, 0] = prompt[-1]
    if n > 0:
        if engine._chunked:
            t = engine.policy.prefill_chunk
            bt = engine._block_table()
            for c in range(0, n, t):
                seg = prompt[c:min(c + t, n)]
                toks = np.zeros((engine.slots, t), np.int32)
                toks[root, :len(seg)] = seg
                start = np.zeros(engine.slots, np.int32)
                start[root] = c
                count = np.zeros(engine.slots, np.int32)
                count[root] = len(seg)
                engine.cache = engine._prefill(
                    engine.params, engine.cache, jnp.asarray(toks),
                    jnp.asarray(start), jnp.asarray(count), bt)
                engine.prefill_calls += 1
        else:
            engine._prefill_per_token(root, list(prompt))

    # -- frontier ------------------------------------------------------------
    live: dict[int, tuple[list[int], float]] = {root: ([], 0.0)}
    done: list[tuple[list[int], float]] = []
    steps = 0
    for _ in range(max_new):
        if not live:
            break
        # COW barrier: every live slot is about to write its next token
        # at slot_pos; forked blocks with other sharers get private
        # copies first
        with obs.span("beam.step", "serving", beams=len(live)):
            for slot in sorted(live):
                p = int(engine.slot_pos[slot])
                kv.begin_write(slot, p, p)
                kv.ensure(slot, p)
                engine.cache = kv.prepare_write(slot, p, p, engine.cache)
            logp, engine.cache = engine._decode_logits(
                engine.params, engine.cache, jnp.asarray(engine.slot_tok),
                jnp.asarray(engine.slot_pos), engine._block_table())
            engine.decode_calls += 1
            steps += 1
            lp = np.asarray(logp)
        # global top-width over (beam score + token log-prob)
        room = width - len(done)
        cands: list[tuple[float, int, int]] = []   # (score, slot, token)
        for slot, (toks, score) in live.items():
            row = lp[slot]
            top = np.argsort(-row, kind="stable")[:room]
            for tok in top:
                cands.append((score + float(row[tok]), slot, int(tok)))
        cands.sort(key=lambda c: (-c[0], c[1], c[2]))
        cands = cands[:room]
        # assignment: one child keeps the parent slot, extras fork;
        # childless parents release *first* so their slots can host
        # forks from fecund siblings
        by_parent: dict[int, list[tuple[float, int]]] = {}
        for score, slot, tok in cands:
            by_parent.setdefault(slot, []).append((score, tok))
        free = [s for s in range(engine.slots) if s not in live]
        for slot in sorted(live):
            if slot not in by_parent:
                kv.release(slot)
                engine._audit_kv()
                free.append(slot)
        free.sort(reverse=True)                    # ascending via pop()
        nxt: dict[int, tuple[list[int], float]] = {}
        for slot in sorted(by_parent):
            toks, _ = live[slot]
            kids = by_parent[slot]
            keep_score, keep_tok = kids[0]
            for score, tok in kids[1:]:
                if eos_id is not None and tok == eos_id:
                    done.append((toks + [tok], score))
                    continue
                dst = free.pop()
                engine.fork(slot, dst)
                engine.slot_tok[dst, 0] = tok
                engine.slot_pos[dst] += 1
                nxt[dst] = (toks + [tok], score)
            if eos_id is not None and keep_tok == eos_id:
                done.append((toks + [keep_tok], keep_score))
                kv.release(slot)
                engine._audit_kv()
            else:
                engine.slot_tok[slot, 0] = keep_tok
                engine.slot_pos[slot] += 1
                nxt[slot] = (toks + [keep_tok], keep_score)
        live = nxt
        if len(done) >= width:
            break
    for slot in live:
        kv.release(slot)
        engine._audit_kv()
    done.extend(live.values())
    done.sort(key=lambda b: -b[1])
    best = done[0]
    return BeamResult(tokens=best[0], score=best[1], beams=done,
                      stats={"steps": steps,
                             "forks": kv.forks,
                             "cow_copies": kv.cow_copies,
                             "fork_counts": dict(engine.fork_counts)})
