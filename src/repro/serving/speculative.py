"""Draft proposers for speculative decoding on the paged KV cache.

The engine's speculative loop (``ServeEngine._spec_step``) is
draft-propose / wide-verify / rollback:

1. a *proposer* guesses up to ``k`` next tokens per active slot,
2. the target model scores the last accepted token plus all proposals
   in one batched wide forward (``LM.verify_step`` — per-slot variable
   width spans, exactly the chunked-prefill write semantics),
3. the longest proposal prefix matching the target's own greedy argmax
   is accepted, one bonus token comes free from the verify logits, and
   the rejected suffix *rolls back* by truncating the slot's block
   table (``PagedKVCache.rollback``) — whole rejected blocks return to
   the memory manager.

The acceptance rule makes greedy speculative decoding token-for-token
identical to one-token decode regardless of proposal quality; proposers
only change *speed* (accepted tokens per verify call), never output.

Proposers
---------
``NGramProposer``
    Self-drafting: re-occurrences of the current suffix earlier in the
    sequence predict its continuation.  Zero model calls, zero state —
    the cheap default that wins whenever decoding is locally repetitive
    (code, structured text, greedy cycles).
``ModelDraft``
    A second, smaller model (paired from ``src/repro/configs/`` — e.g.
    mamba2 drafting for a transformer target) decodes ``k`` tokens ahead
    against its own dense cache.  Rollback on the draft side is cache
    *snapshot selection*: the k+1 draft steps each snapshot the cache,
    and ``commit`` merges, per batch row, the snapshot matching that
    slot's accepted length.
``FixedProposer``
    Test hook: proposals come from a callable ``fn(context) -> tokens``
    (an oracle replaying the baseline output hits acceptance == k;
    a constant wrong token hits acceptance == 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs


class Proposer:
    """Base proposer: stateless, proposes nothing (plain decode)."""

    def admit(self, slot: int, prompt: list[int]) -> None:
        """A request landed in ``slot`` with effective prompt
        ``prompt`` (original prompt + tokens generated before a
        preemption); stateful proposers catch their cache up here."""

    def release(self, slot: int) -> None:
        """The slot finished or was preempted; drop its state."""

    def propose(self, contexts: dict[int, list[int]],
                k: int) -> dict[int, list[int]]:
        """Per-slot draft continuations (0..k tokens each).

        ``contexts[slot]`` is the full token context — prompt plus
        everything generated — whose last element is the engine's
        ``slot_tok`` (emitted, KV not yet written)."""
        return {s: [] for s in contexts}

    def commit(self, accepted: dict[int, int]) -> None:
        """Verify outcome for the round's slots: ``accepted[slot]`` of
        the proposals were kept (plus the free bonus token, which the
        next round feeds back as the slot's last token)."""

    def describe(self) -> dict:
        return {"kind": type(self).__name__}


class NGramProposer(Proposer):
    """Suffix-matching self-drafter.

    Looks for the most recent earlier occurrence of the context's last
    ``n-1`` tokens (falling back to shorter suffixes down to 1) and
    proposes the tokens that followed it.  Wrong proposals cost nothing
    but rejected verify width, so matching aggressively is safe.
    """

    def __init__(self, n: int = 3):
        self.n = max(2, int(n))

    def propose(self, contexts: dict[int, list[int]],
                k: int) -> dict[int, list[int]]:
        return {s: self._match(ctx, k) for s, ctx in contexts.items()}

    def _match(self, ctx: list[int], k: int) -> list[int]:
        size = len(ctx)
        for m in range(min(self.n - 1, size - 1), 0, -1):
            tail = ctx[size - m:]
            # latest candidate start leaving >= 1 follower token
            for i in range(size - m - 1, -1, -1):
                if ctx[i:i + m] == tail:
                    return ctx[i + m:i + m + k]
        return []

    def describe(self) -> dict:
        return {"kind": "NGramProposer", "n": self.n}


class FixedProposer(Proposer):
    """Proposals from a callable ``fn(context) -> list[int]``."""

    def __init__(self, fn):
        self.fn = fn

    def propose(self, contexts: dict[int, list[int]],
                k: int) -> dict[int, list[int]]:
        return {s: list(self.fn(ctx))[:k] for s, ctx in contexts.items()}


class ModelDraft(Proposer):
    """Draft-model proposer with snapshot-selection rollback.

    The draft keeps a dense cache sized like the target engine (slot
    for slot) and mirrors the engine's position bookkeeping: before a
    round, the draft has consumed everything up to but excluding the
    engine's ``slot_tok``.  One round runs ``k + 1`` batched draft
    decode steps — feed ``slot_tok``, then each argmax — snapshotting
    the (immutable) cache after each.  ``commit(accepted)`` then
    rebuilds the cache per batch row from the snapshot matching that
    slot's accepted length: rows of slots that accepted ``a`` proposals
    take snapshot ``a`` (consumed ``slot_tok, d_1..d_a``), idle rows
    keep the pre-round cache.  Rollback on the draft side is therefore
    a where-select, no recompute.

    Mid-flight admission catch-up feeds the new slot's prompt one token
    at a time through the same batched step and then merges *only that
    row* back — whatever those calls did to other rows (including SSM
    recurrent state, which is why mamba2 works as a draft here) is
    discarded by the merge.
    """

    def __init__(self, model, params, *, slots: int, max_seq: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.cache = model.init_cache(slots, max_seq)
        self.pos = np.zeros(slots, np.int32)
        self.draft_calls = 0
        self._axes: list[int] | None = None
        self._step = jax.jit(self._step_fn)
        self._round = None      # (base cache, snapshots, active slots)

    def _step_fn(self, params, cache, tok, pos):
        logits, cache = self.model.decode_step(params, cache, tok, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    # -- per-leaf batch axis (structural) ------------------------------------
    def _batch_axes(self) -> list[int]:
        """Batch axis of every cache leaf, derived by diffing specs at
        ``slots`` vs ``slots + 1`` (scan-stacked layers prepend a layer
        axis, so the batch lands at axis 1 there)."""
        if self._axes is not None:
            return self._axes
        a = jax.tree_util.tree_leaves(
            self.model.cache_spec(self.slots, self.max_seq))
        b = jax.tree_util.tree_leaves(
            self.model.cache_spec(self.slots + 1, self.max_seq))
        axes = []
        for la, lb in zip(a, b):
            sa, sb = tuple(la.shape), tuple(lb.shape)
            hits = [ax for ax in (0, 1)
                    if len(sa) > ax
                    and sb == sa[:ax] + (self.slots + 1,) + sa[ax + 1:]]
            if len(hits) != 1:
                raise ValueError(
                    f"cannot identify batch axis for draft cache leaf "
                    f"{sa} vs {sb}; candidates: {hits}")
            axes.append(hits[0])
        self._axes = axes
        return axes

    def _select_rows(self, mask: np.ndarray, new, old):
        """Per-leaf ``where`` along the batch axis: rows where ``mask``
        is set come from ``new``, the rest from ``old``."""
        m = jnp.asarray(mask)
        leaves_new, treedef = jax.tree_util.tree_flatten(new)
        leaves_old = jax.tree_util.tree_leaves(old)
        out = []
        for ln, lo, ax in zip(leaves_new, leaves_old, self._batch_axes()):
            shape = [1] * ln.ndim
            shape[ax] = m.shape[0]
            out.append(jnp.where(m.reshape(shape), ln, lo))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- lifecycle -----------------------------------------------------------
    def admit(self, slot: int, prompt: list[int]) -> None:
        mask = np.zeros(self.slots, bool)
        mask[slot] = True
        saved = self.cache
        # fresh recurrent/attention state for the recycled row, then
        # consume prompt[:-1]; the engine's slot_tok (= prompt[-1]) is
        # fed by the first propose round, mirroring the target
        work = self._select_rows(
            mask, jax.tree_util.tree_map(jnp.zeros_like, saved), saved)
        tok = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros(self.slots, np.int32)
        for i, t in enumerate(prompt[:-1]):
            tok[slot, 0] = t
            pos[slot] = i
            _, work = self._step(self.params, work, jnp.asarray(tok),
                                 jnp.asarray(pos))
            self.draft_calls += 1
        self.cache = self._select_rows(mask, work, saved)
        self.pos[slot] = len(prompt) - 1

    def release(self, slot: int) -> None:
        self.pos[slot] = 0      # row content is garbage until next admit

    # -- propose / commit ----------------------------------------------------
    def propose(self, contexts: dict[int, list[int]],
                k: int) -> dict[int, list[int]]:
        with obs.span("spec.draft_propose", "serving",
                      slots=len(contexts), k=k):
            return self._propose(contexts, k)

    def _propose(self, contexts: dict[int, list[int]],
                 k: int) -> dict[int, list[int]]:
        active = sorted(contexts)
        base = self.cache
        tok = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros(self.slots, np.int32)
        for s in active:
            tok[s, 0] = contexts[s][-1]
            pos[s] = self.pos[s]
        snaps = []
        cur = base
        out: dict[int, list[int]] = {s: [] for s in active}
        # k+1 steps: the last produces the snapshot for acceptance == k
        # (its logits are never used)
        for i in range(k + 1):
            nxt, cur = self._step(self.params, cur, jnp.asarray(tok),
                                  jnp.asarray(pos))
            self.draft_calls += 1
            snaps.append(cur)
            if i < k:
                nxt_np = np.asarray(nxt)
                for s in active:
                    out[s].append(int(nxt_np[s]))
                    tok[s, 0] = nxt_np[s]
                    pos[s] += 1
        self._round = (base, snaps, active)
        return out

    def commit(self, accepted: dict[int, int]) -> None:
        if self._round is None:
            return
        base, snaps, active = self._round
        self._round = None
        new = base
        for i, snap in enumerate(snaps):
            mask = np.zeros(self.slots, bool)
            for s in active:
                if accepted.get(s, 0) >= i:
                    mask[s] = True
            if mask.any():
                new = self._select_rows(mask, snap, new)
        self.cache = new
        for s in active:
            if s in accepted:
                self.pos[s] += accepted[s] + 1

    def describe(self) -> dict:
        return {"kind": "ModelDraft",
                "arch": getattr(self.model.cfg, "name", None),
                "draft_calls": self.draft_calls}


def make_proposer(spec, *, slots: int, max_seq: int,
                  draft_model=None, draft_params=None) -> Proposer:
    """Build the proposer a :class:`~repro.runtime.SpeculativePolicy`
    asks for.  ``draft="model"`` needs the engine's ``draft_model`` /
    ``draft_params`` constructor arguments."""
    if spec.draft == "model":
        if draft_model is None or draft_params is None:
            raise ValueError(
                "SpeculativePolicy(draft='model') requires "
                "ServeEngine(draft_model=..., draft_params=...)")
        return ModelDraft(draft_model, draft_params,
                          slots=slots, max_seq=max_seq)
    return NGramProposer(spec.ngram)
