"""Pluggable serving schedulers: who is admitted next, who is evicted.

The :class:`Scheduler` protocol is the serving counterpart of the open
memory interface — admission order and preemption victims become a
swappable research policy rather than engine-internal control flow.  The
engine calls ``submit`` when a request arrives, ``pop`` when a cache slot
frees up, ``requeue`` when a request is preempted (block pool ran dry)
or could not be admitted, and ``choose_victim`` when an *active* slot
must be evicted to reclaim KV blocks.

All built-ins break ties by arrival order, so traces are deterministic.
"""

from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover
    from .engine import Request


@runtime_checkable
class Scheduler(Protocol):
    """Admission/preemption policy consumed by ``ServeEngine``."""

    name: str

    def submit(self, req: "Request") -> None:
        """A new request arrived."""

    def pop(self) -> "Request | None":
        """Next request to admit (None = queue empty)."""

    def requeue(self, req: "Request") -> None:
        """A preempted / unadmittable request returns to the queue."""

    def __len__(self) -> int:
        """Requests currently waiting."""

    def choose_victim(self, active: "dict[int, Request]") -> int:
        """Slot to evict when the block pool runs dry (``active`` maps
        slot -> request and is never empty here)."""


class FifoScheduler:
    """First-come-first-served; preempted requests return to the front
    (they arrived earliest among equals).  Victim: youngest admission —
    it has the least decode progress to throw away."""

    name = "fifo"

    def __init__(self):
        self._q: deque = deque()

    def submit(self, req) -> None:
        self._q.append(req)

    def pop(self):
        return self._q.popleft() if self._q else None

    def requeue(self, req) -> None:
        self._q.appendleft(req)

    def __len__(self) -> int:
        return len(self._q)

    def choose_victim(self, active) -> int:
        return max(active, key=lambda s: active[s].admit_seq)


class _HeapScheduler:
    """Shared heap machinery; subclasses define ``_key(req)``."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def _key(self, req):  # pragma: no cover - abstract
        raise NotImplementedError

    def submit(self, req) -> None:
        heapq.heappush(self._heap, (self._key(req), next(self._seq), req))

    def pop(self):
        return heapq.heappop(self._heap)[2] if self._heap else None

    # the key is recomputed, so a preempted request re-sorts with its
    # grown effective prompt (prompt + tokens generated so far)
    requeue = submit

    def __len__(self) -> int:
        return len(self._heap)

    def choose_victim(self, active) -> int:
        return max(active, key=lambda s: active[s].admit_seq)


class ShortestPromptScheduler(_HeapScheduler):
    """Shortest-prompt-first: minimizes mean time-to-first-token under
    mixed-length traffic (classic SJF, prompt length as the job size)."""

    name = "sjf"

    def _key(self, req):
        return len(req.prompt) + len(req.generated)


class PriorityScheduler(_HeapScheduler):
    """Priority/deadline admission: higher ``Request.priority`` first,
    earlier ``deadline`` breaks priority ties.  Victim: the least
    important active request (lowest priority, then latest deadline,
    then youngest admission)."""

    name = "priority"

    def _key(self, req):
        deadline = req.deadline if req.deadline is not None else math.inf
        return (-req.priority, deadline)

    def choose_victim(self, active) -> int:
        def badness(slot):
            r = active[slot]
            deadline = r.deadline if r.deadline is not None else math.inf
            return (-r.priority, deadline, r.admit_seq)

        return max(active, key=badness)


_REGISTRY = {cls.name: cls for cls in
             (FifoScheduler, ShortestPromptScheduler, PriorityScheduler)}
_REGISTRY["shortest"] = ShortestPromptScheduler
_REGISTRY["deadline"] = PriorityScheduler


def make_scheduler(spec) -> Scheduler:
    """Resolve a ``ServingPolicy.scheduler`` spec: a registry name, a
    Scheduler instance (passed through), or a Scheduler class."""
    if isinstance(spec, str):
        try:
            return _REGISTRY[spec]()
        except KeyError:
            raise ValueError(f"unknown scheduler {spec!r}; "
                             f"known: {sorted(_REGISTRY)}") from None
    if isinstance(spec, type):
        return spec()
    return spec
