"""Paged KV cache: fixed-size blocks + per-slot block tables.

Instead of statically reserving a dense ``[slots, max_seq]`` cache per
layer, global-attention layers share a pool of ``num_blocks`` fixed-size
blocks; each slot holds a *block table* mapping its logical cache
positions to physical blocks.  Mixed-length traffic then only pays for
the positions it actually fills, and the pool (not per-slot reservation)
caps concurrency.

Block allocation is delegated to the open memory interface
(``core/memory/manager.py``): the managers the paper studies on recorded
traces here drive a *live* serving workload — allocator policies
(caching vs bump, and their fragmentation stats) become swappable
serving experiments.

Static-shape discipline (TPU/jit): the pool has a fixed block count, the
table a fixed ``[slots, max_blocks]`` shape, and physical block 0 is a
reserved *trash* block — unmapped table entries point at it, so idle
slots' decode writes land harmlessly without dynamic shapes or masking
inside the jitted step.  Ring-buffer (sliding-window) layer caches are
already small and fixed per slot, so they stay dense.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory.manager import (BumpMemoryManager,
                                       CachingMemoryManager,
                                       MemoryManagerAdapter, OutOfMemory)

__all__ = ["BlockTable", "PagedKVCache", "OutOfMemory", "paged_block_bytes"]


@jax.tree_util.register_pytree_node_class
@dataclass
class BlockTable:
    """Device-side view of the per-slot block tables.

    ``table``: int32 ``[slots, max_blocks]`` physical block ids (0 = the
    reserved trash block).  ``block_size`` is static (pytree aux data),
    so it is a Python int inside jitted code.
    """

    table: Any
    block_size: int

    def tree_flatten(self):
        return (self.table,), self.block_size

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def paged_block_bytes(cfg, block_size: int) -> int:
    """Bytes one block occupies across every paged (global-attention)
    layer — the allocation unit handed to the memory manager."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    n_global = sum(1 for i in range(cfg.n_layers)
                   if cfg.layer_kind(i) == "A"
                   and cfg.window_for_layer(i) == 0)
    item = jnp.dtype(cfg.resolved_cache_dtype).itemsize
    per_pos = 2 * kv * hd * item                    # k + v
    if cfg.cache_dtype == "fp8":
        per_pos += 2 * kv * 4                       # float32 scales
    return max(1, n_global * per_pos * block_size)


class PagedKVCache:
    """Host-side block-table + pool manager for one ``ServeEngine``.

    The device pools live in ``self.pools`` (the model's paged cache
    pytree — per-layer ``[num_blocks * block_size, ...]`` arrays, shared
    across slots).  This object owns the host block tables and talks to
    the allocator; the jitted decode/prefill steps only ever see the
    pools plus a :class:`BlockTable` snapshot.
    """

    def __init__(self, model, *, slots: int, max_seq: int, block_size: int,
                 num_blocks: int | None = None,
                 manager: MemoryManagerAdapter | str | None = None):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.slots = slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.max_blocks = math.ceil(max_seq / block_size)
        if num_blocks is None:
            # roomy default: every slot can reach max_seq (+ trash block)
            num_blocks = slots * self.max_blocks + 1
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_bytes = paged_block_bytes(model.cfg, block_size)
        if manager is None or isinstance(manager, str):
            make = {None: CachingMemoryManager, "caching": CachingMemoryManager,
                    "bump": BumpMemoryManager}[manager]
            kw = {} if make is BumpMemoryManager else \
                {"round_to": self.block_bytes}
            manager = make(capacity=num_blocks * self.block_bytes, **kw)
        self.manager = manager
        self.pools = model.init_paged_cache(slots, max_seq,
                                            num_blocks=num_blocks,
                                            block_size=block_size)
        self.table = np.zeros((slots, self.max_blocks), np.int32)
        self._blocks: dict[int, list[tuple[int, int]]] = {}  # slot -> [(id, ptr)]
        # reserve physical block 0 as the trash block, never freed
        ptr0 = self.manager.alloc(self.block_bytes)
        if ptr0 // self.block_bytes != 0:
            raise ValueError(
                "paged KV cache needs a fresh block-aligned arena (the "
                "offset->block-id mapping requires every allocation to be "
                f"a block_bytes={self.block_bytes} multiple); got first "
                f"offset {ptr0}")

    # -- capacity ------------------------------------------------------------
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1          # minus the trash block

    @property
    def blocks_in_use(self) -> int:
        return sum(len(v) for v in self._blocks.values())

    def blocks_for(self, pos: int) -> int:
        """Blocks a slot needs so position ``pos`` is writable."""
        return pos // self.block_size + 1

    # -- slot lifecycle ------------------------------------------------------
    def ensure(self, slot: int, pos: int) -> None:
        """Map enough blocks that ``pos`` is writable for ``slot``.

        Raises :class:`OutOfMemory` when the allocator cannot satisfy the
        growth — the engine's preemption trigger.
        """
        need = self.blocks_for(pos)
        if need > self.max_blocks:
            raise OutOfMemory(
                f"position {pos} exceeds max_seq={self.max_seq} "
                f"({self.max_blocks} blocks/slot)")
        held = self._blocks.setdefault(slot, [])
        while len(held) < need:
            ptr = self.manager.alloc(self.block_bytes)
            bid = ptr // self.block_bytes
            self.table[slot, len(held)] = bid
            held.append((bid, ptr))

    def release(self, slot: int) -> None:
        """Free every block a slot holds (request finished or evicted)."""
        for _bid, ptr in self._blocks.pop(slot, []):
            self.manager.unlock(ptr)
        self.table[slot] = 0

    # -- static audit --------------------------------------------------------
    def snapshot(self):
        """Immutable :class:`repro.analysis.CacheSnapshot` of the block
        table, held-block map, and allocator live set — the input the
        static serving checker reasons over."""
        from repro.analysis.serving import snapshot_cache

        return snapshot_cache(self)

    def audit(self):
        """Run :func:`repro.analysis.check_paged_cache` over the current
        state; returns the :class:`~repro.analysis.DiagnosticReport`
        (leaks, double-frees, double-maps, trash-block violations,
        table/held divergence)."""
        from repro.analysis.serving import check_paged_cache

        return check_paged_cache(self.snapshot(), where="PagedKVCache")

    # -- device views --------------------------------------------------------
    def device_table(self) -> BlockTable:
        return BlockTable(jnp.asarray(self.table), self.block_size)

    def describe(self) -> dict:
        s = self.manager.stats
        return {"block_size": self.block_size,
                "num_blocks": self.num_blocks,
                "max_blocks_per_slot": self.max_blocks,
                "block_bytes": self.block_bytes,
                "blocks_in_use": self.blocks_in_use,
                "manager": type(self.manager).__name__,
                "device_allocs": s.n_device_allocs,
                "internal_fragmentation": s.internal_fragmentation}
