"""Paged KV cache: fixed-size blocks + per-slot block tables.

Instead of statically reserving a dense ``[slots, max_seq]`` cache per
layer, global-attention layers share a pool of ``num_blocks`` fixed-size
blocks; each slot holds a *block table* mapping its logical cache
positions to physical blocks.  Mixed-length traffic then only pays for
the positions it actually fills, and the pool (not per-slot reservation)
caps concurrency.

Block allocation is delegated to the open memory interface
(``core/memory/manager.py``): the managers the paper studies on recorded
traces here drive a *live* serving workload — allocator policies
(caching vs bump, and their fragmentation stats) become swappable
serving experiments.

Static-shape discipline (TPU/jit): the pool has a fixed block count, the
table a fixed ``[slots, max_blocks]`` shape, and physical block 0 is a
reserved *trash* block — unmapped table entries point at it, so idle
slots' decode writes land harmlessly without dynamic shapes or masking
inside the jitted step.  Ring-buffer (sliding-window) layer caches are
already small and fixed per slot, so they stay dense.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.memory import telemetry
from repro.core.memory.manager import (BumpMemoryManager,
                                       CachingMemoryManager,
                                       MemoryManagerAdapter, OutOfMemory)

from .prefix import PrefixIndex, PrefixNode

__all__ = ["BlockTable", "PagedKVCache", "OutOfMemory", "paged_block_bytes"]


@jax.tree_util.register_pytree_node_class
@dataclass
class BlockTable:
    """Device-side view of the per-slot block tables.

    ``table``: int32 ``[slots, max_blocks]`` physical block ids (0 = the
    reserved trash block).  ``block_size`` is static (pytree aux data),
    so it is a Python int inside jitted code.
    """

    table: Any
    block_size: int

    def tree_flatten(self):
        return (self.table,), self.block_size

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def paged_block_bytes(cfg, block_size: int) -> int:
    """Bytes one block occupies across every paged (global-attention)
    layer — the allocation unit handed to the memory manager."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    n_global = sum(1 for i in range(cfg.n_layers)
                   if cfg.layer_kind(i) == "A"
                   and cfg.window_for_layer(i) == 0)
    item = jnp.dtype(cfg.resolved_cache_dtype).itemsize
    per_pos = 2 * kv * hd * item                    # k + v
    if cfg.cache_dtype == "fp8":
        per_pos += 2 * kv * 4                       # float32 scales
    return max(1, n_global * per_pos * block_size)


class PagedKVCache:
    """Host-side block-table + pool manager for one ``ServeEngine``.

    The device pools live in ``self.pools`` (the model's paged cache
    pytree — per-layer ``[num_blocks * block_size, ...]`` arrays, shared
    across slots).  This object owns the host block tables and talks to
    the allocator; the jitted decode/prefill steps only ever see the
    pools plus a :class:`BlockTable` snapshot.
    """

    def __init__(self, model, *, slots: int, max_seq: int, block_size: int,
                 num_blocks: int | None = None,
                 manager: MemoryManagerAdapter | str | None = None,
                 prefix=None):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.model = model
        self.slots = slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.max_blocks = math.ceil(max_seq / block_size)
        if num_blocks is None:
            # roomy default: every slot can reach max_seq (+ trash block)
            num_blocks = slots * self.max_blocks + 1
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_bytes = paged_block_bytes(model.cfg, block_size)
        if manager is None or isinstance(manager, str):
            make = {None: CachingMemoryManager, "caching": CachingMemoryManager,
                    "bump": BumpMemoryManager}[manager]
            kw = {} if make is BumpMemoryManager else \
                {"round_to": self.block_bytes}
            manager = make(capacity=num_blocks * self.block_bytes, **kw)
        self.manager = manager
        self.pools = model.init_paged_cache(slots, max_seq,
                                            num_blocks=num_blocks,
                                            block_size=block_size)
        self.table = np.zeros((slots, self.max_blocks), np.int32)
        self._blocks: dict[int, list[tuple[int, int]]] = {}  # slot -> [(id, ptr)]
        # -- prefix sharing (optional) ---------------------------------------
        # refcount[bid] = slot mappings + (1 if the radix tree holds it);
        # blocks return to the allocator only when the last sharer lets go.
        self.prefix = prefix                      # PrefixPolicy | None
        self.prefix_index = (PrefixIndex(block_size)
                             if prefix is not None else None)
        self.refcount: dict[int, int] = {}
        self._shared_len: dict[int, int] = {}     # slot -> matched positions
        # slot -> (lo, hi) of the most recent prepared write range; the
        # audit re-checks exactly this range against the refcounts (a
        # *past* write into a block that became shared afterwards — the
        # registrant's own prefill — is fine)
        self._prepared: dict[int, tuple[int, int]] = {}
        self._pending: dict[int, list[PrefixNode]] = {}  # pre-ready nodes
        # -- speculative decoding / forking ----------------------------------
        # committed[slot] = positions whose KV content is final; anything a
        # slot holds past blocks_for(committed-1) that is not covered by a
        # declared write intent (_prepared) is rollback debris the audit
        # flags (kv.rollback-dangling).  Only speculative engines maintain
        # this — plain decode never rolls back, so the map stays empty.
        self._committed: dict[int, int] = {}
        self._forks: dict[int, int] = {}          # child slot -> parent slot
        self.rollback_blocks_freed = 0
        self.forks = 0
        self._leaf_axes_cache: list[int | None] | None = None
        self.cow_copies = 0
        # ambient tracer at construction (the engine builds its cache
        # inside the serving session); None = observability off
        self._obs = obs.get_tracer()
        # reserve physical block 0 as the trash block, never freed
        ptr0 = self.manager.alloc(self.block_bytes)
        if ptr0 // self.block_bytes != 0:
            raise ValueError(
                "paged KV cache needs a fresh block-aligned arena (the "
                "offset->block-id mapping requires every allocation to be "
                f"a block_bytes={self.block_bytes} multiple); got first "
                f"offset {ptr0}")

    # -- capacity ------------------------------------------------------------
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1          # minus the trash block

    @property
    def blocks_in_use(self) -> int:
        return sum(len(v) for v in self._blocks.values())

    def blocks_for(self, pos: int) -> int:
        """Blocks a slot needs so position ``pos`` is writable."""
        return pos // self.block_size + 1

    # -- refcounted allocation ----------------------------------------------
    def _alloc_block(self) -> tuple[int, int]:
        """One fresh block (refcount 1); under pool pressure, reclaim
        LRU tree-only prefix blocks before giving up with OutOfMemory."""
        while True:
            try:
                ptr = self.manager.alloc(self.block_bytes)
                break
            except OutOfMemory:
                if not self._evict_prefix(1):
                    raise
        bid = ptr // self.block_bytes
        self.refcount[bid] = 1
        # bridge into the allocation-telemetry stream (negative uid
        # namespace: KV block ids must not collide with LazyTensor uids
        # in a recording that spans both sources)
        telemetry.record_alloc(-(bid + 1), self.block_bytes, tag="kv.block")
        return bid, ptr

    def _decref(self, bid: int) -> None:
        c = self.refcount.get(bid, 0) - 1
        if c > 0:
            self.refcount[bid] = c
        else:
            self.refcount.pop(bid, None)
            self.manager.unlock(bid * self.block_bytes)
            telemetry.record_free(-(bid + 1))

    def _evict_prefix(self, n: int) -> bool:
        """Drop up to ``n`` LRU radix leaves nobody maps (refcount 1 =
        tree-only) and return their blocks to the allocator."""
        if self.prefix_index is None:
            return False
        freed = self.prefix_index.evict(
            lambda b: self.refcount.get(b, 0) == 1, limit=n)
        for bid in freed:
            self._decref(bid)
        return bool(freed)

    # -- slot lifecycle ------------------------------------------------------
    def ensure(self, slot: int, pos: int) -> None:
        """Map enough blocks that ``pos`` is writable for ``slot``.

        Raises :class:`OutOfMemory` when the allocator cannot satisfy the
        growth — the engine's preemption trigger.
        """
        need = self.blocks_for(pos)
        if need > self.max_blocks:
            raise OutOfMemory(
                f"position {pos} exceeds max_seq={self.max_seq} "
                f"({self.max_blocks} blocks/slot)")
        held = self._blocks.setdefault(slot, [])
        if len(held) >= need:
            return
        if self._obs is None:
            self._grow(slot, held, need)
        else:
            with self._obs.span("kv.grow", "memory", slot=slot,
                                blocks=need - len(held)):
                self._grow(slot, held, need)

    def _grow(self, slot: int, held: list[tuple[int, int]],
              need: int) -> None:
        while len(held) < need:
            bid, ptr = self._alloc_block()
            self.table[slot, len(held)] = bid
            held.append((bid, ptr))

    def release(self, slot: int) -> None:
        """Drop every reference a slot holds (finished or evicted).

        Shared blocks only decref — the block stays live for its other
        sharers (tree included) and reaches the allocator when the last
        one lets go.  Registrations that never became ready (the owner
        was evicted before its prefill round completed) are unlinked so
        no future admission can match garbage content.
        """
        for node in reversed(self._pending.pop(slot, [])):
            if node.parent is not None and not node.children:
                self.prefix_index.remove(node)
                self._decref(node.block)
        for bid, _ptr in self._blocks.pop(slot, []):
            self._decref(bid)
        self.table[slot] = 0
        self._shared_len.pop(slot, None)
        self._prepared.pop(slot, None)
        self._committed.pop(slot, None)
        self._forks.pop(slot, None)
        # a released parent orphans its children: they own their blocks
        # (refcounted) and stop being audited as forks of a dead slot
        for child, parent in list(self._forks.items()):
            if parent == slot:
                del self._forks[child]
        if self.prefix_index is not None and not self.prefix.retain:
            for bid in self.prefix_index.sweep(
                    lambda b: self.refcount.get(b, 0) == 1):
                self._decref(bid)

    # -- speculative rollback / forking --------------------------------------
    def set_committed(self, slot: int, n: int) -> None:
        """Record that positions ``[0, n)`` hold final KV content for
        ``slot`` (speculative engines call this at admission and after
        every verify round; the rollback audit keys off it)."""
        self._committed[slot] = n

    def begin_write(self, slot: int, lo: int, hi: int) -> None:
        """Declare an upcoming write to positions ``[lo, hi]`` *before*
        growing the mapping — so an audit triggered mid-growth (a
        preemption freeing room for this very span) sees the extra
        blocks as intended, not as rollback debris."""
        self._prepared[slot] = (lo, hi)

    def rollback(self, slot: int, new_len: int) -> int:
        """Truncate ``slot`` to ``new_len`` committed positions.

        The speculative-decoding rejection path: verify wrote K/V for
        the whole proposed span, acceptance kept a prefix, and the
        surplus *blocks* return to the memory manager (refcount-aware —
        a block other sharers or the radix tree still reference only
        decrefs).  Positions inside the last kept block need no cleanup:
        the decode validity mask hides them and future writes overwrite
        them.  Returns the number of block references dropped.
        """
        held = self._blocks.get(slot, [])
        keep = 0 if new_len <= 0 else (new_len - 1) // self.block_size + 1
        freed = 0
        while len(held) > keep:
            bid, _ptr = held.pop()
            self.table[slot, len(held)] = 0
            self._decref(bid)
            freed += 1
        self._committed[slot] = new_len
        if slot in self._prepared:
            lo, hi = self._prepared[slot]
            if lo >= new_len:
                del self._prepared[slot]
            elif hi >= new_len:
                self._prepared[slot] = (lo, new_len - 1)
        self.rollback_blocks_freed += freed
        return freed

    def fork(self, src: int, dst: int) -> None:
        """Clone ``src``'s block table into pristine slot ``dst``.

        Every mapped block gains a reference; nothing is copied — the
        first divergent write through :meth:`prepare_write` triggers
        copy-on-write for whichever sequence writes first.  This is the
        beam-search primitive: a fork costs O(blocks) refcount bumps.
        """
        if self._blocks.get(dst):
            raise ValueError(f"fork() into non-empty slot {dst}")
        held = self._blocks.get(src, [])
        self._blocks[dst] = list(held)
        for bid, _ptr in held:
            self.refcount[bid] = self.refcount.get(bid, 0) + 1
        self.table[dst] = 0
        self.table[dst, :len(held)] = self.table[src, :len(held)]
        # the child inherits the parent's shared-prefix semantics: any
        # write past it into a still-shared block must COW
        self._shared_len[dst] = self._shared_len.get(src, 0)
        if src in self._committed:
            self._committed[dst] = self._committed[src]
        self._forks[dst] = src
        self.forks += 1

    # -- prefix sharing ------------------------------------------------------
    def admit(self, slot: int, tokens: list[int]) -> int:
        """Map the longest cached prefix of ``tokens`` into ``slot``.

        Walks the radix tree, increfs every matched block, and installs
        it in the slot's table; returns the number of leading positions
        already cached (the engine skips their prefill).  Call before
        :meth:`ensure` — the private tail extends past the shared head.
        """
        if self.prefix_index is None:
            return 0
        held = self._blocks.setdefault(slot, [])
        if held:
            raise ValueError(f"admit() into non-empty slot {slot}")
        nodes, matched = self.prefix_index.match(
            tokens, partial=self.prefix.partial)
        for j, node in enumerate(nodes):
            self.refcount[node.block] += 1
            self.table[slot, j] = node.block
            held.append((node.block, node.block * self.block_bytes))
        self._shared_len[slot] = matched
        return matched

    def register(self, slot: int, tokens: list[int]) -> None:
        """Publish the slot's full blocks of ``tokens`` (the prefill
        extent) into the radix tree so later admissions can share them.

        First registrant of a span wins; spans already in the tree are
        skipped (this slot's block for them is either the shared block
        itself or a private duplicate that stays private).  New nodes
        start non-ready — call :meth:`mark_ready` once the prefill round
        has actually materialized their content on device.
        """
        if self.prefix_index is None:
            return
        held = self._blocks.get(slot, ())
        nfull = min(len(tokens) // self.block_size, len(held))
        if not nfull:
            return
        created = self.prefix_index.insert(
            tokens, [held[j][0] for j in range(nfull)])
        for node in created:
            self.refcount[node.block] += 1
        if created:
            self._pending.setdefault(slot, []).extend(created)

    def mark_ready(self, slot: int) -> None:
        """Flip the slot's pending registrations to ready (their prefill
        round ran; partial-match COW may now copy out of them)."""
        for node in self._pending.pop(slot, []):
            if node.parent is not None:
                node.ready = True

    def prepare_write(self, slot: int, lo: int, hi: int, pools):
        """Copy-on-write barrier for writes to positions ``[lo, hi]``.

        Positions below the slot's shared prefix length are idempotent
        rewrites of identical values (KV at position p is a function of
        the matched token prefix) and stay shared; a *divergent* write
        (pos >= shared_len) into a block with other sharers gets a
        private copy first.  Takes and returns the live device pools —
        the engine's ``self.cache``, not the construction-time
        ``self.pools`` — so the copy reads current data.
        """
        self._prepared[slot] = (lo, hi)
        held = self._blocks.get(slot)
        shared = self._shared_len.get(slot, 0)
        if held and hi >= shared:
            for j in range(max(lo, shared) // self.block_size,
                           min(hi // self.block_size, len(held) - 1) + 1):
                bid = held[j][0]
                if self.refcount.get(bid, 0) <= 1:
                    continue
                nbid, nptr = self._alloc_block()
                pools = self._copy_block(pools, src=bid, dst=nbid)
                self.cow_copies += 1
                held[j] = (nbid, nptr)
                self.table[slot, j] = nbid
                self._decref(bid)
        self.pools = pools
        return pools

    def clear_prefix(self) -> int:
        """Drop every tree-only cached prefix block; returns the count
        of blocks returned to the allocator."""
        if self.prefix_index is None:
            return 0
        freed = self.prefix_index.sweep(
            lambda b: self.refcount.get(b, 0) == 1)
        for bid in freed:
            self._decref(bid)
        return len(freed)

    def shared_len(self, slot: int) -> int:
        return self._shared_len.get(slot, 0)

    # -- copy-on-write device copy -------------------------------------------
    def _leaf_axes(self) -> list:
        """Per-pool-leaf block-pool axis (None = dense ring/window leaf).

        Derived structurally: a paged leaf's shape is the dense leaf's
        shape with the (batch, seq) pair replaced by the pool dimension
        ``num_blocks * block_size`` at axis 0 (unstacked layer) or axis
        1 (scan-stacked layers prepend a layer axis).
        """
        if self._leaf_axes_cache is not None:
            return self._leaf_axes_cache
        dense = jax.tree_util.tree_leaves(
            self.model.cache_spec(self.slots, self.max_seq))
        paged = jax.tree_util.tree_leaves(
            self.model.paged_cache_spec(self.slots, self.max_seq,
                                        num_blocks=self.num_blocks,
                                        block_size=self.block_size))
        p = self.num_blocks * self.block_size
        axes: list[int | None] = []
        for dm, pm in zip(dense, paged):
            ds, ps = tuple(dm.shape), tuple(pm.shape)
            if ds == ps:
                axes.append(None)
                continue
            hits = [k for k in (0, 1)
                    if len(ds) >= k + 2
                    and ps == ds[:k] + (p,) + ds[k + 2:]]
            if len(hits) != 1:
                raise ValueError(
                    f"cannot identify pool axis for paged leaf {ps} vs "
                    f"dense {ds} (pool={p}); candidates: {hits}")
            axes.append(hits[0])
        self._leaf_axes_cache = axes
        return axes

    def _copy_block(self, pools, *, src: int, dst: int):
        """Device-copy one physical block's rows across every paged
        pool leaf (the COW body)."""
        leaves, treedef = jax.tree_util.tree_flatten(pools)
        bs = self.block_size
        out = []
        for leaf, ax in zip(leaves, self._leaf_axes()):
            if ax is None:
                out.append(leaf)
                continue
            row = jax.lax.dynamic_slice_in_dim(leaf, src * bs, bs, axis=ax)
            out.append(jax.lax.dynamic_update_slice_in_dim(
                leaf, row, dst * bs, axis=ax))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- static audit --------------------------------------------------------
    def snapshot(self):
        """Immutable :class:`repro.analysis.CacheSnapshot` of the block
        table, held-block map, and allocator live set — the input the
        static serving checker reasons over."""
        from repro.analysis.serving import snapshot_cache

        return snapshot_cache(self)

    def audit(self):
        """Run :func:`repro.analysis.check_paged_cache` over the current
        state; returns the :class:`~repro.analysis.DiagnosticReport`
        (leaks, double-frees, double-maps, trash-block violations,
        table/held divergence)."""
        from repro.analysis.serving import check_paged_cache

        return check_paged_cache(self.snapshot(), where="PagedKVCache")

    # -- device views --------------------------------------------------------
    def device_table(self) -> BlockTable:
        return BlockTable(jnp.asarray(self.table), self.block_size)

    def describe(self) -> dict:
        s = self.manager.stats
        d = {"block_size": self.block_size,
             "num_blocks": self.num_blocks,
             "max_blocks_per_slot": self.max_blocks,
             "block_bytes": self.block_bytes,
             "blocks_in_use": self.blocks_in_use,
             "manager": type(self.manager).__name__,
             "device_allocs": s.n_device_allocs,
             "internal_fragmentation": s.internal_fragmentation,
             "rollback_blocks_freed": self.rollback_blocks_freed,
             "forks": self.forks}
        if self.prefix_index is not None:
            d["prefix"] = {**self.prefix_index.describe(),
                           "cow_copies": self.cow_copies,
                           "shared_blocks": sum(
                               1 for c in self.refcount.values() if c > 1)}
        return d
