"""Content-addressed prefix index for the paged KV cache.

A radix tree at *block* granularity: each node owns one physical pool
block and is keyed by the exact ``block_size``-token span it caches, so
a path from the root spells out a token prefix whose KV is already on
device.  Admissions walk the tree with their prompt and map every node
they match straight into their block table instead of re-allocating and
re-prefilling — N requests with a shared system prompt pay prefill once.

Sharing is refcount-based (the :class:`PagedKVCache` owns the counts):
a tree reference and each slot mapping contribute one reference each, so
a block is only returned to the memory manager when the last sharer —
tree included — lets go.  Divergent writes into a block with more than
one reference are copy-on-write (``PagedKVCache.prepare_write``).

Two-phase visibility: nodes are inserted at admission but start
``ready=False`` — their content only exists on device once the owner's
prefill round runs.  Full-block matches against non-ready nodes are safe
*within one admission round* (the joint chunked prefill writes chunk
``c`` for every admitted slot before any slot reads it), so same-round
admissions still share; *partial*-block matches copy data out of the
block (COW) and therefore require ``ready``.
"""

from __future__ import annotations

from typing import Callable, Iterator, Sequence

__all__ = ["PrefixNode", "PrefixIndex"]


class PrefixNode:
    """One cached block: ``tokens`` (the exact span) -> physical block."""

    __slots__ = ("tokens", "block", "children", "parent", "ready",
                 "last_used")

    def __init__(self, tokens: tuple[int, ...], block: int,
                 parent: "PrefixNode | None", *, ready: bool,
                 last_used: int = 0):
        self.tokens = tokens
        self.block = block
        self.parent = parent
        self.children: dict[tuple[int, ...], PrefixNode] = {}
        self.ready = ready
        self.last_used = last_used

    @property
    def depth(self) -> int:
        d, node = 0, self.parent
        while node is not None:
            d, node = d + 1, node.parent
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PrefixNode(block={self.block}, ready={self.ready}, "
                f"tokens={self.tokens!r})")


def _overlap(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixIndex:
    """Block-granularity radix tree over cached token prefixes."""

    def __init__(self, block_size: int):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.block_size = block_size
        self.root = PrefixNode((), -1, None, ready=True)
        self._clock = 0
        # counters (surfaced through PagedKVCache.describe())
        self.hits = 0
        self.hit_tokens = 0
        self.evictions = 0

    # -- internals -----------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _walk(self) -> Iterator[PrefixNode]:
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    # -- queries -------------------------------------------------------------
    def match(self, tokens: Sequence[int], *, partial: bool = True,
              touch: bool = True) -> tuple[list[PrefixNode], int]:
        """Longest cached prefix of ``tokens``.

        Returns ``(nodes, matched)``: the node path (full-block matches,
        optionally ending in one *partially* matching ready node) and
        the number of leading tokens it covers (``matched <=
        len(tokens)``).  ``touch=False`` peeks without bumping LRU
        clocks or hit counters (router affinity probing).
        """
        bs = self.block_size
        node, nodes, i = self.root, [], 0
        while i + bs <= len(tokens):
            child = node.children.get(tuple(tokens[i:i + bs]))
            if child is None:
                break
            nodes.append(child)
            node, i = child, i + bs
        matched = i
        if partial and i < len(tokens):
            rest = tuple(tokens[i:])
            best, best_ov = None, 0
            for child in node.children.values():
                if not child.ready:
                    continue        # partial matches copy data out (COW)
                ov = _overlap(child.tokens, rest)
                if ov > best_ov:
                    best, best_ov = child, ov
            if best is not None:
                nodes.append(best)
                matched += best_ov
        if touch and nodes:
            clk = self._tick()
            for nd in nodes:
                nd.last_used = clk
            self.hits += 1
            self.hit_tokens += matched
        return nodes, matched

    def match_len(self, tokens: Sequence[int]) -> int:
        """Peek the cached-prefix length without touching LRU state."""
        return self.match(tokens, touch=False)[1]

    # -- mutation ------------------------------------------------------------
    def insert(self, tokens: Sequence[int],
               blocks: Sequence[int]) -> list[PrefixNode]:
        """Register the full blocks of ``tokens`` (``blocks[j]`` caches
        span ``tokens[j*bs:(j+1)*bs]``) as non-ready nodes.

        Walks existing nodes (first registrant of a span wins; a later
        slot's private block for the same span stays private) and
        creates the rest.  Returns only the *newly created* nodes — the
        caller increfs their blocks and flips ``ready`` after prefill.
        """
        bs = self.block_size
        if len(blocks) * bs > len(tokens):
            raise ValueError("insert needs block_size tokens per block")
        node, created = self.root, []
        clk = self._tick()
        for j, bid in enumerate(blocks):
            key = tuple(tokens[j * bs:(j + 1) * bs])
            child = node.children.get(key)
            if child is None:
                child = PrefixNode(key, bid, node, ready=False,
                                   last_used=clk)
                node.children[key] = child
                created.append(child)
            else:
                child.last_used = clk
            node = child
        return created

    def remove(self, node: PrefixNode) -> None:
        """Detach one node (must be childless) from the tree."""
        if node.children:
            raise ValueError(f"cannot remove non-leaf prefix node {node!r}")
        parent = node.parent
        if parent is not None and parent.children.get(node.tokens) is node:
            del parent.children[node.tokens]
        node.parent = None

    def evict(self, is_evictable: Callable[[int], bool],
              limit: int = 1) -> list[int]:
        """Drop up to ``limit`` least-recently-used *ready leaves* whose
        block passes ``is_evictable`` (refcount == 1, i.e. tree-only).
        Returns the freed block ids; the cache unlocks them."""
        freed: list[int] = []
        while len(freed) < limit:
            leaves = [n for n in self._walk()
                      if not n.children and n.ready and is_evictable(n.block)]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: (n.last_used, n.block))
            self.remove(victim)
            freed.append(victim.block)
            self.evictions += 1
        return freed

    def sweep(self, is_evictable: Callable[[int], bool]) -> list[int]:
        """Drop *every* evictable ready leaf, cascading up the tree
        (``retain=False`` release path / ``clear``)."""
        freed: list[int] = []
        while True:
            batch = self.evict(is_evictable, limit=len(self) + 1)
            if not batch:
                return freed
            freed.extend(batch)

    # -- introspection -------------------------------------------------------
    def blocks(self) -> frozenset[int]:
        return frozenset(n.block for n in self._walk())

    def __len__(self) -> int:
        return sum(1 for _ in self._walk())

    def describe(self) -> dict:
        return {"nodes": len(self), "hits": self.hits,
                "hit_tokens": self.hit_tokens, "evictions": self.evictions}
