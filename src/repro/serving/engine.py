"""Batched serving engine: continuous batching over a static slot pool,
with a paged KV-cache runtime behind it.

Requests join a pluggable :mod:`scheduler <repro.serving.scheduler>`;
free cache slots are assigned per step in ascending order (deterministic
traces, static shapes — TPU-friendly).  Admission runs *chunked batched
prefill*: one jitted ``prefill_step`` call consumes a whole chunk of
prompt tokens for every newly admitted slot at once, so a length-L
prompt costs O(L / chunk) compiled calls instead of the O(L) one-token
decodes of the legacy path (kept as a fallback for MLA models, or
``prefill_chunk=0``).  All active slots then advance one token per
``decode`` call at their *own* position.

Cache layouts (``ServingPolicy.cache``):

* ``"dense"`` — every slot statically reserves ``max_seq`` positions
  per layer (the compatibility path).
* ``"paged"`` — global-attention layers share a fixed pool of
  fixed-size blocks mapped through per-slot block tables
  (:class:`~repro.serving.kv_cache.PagedKVCache`); block allocation is
  delegated to the ``core/memory/manager.py`` allocator policies.  When
  the pool runs dry, the scheduler picks a victim to evict — its blocks
  are freed and the request is requeued (recomputed on re-admission).

The engine reads its scoped configuration from the unified runtime
Session (kernel overrides, and ``Session.serving`` for the default
``ServingPolicy``); the session is snapshotted at construction so
``engine.session.describe()`` records the serving scenario's provenance.

Models whose layers carry SSM recurrent state (mamba/jamba families)
are rejected at construction: staggered per-slot admission advances the
shared recurrence at the wrong times and silently corrupts every other
in-flight sequence — they need batch-level bulk prefill, which this
slot-granular engine does not do.
"""

from __future__ import annotations

import warnings
from contextlib import nullcontext as _nullcontext
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.runtime import ServingPolicy, current_session
from repro.runtime import stack as _rt

from .kv_cache import OutOfMemory, PagedKVCache
from .scheduler import make_scheduler


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    priority: int = 0                 # higher = more important (scheduler)
    deadline: float | None = None     # smaller = more urgent (scheduler)
    generated: list[int] = field(default_factory=list)
    done: bool = False
    # engine-maintained bookkeeping (monotonic repro.obs.now timestamps)
    submit_time: float = 0.0
    first_token_time: float | None = None
    token_times: list[float] = field(default_factory=list)
    admit_seq: int = -1               # admission order (victim selection)
    preemptions: int = 0


class ServeEngine:
    def __init__(self, model, params, *, batch_slots: int, max_seq: int,
                 policy: ServingPolicy | None = None, attend_fn=None,
                 draft_model=None, draft_params=None, proposer=None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.session = current_session()
        self.policy = policy if policy is not None else self.session.serving
        if self.policy is not self.session.serving:
            # describe() must record the scenario that actually runs
            self.session = self.session.replace(serving=self.policy)
        if attend_fn is not None:
            warnings.warn(
                "ServeEngine(attend_fn=...) is deprecated; construct the "
                "engine inside repro.session(kernels={'decode_attention': "
                "fn}) instead", DeprecationWarning, stacklevel=2)
        self.attend_fn = attend_fn or self.session.kernels.decode_attention

        # SSM-family caches are recurrent state, not position-addressed:
        # a prefill loop advances EVERY slot's recurrence, so staggered
        # (mid-flight) admission silently corrupts other sequences, and a
        # recycled slot inherits its previous occupant's state.  Allow
        # only the safe case (one pristine-slot admission into an
        # otherwise-idle engine) and raise loudly on the rest.
        self._recurrent = getattr(model, "has_recurrent_state",
                                  lambda: False)()
        self._slots_used: set[int] = set()

        self.paged = self.policy.cache == "paged"
        if self.policy.cache not in ("dense", "paged"):
            raise ValueError(f"unknown cache layout {self.policy.cache!r}")
        if self.paged:
            if not getattr(model, "supports_paged_cache", lambda: False)():
                raise ValueError(
                    "this model does not support the paged KV cache "
                    "(MLA latent caches are dense-only for now); use "
                    "ServingPolicy(cache='dense')")
            # prefix sharing needs chunked prefill (the skip is
            # chunk-aligned) and a model with no window layers; anything
            # else silently degrades to private blocks (shared_len=0)
            # so the policy stays safe to enable globally.
            self.prefix_on = (
                self.policy.prefix.enabled
                and self.policy.prefill_chunk > 0
                and getattr(model, "supports_prefix_sharing",
                            lambda: False)())
            self.kv = PagedKVCache(model, slots=batch_slots, max_seq=max_seq,
                                   block_size=self.policy.block_size,
                                   num_blocks=self.policy.num_blocks,
                                   manager=self.policy.allocator,
                                   prefix=(self.policy.prefix
                                           if self.prefix_on else None))
            self.cache = self.kv.pools
        else:
            self.prefix_on = False
            self.kv = None
            self.cache = model.init_cache(batch_slots, max_seq)

        self._chunked = (self.policy.prefill_chunk > 0 and getattr(
            model, "supports_chunked_prefill", lambda: False)())
        # speculative decoding needs the paged cache (rollback is block-
        # table truncation) on a model whose layers are all position-
        # addressed (no ring buffers); anything else silently degrades
        # to plain one-token decode so the policy is safe globally.
        spec = self.policy.speculative
        self.spec_on = (spec.enabled and self.paged
                        and getattr(model, "supports_speculative",
                                    lambda: False)())
        self.proposer = None
        if self.spec_on:
            from .speculative import make_proposer
            self.proposer = (proposer if proposer is not None else
                             make_proposer(spec, slots=batch_slots,
                                           max_seq=max_seq,
                                           draft_model=draft_model,
                                           draft_params=draft_params))
        self.scheduler = make_scheduler(self.policy.scheduler)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn) if self._chunked else None
        self._verify = jax.jit(self._verify_fn) if self.spec_on else None
        self._decode_logits = jax.jit(self._decode_logits_fn)
        self.active: dict[int, Request] = {}     # slot -> request
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.slot_tok = np.zeros((batch_slots, 1), np.int32)
        self.steps = 0
        self.decode_calls = 0
        self.prefill_calls = 0
        self.preemptions = 0
        self.prefill_tokens_saved = 0
        self.shared_admissions = 0
        self._admit_counter = 0
        # speculative / beam bookkeeping
        self.spec_rounds = 0
        self.slot_rounds = 0     # (slot, round) verify instances
        self.verify_calls = 0
        self.accepted_tokens = 0
        self.rejected_tokens = 0
        self.fork_counts: dict[int, int] = {}    # slot -> forks taken
        # observability: the pinned session's tracer, or None (the off
        # path is this one attribute check per site)
        self._obs = obs.get_tracer(self.session)
        if self._obs is not None:
            m = self._obs.metrics
            self._h_ttft = m.histogram("serving.ttft_s")
            self._h_itl = m.histogram("serving.inter_token_s")
            self._g_free = m.gauge("kv.free_blocks")
            self._g_cow = m.gauge("kv.cow_copies")
            self._g_prefix = m.gauge("kv.prefix_hits")
            self._gauge_vals: tuple | None = None

    # -- jitted bodies -------------------------------------------------------
    def _decode_fn(self, params, cache, tok, pos, block_table):
        # pin the construction-time session during tracing: whatever is
        # ambient when jit first traces must not leak into the compiled
        # decode (describe() provenance has to match actual behavior)
        with _rt.session(self.session):
            if block_table is None:
                logits, cache = self.model.decode_step(
                    params, cache, tok, pos, attend_fn=self.attend_fn)
            else:
                logits, cache = self.model.decode_step(
                    params, cache, tok, pos, attend_fn=self.attend_fn,
                    block_table=block_table)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    def _prefill_fn(self, params, cache, toks, start, count, block_table):
        with _rt.session(self.session):
            return self.model.prefill_step(params, cache, toks, start,
                                           count, block_table=block_table)

    def _verify_fn(self, params, cache, toks, start, count, block_table):
        # wide verify: per-slot [start, start+count) token spans written
        # through the chunked-prefill path, greedy targets for every
        # position argmaxed on device
        with _rt.session(self.session):
            logits, cache = self.model.verify_step(
                params, cache, toks, start, count, block_table=block_table)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, cache

    def _decode_logits_fn(self, params, cache, tok, pos, block_table):
        # beam-search body: like _decode_fn but returns full next-token
        # log-probs so the caller can expand/score hypotheses
        with _rt.session(self.session):
            if block_table is None:
                logits, cache = self.model.decode_step(
                    params, cache, tok, pos, attend_fn=self.attend_fn)
            else:
                logits, cache = self.model.decode_step(
                    params, cache, tok, pos, attend_fn=self.attend_fn,
                    block_table=block_table)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return logp, cache

    def _block_table(self):
        return self.kv.device_table() if self.paged else None

    def _span(self, name: str, **attrs):
        """A tracer span when observability is on; free no-op otherwise."""
        if self._obs is None:
            return _nullcontext(None)
        return self._obs.span(name, "serving", **attrs)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submit_time = obs.now()
        if self._obs is not None:
            self._obs.instant("request.submit", "serving",
                              ts=req.submit_time, uid=req.uid,
                              prompt_tokens=len(req.prompt))
        self.scheduler.submit(req)

    @property
    def waiting(self) -> int:
        """Requests queued in the scheduler (not yet admitted)."""
        return len(self.scheduler)

    def _admit(self) -> list[tuple[int, Request, list[int], int]]:
        free = sorted(s for s in range(self.slots) if s not in self.active)
        admitted: list[tuple[int, Request, list[int], int]] = []
        while free:
            req = self.scheduler.pop()
            if req is None:
                break
            slot = free.pop(0)                      # ascending: determinism
            if self._recurrent and (self.active or slot in self._slots_used):
                raise ValueError(
                    "SSM-family models carry recurrent state: admitting "
                    "request %d %s would advance the shared recurrence at "
                    "the wrong times and silently corrupt decoding; SSM "
                    "serving supports one request per pristine slot at a "
                    "time (use batch-level bulk prefill — model.prefill — "
                    "for concurrent SSM workloads)" % (
                        req.uid, "mid-flight" if self.active
                        else f"into recycled slot {slot}"))
            self._slots_used.add(slot)
            # a preempted request resumes from prompt + tokens so far
            eff = req.prompt + req.generated
            if len(eff) - 1 >= self.max_seq:
                raise ValueError(
                    f"request {req.uid} prompt ({len(eff)} tokens) does "
                    f"not fit max_seq={self.max_seq}; requeueing would "
                    "spin forever")
            shared = 0
            if self.paged:
                if self.kv.blocks_for(len(eff) - 1) > self.kv.usable_blocks:
                    raise OutOfMemory(
                        f"request {req.uid} needs more KV blocks than the "
                        f"whole pool holds ({self.kv.usable_blocks} usable "
                        f"blocks of {self.kv.block_size} positions)")
                try:
                    if self.prefix_on:
                        # map the longest cached prefix, then grow the
                        # private tail behind it
                        shared = self.kv.admit(slot, eff)
                    self.kv.ensure(slot, len(eff) - 1)
                    if self.prefix_on:
                        n = len(eff) - 1
                        if shared < n:
                            # the prefill round will write [c0, n); COW
                            # any still-shared block it diverges into
                            # *before* the tokens land
                            t = self.policy.prefill_chunk
                            c0 = (shared // t) * t
                            self.cache = self.kv.prepare_write(
                                slot, c0, n - 1, self.cache)
                        # publish this prompt's full blocks for later
                        # admissions (ready after the prefill round)
                        self.kv.register(slot, eff[:n])
                except OutOfMemory:
                    # pool dry: roll back any partial allocation and wait
                    # for active slots to finish (or get evicted later)
                    self.kv.release(slot)
                    self._audit_kv()
                    if self._obs is not None:
                        self._obs.instant("request.requeue", "serving",
                                          uid=req.uid, reason="admit-oom")
                    self.scheduler.requeue(req)
                    break
                if shared:
                    self.shared_admissions += 1
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.active[slot] = req
            self.slot_pos[slot] = len(eff) - 1
            self.slot_tok[slot, 0] = eff[-1]
            if self._obs is not None:
                self._obs.instant("request.admit", "serving", uid=req.uid,
                                  slot=slot, admit_seq=req.admit_seq,
                                  prompt_tokens=len(eff), shared=shared)
            admitted.append((slot, req, eff, shared))
        if admitted:
            if self._chunked:
                self._prefill_chunked(admitted)
            else:
                for slot, _req, eff, _shared in admitted:
                    self._prefill_per_token(slot, eff)
        return admitted

    def _prefill_chunked(self, admitted) -> None:
        """All newly admitted slots prefill together, one jitted call per
        chunk: ceil(max_prompt_len / chunk) calls per admission round.

        With prefix sharing, a slot whose leading ``shared`` positions
        came out of the radix tree starts at the chunk boundary below
        the match (``c0``) — recomputing the partial-chunk tail [c0,
        shared) keeps the chunk grid, and therefore the numerics,
        bit-identical to the sharing-off path (the rewrites are
        idempotent: identical values at identical positions).  A slot
        whose whole prompt is cached skips prefill entirely.
        """
        t = self.policy.prefill_chunk
        plan = []                            # (slot, eff, c0)
        for slot, _req, eff, shared in admitted:
            n = len(eff) - 1
            if self.prefix_on and shared >= n:
                self.prefill_tokens_saved += n
                continue
            c0 = (min(shared, n) // t) * t if self.prefix_on else 0
            self.prefill_tokens_saved += c0
            plan.append((slot, eff, c0))
        if plan:
            longest = max(len(eff) - 1 for _s, eff, _c in plan)
            first = min(c0 for _s, _e, c0 in plan)
            bt = self._block_table()
            for c in range(first, longest, t):
                toks = np.zeros((self.slots, t), np.int32)
                start = np.zeros(self.slots, np.int32)
                count = np.zeros(self.slots, np.int32)
                for slot, eff, c0 in plan:
                    if c < c0:
                        continue
                    seg = eff[:-1][c:c + t]
                    if not seg:
                        continue
                    toks[slot, :len(seg)] = seg
                    start[slot] = c
                    count[slot] = len(seg)
                with self._span("serve.prefill_chunk", chunk_start=c,
                                chunk=t, slots=len(plan)):
                    self.cache = self._prefill(self.params, self.cache,
                                               jnp.asarray(toks),
                                               jnp.asarray(start),
                                               jnp.asarray(count), bt)
                    self.prefill_calls += 1
        if self.prefix_on:
            # device content for this round's registrations now exists
            for slot, _req, _eff, _shared in admitted:
                self.kv.mark_ready(slot)

    def _prefill_per_token(self, slot: int, eff: list[int]) -> None:
        # Legacy fallback (MLA / prefill_chunk=0): feed prompt tokens
        # through decode steps.  Other slots are fed their own current
        # (token, position), so their cache writes land where the next
        # decode step would write the identical values — idempotent for
        # position-addressed attention caches.
        bt = self._block_table()
        with self._span("serve.prefill_legacy", slot=slot,
                        tokens=len(eff) - 1):
            for i, tok in enumerate(eff[:-1]):
                tkn = self.slot_tok.copy()
                tkn[slot, 0] = tok
                pos = self.slot_pos.copy()
                pos[slot] = i
                _, self.cache = self._decode(self.params, self.cache,
                                             jnp.asarray(tkn),
                                             jnp.asarray(pos), bt)
                self.prefill_calls += 1

    # -- static audit --------------------------------------------------------
    def _audit_kv(self) -> None:
        """Audit the paged block tables after a release when the pinned
        session's :class:`~repro.runtime.AnalysisPolicy` asks for it
        (``audit_serving=True``, or always at ``"strict"``).  A leak,
        double-free, or trash-block violation raises
        :class:`~repro.analysis.AnalysisError` at the release that caused
        it instead of surfacing as cross-request corruption later."""
        pol = self.session.analysis
        if not pol.enabled or not (pol.strict or pol.audit_serving):
            return
        report = self.kv.audit()
        report.raise_if_errors(context="paged KV cache audit")

    # -- preemption ----------------------------------------------------------
    def _preempt(self, slot: int) -> None:
        req = self.active.pop(slot)
        req.preemptions += 1
        self.preemptions += 1
        if self._obs is not None:
            self._obs.instant("request.preempt", "serving", uid=req.uid,
                              slot=slot, generated=len(req.generated))
        self.kv.release(slot)
        if self.spec_on:
            self.proposer.release(slot)
        self._audit_kv()
        if self._obs is not None:
            self._obs.instant("request.requeue", "serving", uid=req.uid,
                              reason="preempt")
        self.scheduler.requeue(req)

    def _ensure_capacity(self) -> None:
        """Paged mode: every active slot must be able to write its next
        position; when the pool runs dry, evict scheduler-chosen victims
        (their blocks free, the requests requeue and recompute later)."""
        for slot in sorted(self.active):
            while slot in self.active:
                try:
                    p = int(self.slot_pos[slot])
                    self.kv.ensure(slot, p)
                    if self.prefix_on:
                        # decode is about to write position p: give the
                        # slot a private copy of a still-shared block
                        # before the first divergent token lands
                        self.cache = self.kv.prepare_write(
                            slot, p, p, self.cache)
                    break
                except OutOfMemory:
                    others = {s: r for s, r in self.active.items()
                              if s != slot}
                    if not others:
                        # this request alone exhausts the pool
                        self._preempt(slot)
                        raise
                    self._preempt(self.scheduler.choose_victim(others))

    # -- stepping ---------------------------------------------------------------
    def step(self) -> list[Request]:
        """Advance all active slots; returns finished requests.

        Plain mode emits one token per slot per step; speculative mode
        runs one draft-propose / wide-verify round emitting 1..k+1
        tokens per slot (token-for-token identical output)."""
        if self._obs is None:
            if self.spec_on:
                return self._spec_step()
            return self._plain_step()
        with self._obs.span("serve.step", "serving", step=self.steps):
            return self._spec_step() if self.spec_on else self._plain_step()

    def _plain_step(self) -> list[Request]:
        self._admit()
        if not self.active:
            return []
        if self.paged:
            self._ensure_capacity()
            if not self.active:
                return []
        # the span covers dispatch AND the host sync (np.asarray), so its
        # duration is the real step latency, not just dispatch time
        with self._span("serve.decode_step", active=len(self.active)):
            tok = jnp.asarray(self.slot_tok)
            pos = jnp.asarray(self.slot_pos)             # per-slot positions
            next_tok, self.cache = self._decode(self.params, self.cache, tok,
                                                pos, self._block_table())
            self.decode_calls += 1
            next_np = np.asarray(next_tok)
        now = obs.now()
        finished = []
        for slot, req in list(self.active.items()):
            t = int(next_np[slot, 0])
            self._emit_token(req, t, now)
            self.slot_tok[slot, 0] = t
            self.slot_pos[slot] += 1
            if ((req.eos_id is not None and t == req.eos_id)
                    or len(req.generated) >= req.max_new_tokens
                    or self.slot_pos[slot] >= self.max_seq - 1):
                req.done = True
                finished.append(req)
                del self.active[slot]
                if self._obs is not None:
                    self._obs.instant("request.done", "serving", ts=now,
                                      uid=req.uid,
                                      tokens=len(req.generated))
                if self.paged:
                    self.kv.release(slot)
                    self._audit_kv()
        self._sample_gauges()
        self.steps += 1
        return finished

    def _emit_token(self, req: Request, t: int, now: float) -> None:
        """Record one emitted token: benchmark-side fields (generated /
        token_times / first_token_time) and — when observability is on —
        the trace instants and latency histograms, all stamped with the
        SAME clock sample so trace summaries and benchmark math agree."""
        req.generated.append(t)
        req.token_times.append(now)
        first = req.first_token_time is None
        if first:
            req.first_token_time = now
        if self._obs is not None:
            self._obs.instant("request.token", "serving", ts=now,
                              uid=req.uid, token=t)
            if first:
                self._obs.instant("request.first_token", "serving", ts=now,
                                  uid=req.uid)
                self._h_ttft.observe(now - req.submit_time)
            else:
                self._h_itl.observe(now - req.token_times[-2])

    def _sample_gauges(self) -> None:
        if self._obs is None or not self.paged:
            return
        # gauges also append a counter-track sample per set(); skip
        # unchanged values so steady-state steps stay cheap
        vals = (self.kv.usable_blocks - self.kv.blocks_in_use,
                self.kv.cow_copies,
                getattr(getattr(self.kv, "prefix_index", None), "hits", 0))
        if vals == self._gauge_vals:
            return
        self._gauge_vals = vals
        self._g_free.set(vals[0])
        self._g_cow.set(vals[1])
        if getattr(self.kv, "prefix_index", None) is not None:
            self._g_prefix.set(vals[2])

    def _spec_step(self) -> list[Request]:
        """One draft-propose / wide-verify / rollback round.

        Per active slot with last emitted token ``t`` at position ``p``
        and proposals ``d_1..d_{c-1}``: the verify call feeds
        ``[t, d_1..d_{c-1}]`` at positions ``p..p+c-1`` (one batched
        forward, per-slot width via count masks) and argmaxes greedy
        targets ``g_0..g_{c-1}``.  The accepted prefix is the longest
        ``a`` with ``d_{i+1} == g_i``; the slot emits ``d_1..d_a, g_a``
        — every emitted token equals what sequential greedy decode
        would have produced, which is the identity guarantee.  KV for
        the rejected suffix rolls back by block-table truncation.
        """
        admitted = self._admit()
        for slot, _req, eff, _shared in admitted:
            n = len(eff) - 1
            # prefill wrote positions [0, n); the audit treats anything
            # held beyond that without a declared write intent as
            # rollback debris, so record both
            self.kv.set_committed(slot, n)
            if slot not in self.kv._prepared:
                self.kv.begin_write(slot, max(n - 1, 0), max(n - 1, 0))
            self.proposer.admit(slot, eff)
        if not self.active:
            return []
        k = self.policy.speculative.k
        width = k + 1
        contexts = {s: r.prompt + r.generated
                    for s, r in self.active.items()}
        with self._span("serve.spec_propose", slots=len(contexts), k=k):
            proposals = self.proposer.propose(contexts, k)
        counts: dict[int, tuple[int, list[int]]] = {}
        for s in list(self.active):
            props = [int(t) for t in proposals.get(s, [])][:k]
            # verify writes positions p..p+c-1; clamp inside the cache
            c = min(len(props) + 1, width,
                    self.max_seq - int(self.slot_pos[s]))
            counts[s] = (c, props[:c - 1])
        # grow + COW ahead of the wide write; the write intent is
        # declared *before* ensure so a mid-growth preemption audit
        # sees intended blocks, not dangling ones
        for slot in sorted(self.active):
            while slot in self.active:
                p = int(self.slot_pos[slot])
                hi = p + counts[slot][0] - 1
                try:
                    self.kv.begin_write(slot, p, hi)
                    self.kv.ensure(slot, hi)
                    self.cache = self.kv.prepare_write(slot, p, hi,
                                                       self.cache)
                    break
                except OutOfMemory:
                    others = {s: r for s, r in self.active.items()
                              if s != slot}
                    if not others:
                        self._preempt(slot)
                        raise
                    self._preempt(self.scheduler.choose_victim(others))
        if not self.active:
            return []
        toks = np.zeros((self.slots, width), np.int32)
        start = np.zeros(self.slots, np.int32)
        count = np.zeros(self.slots, np.int32)
        for s in self.active:
            c, props = counts[s]
            span = [int(self.slot_tok[s, 0])] + props
            toks[s, :c] = span[:c]
            start[s] = self.slot_pos[s]
            count[s] = c
        with self._span("serve.spec_verify", slots=len(self.active),
                        width=width):
            greedy, self.cache = self._verify(self.params, self.cache,
                                              jnp.asarray(toks),
                                              jnp.asarray(start),
                                              jnp.asarray(count),
                                              self._block_table())
            self.verify_calls += 1
            g = np.asarray(greedy)
        self.spec_rounds += 1
        self.slot_rounds += len(self.active)
        now = obs.now()
        finished = []
        accepted_map: dict[int, int] = {}
        for slot, req in list(self.active.items()):
            _c, props = counts[slot]
            a = 0
            while a < len(props) and props[a] == int(g[slot, a]):
                a += 1
            emit = props[:a] + [int(g[slot, a])]
            accepted_map[slot] = a
            self.accepted_tokens += a
            self.rejected_tokens += len(props) - a
            if self._obs is not None:
                self._obs.instant("spec.round", "serving", ts=now,
                                  uid=req.uid, slot=slot, accepted=a,
                                  rejected=len(props) - a)
            p0 = int(self.slot_pos[slot])
            done = False
            n_emit = 0
            for t in emit:
                self._emit_token(req, t, now)
                n_emit += 1
                if ((req.eos_id is not None and t == req.eos_id)
                        or len(req.generated) >= req.max_new_tokens
                        or p0 + n_emit >= self.max_seq - 1):
                    done = True
                    break
            new_pos = p0 + n_emit
            self.slot_pos[slot] = new_pos
            self.slot_tok[slot, 0] = emit[n_emit - 1]
            # truncate the rejected suffix: KV past new_pos-1 is
            # either unwritten (the bonus token) or rejected content
            freed = self.kv.rollback(slot, new_pos)
            if self._obs is not None and len(props) - a:
                self._obs.instant("kv.rollback", "serving", ts=now,
                                  uid=req.uid, slot=slot, pos=new_pos,
                                  blocks_freed=freed)
            if done:
                req.done = True
                finished.append(req)
                del self.active[slot]
                if self._obs is not None:
                    self._obs.instant("request.done", "serving", ts=now,
                                      uid=req.uid,
                                      tokens=len(req.generated))
                self.kv.release(slot)
                self.proposer.release(slot)
                self._audit_kv()
        self.proposer.commit(accepted_map)
        self._sample_gauges()
        self.steps += 1
        return finished

    # -- beam forking --------------------------------------------------------
    def fork(self, src: int, dst: int) -> None:
        """Clone slot ``src``'s sequence state into free slot ``dst``:
        block table refcount++ per block, copy-on-write on the first
        divergent write (see ``serving/beam.py`` for the consumer)."""
        if not self.paged:
            raise ValueError("fork() requires the paged KV cache")
        self.kv.fork(src, dst)
        self.slot_pos[dst] = self.slot_pos[src]
        self.slot_tok[dst] = self.slot_tok[src]
        self.fork_counts[src] = self.fork_counts.get(src, 0) + 1
        if self._obs is not None:
            self._obs.instant("kv.fork", "serving", src=src, dst=dst)

    def run_until_done(self, max_steps: int = 10000) -> list[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not len(self.scheduler):
                break
        return out

    # -- provenance ----------------------------------------------------------
    def describe(self) -> dict:
        """Serving-scenario snapshot for logs and benchmark provenance."""
        d = {"session": self.session.describe(),
             "slots": self.slots, "max_seq": self.max_seq,
             "chunked_prefill": self._chunked,
             "decode_calls": self.decode_calls,
             "prefill_calls": self.prefill_calls,
             "preemptions": self.preemptions,
             "prefix_sharing": self.prefix_on,
             "prefill_tokens_saved": self.prefill_tokens_saved,
             "shared_admissions": self.shared_admissions}
        spec = {"enabled": self.spec_on,
                "rounds": self.spec_rounds,
                "slot_rounds": self.slot_rounds,
                "verify_calls": self.verify_calls,
                "accepted_tokens": self.accepted_tokens,
                "rejected_tokens": self.rejected_tokens,
                # mean tokens a slot emits per verify round, in
                # [1, k + 1] (one-token decode is exactly 1.0) — the
                # speculative speedup knob
                "accepted_per_step": round(
                    (self.accepted_tokens + self.slot_rounds)
                    / max(1, self.slot_rounds), 3)}
        if self.proposer is not None:
            spec["proposer"] = self.proposer.describe()
        d["speculative"] = spec
        d["fork_counts"] = dict(self.fork_counts)
        if self.paged:
            d["kv_cache"] = self.kv.describe()
        return d
