"""Batched serving engine: continuous-batching-lite over a static slot pool.

Requests join a waiting queue; free cache slots are assigned per step
(static shapes — TPU-friendly), prefill runs per-request, then all active
slots advance one token per ``decode`` call at their *own* position
(slots admitted mid-flight decode at different depths).  Finished slots
(EOS or max-tokens) are returned and recycled.  This is the serving
counterpart of the train loop and the driver behind examples/serve_lm.py.

The engine reads its scoped configuration from the unified runtime
Session: construct it inside ``repro.session(kernels={"decode_attention":
...}, ...)`` to swap the cache-attention kernel (e.g. flash-decoding over
a sequence-sharded cache); the session is snapshotted at construction so
``engine.session.describe()`` records the serving scenario's provenance.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import current_session
from repro.runtime import stack as _rt


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch_slots: int, max_seq: int,
                 attend_fn=None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.session = current_session()
        if attend_fn is not None:
            warnings.warn(
                "ServeEngine(attend_fn=...) is deprecated; construct the "
                "engine inside repro.session(kernels={'decode_attention': "
                "fn}) instead", DeprecationWarning, stacklevel=2)
        self.attend_fn = attend_fn or self.session.kernels.decode_attention
        self._decode = jax.jit(self._decode_fn)
        self.waiting: list[Request] = []
        self.active: dict[int, Request] = {}     # slot -> request
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.slot_tok = np.zeros((batch_slots, 1), np.int32)
        self.cache = model.init_cache(batch_slots, max_seq)
        self.steps = 0

    def _decode_fn(self, params, cache, tok, pos):
        # pin the construction-time session during tracing: whatever is
        # ambient when jit first traces must not leak into the compiled
        # decode (describe() provenance has to match actual behavior)
        with _rt.session(self.session):
            logits, cache = self.model.decode_step(
                params, cache, tok, pos, attend_fn=self.attend_fn)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.waiting:
            slot = free.pop()
            req = self.waiting.pop(0)
            self._prefill_into_slot(slot, req)
            self.active[slot] = req

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        # Per-request prefill: feed prompt tokens through decode steps.
        # Other slots are fed their own current (token, position), so their
        # cache writes land where the next decode step would write the
        # identical values — idempotent for position-addressed attention
        # caches.  (SSM-state layers advance their recurrence on every
        # call, so staggered admission needs a batch-level bulk prefill
        # for SSM families — same limitation as before.)
        for i, tok in enumerate(req.prompt[:-1]):
            t = self.slot_tok.copy()
            t[slot, 0] = tok
            p = self.slot_pos.copy()
            p[slot] = i
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(t), jnp.asarray(p))
        self.slot_pos[slot] = len(req.prompt) - 1
        self.slot_tok[slot, 0] = req.prompt[-1]

    # -- stepping ---------------------------------------------------------------
    def step(self) -> list[Request]:
        """Advance all active slots one token; returns finished requests."""
        self._admit()
        if not self.active:
            return []
        tok = jnp.asarray(self.slot_tok)
        pos = jnp.asarray(self.slot_pos)                 # per-slot positions
        next_tok, self.cache = self._decode(self.params, self.cache, tok,
                                            pos)
        next_np = np.asarray(next_tok)
        finished = []
        for slot, req in list(self.active.items()):
            t = int(next_np[slot, 0])
            req.generated.append(t)
            self.slot_tok[slot, 0] = t
            self.slot_pos[slot] += 1
            if ((req.eos_id is not None and t == req.eos_id)
                    or len(req.generated) >= req.max_new_tokens
                    or self.slot_pos[slot] >= self.max_seq - 1):
                req.done = True
                finished.append(req)
                del self.active[slot]
        self.steps += 1
        return finished

    def run_until_done(self, max_steps: int = 10000) -> list[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.waiting:
                break
        return out
