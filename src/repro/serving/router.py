"""Multi-replica serving front door: routing policies + ``serve()``.

One :class:`~repro.serving.engine.ServeEngine` continuous-batches over
its own slot pool; the :class:`Router` scales that *out* — it owns N
engine replicas, places every arriving request on one of them through a
pluggable :class:`RoutingPolicy`, and steps all replicas in lockstep.
Replicas are independent (own KV pool, own radix tree, own scheduler),
so placement is where cross-replica intelligence lives:

``round_robin``      cycle through replicas — the stateless baseline.
``least_loaded``     fewest in-flight requests (active + queued).
``prefix_affinity``  the replica whose radix tree caches the longest
                     prefix of the prompt (probed without touching LRU
                     state), so requests with a shared system prompt
                     pile onto the replica that already paid its
                     prefill; load-only tie-break keeps cold prompts
                     balanced.

:func:`serve` is the stream front door: it pulls arrivals from a
callable or iterator (the continuous-batching analogue of an async
request queue — each engine step is one tick), routes them, steps the
replicas, and yields finished requests as they complete.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Iterable, Iterator, Protocol, Sequence, Union

from repro import obs
from repro.runtime import ServingPolicy, current_session

from .engine import Request, ServeEngine

__all__ = ["RoutingPolicy", "RoundRobinRouting", "LeastLoadedRouting",
           "PrefixAffinityRouting", "make_routing", "Router", "serve",
           "timed_stream"]


class RoutingPolicy(Protocol):
    """Placement policy: pick a replica index for an arriving request."""

    name: str

    def route(self, req: Request, engines: Sequence[ServeEngine]) -> int:
        ...


def _load(engine: ServeEngine) -> int:
    return len(engine.active) + engine.waiting


class RoundRobinRouting:
    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def route(self, req: Request, engines: Sequence[ServeEngine]) -> int:
        i = self._next % len(engines)
        self._next += 1
        return i


class LeastLoadedRouting:
    name = "least_loaded"

    def route(self, req: Request, engines: Sequence[ServeEngine]) -> int:
        return min(range(len(engines)), key=lambda i: (_load(engines[i]), i))


class PrefixAffinityRouting:
    """Longest cached radix match wins; ties fall back to least-loaded.

    Probing uses ``PrefixIndex.match_len`` (no LRU touch, no counters),
    so routing never perturbs the caches it inspects.  Engines without
    a radix tree (sharing off / unsupported model) probe as 0 and the
    policy degrades to least-loaded.
    """

    name = "prefix_affinity"

    def route(self, req: Request, engines: Sequence[ServeEngine]) -> int:
        def key(i: int) -> tuple[int, int, int]:
            eng = engines[i]
            index = eng.kv.prefix_index if eng.kv is not None else None
            cached = (index.match_len(req.prompt)
                      if index is not None else 0)
            return (-cached, _load(eng), i)
        return min(range(len(engines)), key=key)


_ROUTING: dict[str, Callable[[], Any]] = {
    "round_robin": RoundRobinRouting,
    "rr": RoundRobinRouting,
    "least_loaded": LeastLoadedRouting,
    "prefix_affinity": PrefixAffinityRouting,
    "prefix": PrefixAffinityRouting,
}


def make_routing(spec: Any) -> RoutingPolicy:
    """Registry name or ready-made policy instance -> RoutingPolicy."""
    if isinstance(spec, str):
        try:
            return _ROUTING[spec]()
        except KeyError:
            raise ValueError(f"unknown routing policy {spec!r}; known: "
                             f"{sorted(set(_ROUTING))}") from None
    if callable(getattr(spec, "route", None)):
        return spec
    raise TypeError(f"routing spec {spec!r} is neither a registry name "
                    "nor a RoutingPolicy")


# arrivals: an iterator yielding Request (submit now) or None (tick
# done), or a callable tick -> Request | iterable of Requests | None
Stream = Union[Iterator[Any], Callable[[int], Any]]


def timed_stream(trace: Iterable[tuple[int, Request]]) -> Iterator[Any]:
    """Turn ``(arrival_tick, request)`` pairs into a serve() stream.

    Each ``None`` yielded ends one tick; requests are released once the
    tick counter reaches their arrival.  Pairs must be sorted by
    arrival tick (a Poisson trace built from cumulative gaps is).
    """
    pending = iter(trace)
    nxt = next(pending, None)
    tick = 0
    while nxt is not None:
        while nxt is not None and nxt[0] <= tick:
            yield nxt[1]
            nxt = next(pending, None)
        yield None
        tick += 1


class Router:
    """N engine replicas behind one routing policy, stepped in lockstep."""

    def __init__(self, engines: Sequence[ServeEngine],
                 routing: Any | None = None):
        if not engines:
            raise ValueError("Router needs at least one engine replica")
        self.engines = list(engines)
        if routing is None:
            routing = self.engines[0].policy.routing
        self.routing = make_routing(routing)
        self.routed: dict[int, int] = {}          # request uid -> replica
        self.steps = 0
        # ambient tracer, falling back to any replica's (replicas built
        # inside an obs session, router constructed outside it)
        self._obs = obs.get_tracer()
        if self._obs is None:
            self._obs = next((e._obs for e in self.engines
                              if getattr(e, "_obs", None) is not None), None)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> int:
        """Route one request; returns the replica index it landed on."""
        i = self.routing.route(req, self.engines)
        if not 0 <= i < len(self.engines):
            raise ValueError(f"routing policy {self.routing.name!r} "
                             f"returned replica {i} of {len(self.engines)}")
        if self._obs is not None:
            self._obs.instant("router.place", "serving", uid=req.uid,
                              replica=i, policy=self.routing.name)
            self._obs.metrics.counter(f"router.placed.replica{i}").add()
        self.engines[i].submit(req)
        self.routed[req.uid] = i
        return i

    @property
    def waiting(self) -> int:
        return sum(e.waiting for e in self.engines)

    @property
    def active(self) -> int:
        return sum(len(e.active) for e in self.engines)

    def step(self) -> list[Request]:
        """Advance every replica one step; returns finished requests."""
        self.steps += 1
        done: list[Request] = []
        for eng in self.engines:
            done.extend(eng.step())
        return done

    def run_until_done(self, max_steps: int = 10000) -> list[Request]:
        out: list[Request] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.waiting:
                break
        return out

    # -- stream front door ---------------------------------------------------
    def serve(self, stream: Stream,
              max_steps: int = 100000) -> Iterator[Request]:
        """Continuous batching from a request stream.

        Pulls arrivals for the current tick (iterator: items until a
        ``None``; callable: one call with the tick number), routes
        them, steps every replica, and yields requests the moment they
        finish.  Runs until the stream is exhausted and all in-flight
        work drains.
        """
        it = stream if hasattr(stream, "__next__") else None
        exhausted = False
        for tick in itertools.count():
            if tick >= max_steps:
                raise RuntimeError(f"serve() exceeded max_steps={max_steps} "
                                   "with work still in flight")
            if not exhausted:
                arrivals: list[Request] = []
                if it is not None:
                    for item in it:
                        if item is None:
                            break
                        arrivals.append(item)
                    else:
                        exhausted = True
                else:
                    got = stream(tick)
                    if got is None:
                        exhausted = True
                    elif isinstance(got, Request):
                        arrivals = [got]
                    else:
                        arrivals = list(got)
                for req in arrivals:
                    self.submit(req)
            yield from self.step()
            if exhausted and not self.active and not self.waiting:
                return

    # -- provenance ----------------------------------------------------------
    def describe(self) -> dict:
        per_engine = [e.describe() for e in self.engines]
        spec = {"accepted_tokens": 0, "rejected_tokens": 0,
                "spec_rounds": 0, "rollback_blocks_freed": 0, "forks": 0}
        for d in per_engine:
            s = d.get("speculative", {})
            spec["accepted_tokens"] += s.get("accepted_tokens", 0)
            spec["rejected_tokens"] += s.get("rejected_tokens", 0)
            spec["spec_rounds"] += s.get("rounds", 0)
            kv = d.get("kv_cache", {})
            spec["rollback_blocks_freed"] += kv.get("rollback_blocks_freed", 0)
            spec["forks"] += kv.get("forks", 0)
        return {"replicas": len(self.engines),
                "routing": self.routing.name,
                "steps": self.steps,
                "placement": {uid: i for uid, i in sorted(self.routed.items())},
                "speculative": spec,
                "engines": per_engine}


def serve(model, params, stream: Stream, *, replicas: int = 2,
          batch_slots: int, max_seq: int,
          policy: ServingPolicy | None = None,
          routing: Any | None = None,
          max_steps: int = 100000) -> Iterator[Request]:
    """Front door: build ``replicas`` engine replicas under the current
    session, route a request stream across them, and yield finished
    requests as they complete.  ``routing`` (or the session
    ``ServingPolicy.routing``) picks the placement policy."""
    if replicas < 1:
        raise ValueError("serve() needs at least one replica")
    if policy is None:
        policy = current_session().serving
    engines = [ServeEngine(model, params, batch_slots=batch_slots,
                           max_seq=max_seq, policy=policy)
               for _ in range(replicas)]
    router = Router(engines, routing=routing)
    yield from router.serve(stream, max_steps=max_steps)
