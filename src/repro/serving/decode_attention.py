"""Flash-decoding over sequence-sharded KV caches (SP for serving).

Why: MQA/MLA architectures have too few (or zero materialized) KV heads to
tensor-parallelize the cache over a 16-way model axis, and ``long_500k``
has batch=1 so batch sharding is unavailable too.  The scalable axis is
the cache *sequence*.  Under plain GSPMD, decode attention against a
seq-sharded cache all-gathers the cache (collective-bound); flash-decoding
instead computes partial softmax statistics (m, l, o) per sequence shard
inside ``shard_map`` and merges them with a pmax/psum combine — moving
O(S·d) gather traffic down to O(d) statistics traffic per step.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.attention import partial_cache_attention


def make_flash_decode_attend(mesh: Mesh, *, seq_axes: Sequence[str],
                             batch_axes: Sequence[str] = ()):
    """Build an ``attend_fn(q, k, v, valid, scale, cap)`` closure.

    q: [B, H, Dk] (replicated over seq_axes);
    k: [B, S, Kv, Dk]; v: [B, S, Kv, Dv] (S sharded over seq_axes);
    valid: [S] bool (sharded like S), or [B, S] when slots decode at
    per-slot positions (continuous batching).

    Suitable as a session-level override:
    ``repro.session(kernels={"decode_attention": attend_fn})``.
    """
    seq_axes = tuple(seq_axes)
    batch_axes = tuple(batch_axes)
    bspec = batch_axes if len(batch_axes) != 1 else batch_axes[0]
    sspec = seq_axes if len(seq_axes) != 1 else seq_axes[0]

    def attend(q, k, v, valid, *, scale, cap: float = 0.0):
        def local(q_l, k_l, v_l, valid_l):
            m, l, o = partial_cache_attention(q_l, k_l, v_l, valid_l,
                                              scale=scale, cap=cap)
            gm = jax.lax.pmax(m, seq_axes)
            corr = jnp.exp(m - gm)
            l_g = jax.lax.psum(l * corr, seq_axes)
            o_g = jax.lax.psum(o * corr[..., None], seq_axes)
            out = o_g / jnp.maximum(l_g[..., None], 1e-30)
            b, kvh, g, dv = out.shape
            return out.reshape(b, kvh * g, dv).astype(q_l.dtype)

        from repro.core.compat import shard_map

        valid_spec = P(bspec, sspec) if valid.ndim == 2 else P(sspec)
        return shard_map(
            local, mesh=mesh,
            in_specs=(P(bspec, None, None),
                      P(bspec, sspec, None, None),
                      P(bspec, sspec, None, None),
                      valid_spec),
            out_specs=P(bspec, None, None),
            check_vma=False,
        )(q, k, v, valid)

    return attend
