"""The compiler's SSA-style graph IR: :class:`Node`, :class:`Graph`, and
``trace()`` — lifting the lazy backend's pending op stream into an
inspectable, rewritable program.

The lazy backend (paper §4.1.1, the ArrayFire-JIT analog) always *had* a
tensor graph; it was just opaque — a web of ``LazyTensor`` closures only
``materialize`` could walk.  ``trace()`` captures that web as an explicit
``Graph``: canonically-numbered nodes in topological order, named inputs
and outputs, per-node op/attrs/shape/dtype metadata, and an ``alias`` map
recording what rewrites merged away.  Passes (``repro.compiler.passes``)
rewrite the Graph; lowering (``repro.compiler.lowering``) turns it into an
executable program of generated cluster kernels and residual op dispatches.

Node kinds:

``input``   a value supplied at execution time (a materialized leaf);
``const``   a value baked at compile time (created by constant folding);
anything else: a compute node whose ``fn`` maps input values to the
            node's value.  ``attrs`` carries the op's static parameters
            as a hashable tuple; ``attrs is None`` marks the node
            *opaque* — its closure captures something we cannot compare
            (e.g. a PRNG key array), so CSE/folding/program-caching must
            leave it alone.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

import jax.numpy as jnp

from repro.core.tensor.lazy_backend import _ELEMENTWISE

#: ops that compute one output element from the matching input elements —
#: the fusable set (the lazy backend's table is the source of truth).
ELEMENTWISE_OPS = frozenset(_ELEMENTWISE)

#: ops that collapse one or more axes; ``attrs`` is ``(axis, keepdims)``.
REDUCTION_OPS = frozenset({"sum", "max", "min", "prod"})

#: everything the fusion pass may place inside a generated cluster:
#: elementwise ops, trailing reductions (and the elementwise epilogue that
#: follows them — softmax denominators, mean chains), plus the two
#: shape-transparent ops those compositions thread values through.
FUSABLE_OPS = (ELEMENTWISE_OPS | REDUCTION_OPS
               | frozenset({"stop_gradient", "broadcast_to"}))

#: ops whose value depends on state we must not deduplicate or precompute.
IMPURE_OPS = frozenset({"random_uniform", "random_normal"})

#: the cluster kinds lowering knows how to dispatch on.
CLUSTER_KINDS = ("elementwise", "reduction", "epilogue", "attention")


@dataclass
class Node:
    """One SSA value: ``%uid = op(inputs) : dtype[shape]``."""

    uid: int
    op: str
    fn: Callable | None
    inputs: tuple[int, ...]
    shape: tuple[int, ...]
    dtype: Any
    attrs: tuple | None = ()
    value: Any = None          # concrete array for input/const nodes
    src_op: str = ""           # original op (survives folding), telemetry tag
    cluster: int | None = None  # fusion-pass assignment

    def __post_init__(self) -> None:
        if not self.src_op:
            self.src_op = self.op

    @property
    def size(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    def type_str(self) -> str:
        return f"{jnp.dtype(self.dtype).name}[{','.join(map(str, self.shape))}]"


@dataclass
class Cluster:
    """A fusable region found by a fusion/matcher pass: executed atomically
    as one generated kernel.

    ``kind`` selects the lowering strategy (see :data:`CLUSTER_KINDS`):
    ``elementwise``/``reduction`` regions get a synthesized whole-body
    kernel, ``epilogue`` regions fold into the tiled matmul kernel, and
    ``attention`` regions lower to the parameterized flash-attention
    template.  ``meta`` carries the matcher's role assignments (which
    external input is q/k/v, the static scale, the softmax/sigmoid mode);
    it is empty for plain fusion clusters.
    """

    cid: int
    node_ids: tuple[int, ...]     # members, topo order
    inputs: tuple[int, ...]       # external producers, first-use order
    outputs: tuple[int, ...]      # members consumed outside (or graph outputs)
    kind: str = "elementwise"
    meta: dict[str, Any] = field(default_factory=dict)


@dataclass
class Graph:
    """A program over Nodes; insertion order of ``order`` is topological."""

    nodes: dict[int, Node] = field(default_factory=dict)
    order: list[int] = field(default_factory=list)
    inputs: tuple[int, ...] = ()
    outputs: tuple[int, ...] = ()
    alias: dict[int, int] = field(default_factory=dict)
    clusters: list[Cluster] = field(default_factory=list)

    # -- bookkeeping --------------------------------------------------------
    def resolve(self, uid: int) -> int:
        """Follow the alias chain to the surviving representative."""
        while uid in self.alias:
            uid = self.alias[uid]
        return uid

    def add(self, node: Node) -> Node:
        self.nodes[node.uid] = node
        self.order.append(node.uid)
        return node

    def clear_clusters(self) -> None:
        """Invalidate the fusion partition (rewriting passes call this —
        membership/edge metadata would dangle otherwise)."""
        self.clusters = []
        for uid in self.order:
            self.nodes[uid].cluster = None

    def remove(self, uid: int, replacement: int | None = None) -> None:
        if replacement is not None:
            self.alias[uid] = replacement
        del self.nodes[uid]
        self.order.remove(uid)

    def consumers(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {uid: [] for uid in self.order}
        for uid in self.order:
            for d in self.nodes[uid].inputs:
                out[d].append(uid)
        return out

    def n_edges(self) -> int:
        return sum(len(self.nodes[uid].inputs) for uid in self.order)

    def signature(self) -> tuple | None:
        """Structural identity for program caching; ``None`` if any node
        is opaque (its behavior is not captured by (op, attrs))."""
        sig = []
        for uid in self.order:
            n = self.nodes[uid]
            if n.attrs is None:
                return None
            sig.append((n.uid, n.op, n.attrs, n.inputs, n.shape,
                        str(jnp.dtype(n.dtype))))
        return (tuple(sig), self.inputs, self.outputs)

    # -- verification -------------------------------------------------------
    def validate(self) -> list[str]:
        """IR invariants; returns human-readable violations (empty = ok).

        Delegates to the structured verifier
        (:func:`repro.analysis.check_graph`, at ``strict`` level so every
        non-opaque compute node's recorded shape/dtype is re-derived) and
        flattens the :class:`~repro.analysis.Diagnostic`s back to strings
        — there is exactly one verifier; this is the legacy view of it.
        """
        from repro.analysis.shapes import check_graph
        from repro.runtime.policies import AnalysisPolicy

        return [d.format()
                for d in check_graph(self, AnalysisPolicy(level="strict"))]

    def check(self, policy: Any = None, where: str | None = None) -> Any:
        """Structured form of :meth:`validate`: a
        :class:`repro.analysis.DiagnosticReport` at the given
        :class:`~repro.runtime.AnalysisPolicy` level."""
        from repro.analysis.shapes import check_graph

        return check_graph(self, policy, where=where)

    # -- presentation -------------------------------------------------------
    def dump(self) -> str:
        """Text format, one SSA binding per line::

            graph(%0: f32[8,8]) {
              %1 = add(%0, %0) : f32[8,8]        # cluster 0 (elementwise)
              ...
              return %1
            }
        """
        ins = ", ".join(f"%{i}: {self.nodes[i].type_str()}"
                        for i in self.inputs if i in self.nodes)
        lines = [f"graph({ins}) {{"]
        for uid in self.order:
            n = self.nodes[uid]
            if n.op == "input":
                continue
            args = ", ".join(f"%{d}" for d in n.inputs)
            if n.op == "const":
                head = f"  %{uid} = const[{n.src_op}]() : {n.type_str()}"
            else:
                head = f"  %{uid} = {n.op}({args}) : {n.type_str()}"
            if n.cluster is not None:
                kind = (self.clusters[n.cluster].kind
                        if n.cluster < len(self.clusters) else "?")
                head = f"{head:<52}# cluster {n.cluster} ({kind})"
            lines.append(head)
        rets = ", ".join(f"%{self.resolve(o)}" for o in self.outputs)
        lines.append(f"  return {rets}")
        lines.append("}")
        return "\n".join(lines)

    # -- reference interpreter ----------------------------------------------
    def eval(self, env: dict[int, Any] | None = None) -> list[Any]:
        """Node-at-a-time evaluation — the semantics every lowering must
        reproduce (also the legacy/empty-pipeline execution path)."""
        env = dict(env or {})
        for uid in self.order:
            n = self.nodes[uid]
            if n.op == "input":
                if uid not in env:
                    if n.value is None:
                        raise KeyError(f"input %{uid} missing from env")
                    env[uid] = n.value
            elif n.op == "const":
                env[uid] = n.value
            else:
                env[uid] = n.fn(*[env[d] for d in n.inputs])
        return [env[self.resolve(o)] for o in self.outputs]


def trace(roots: Iterable[Any]) -> tuple[Graph, dict[int, Any]]:
    """Capture the pending subgraph under ``roots`` as a :class:`Graph`.

    ``roots`` are ``LazyTensor``s (duck-typed: ``op/fn/deps/shape/dtype/
    value/attrs``).  Tensors that already hold a value become ``input``
    nodes (their value is supplied via the execution env, never baked into
    the program — so a cached program can be replayed against new leaf
    values).  Returns the graph plus ``sources``: canonical uid → the
    traced LazyTensor, for writing results back after execution.
    """
    graph = Graph()
    sources: dict[int, Any] = {}
    canon: dict[int, int] = {}       # LazyTensor.uid -> canonical uid
    roots = list(roots)

    def lift_raw(d: Any) -> int:
        # defensive: a raw python/array dep becomes an (opaque) const
        arr = jnp.asarray(d)
        cid = len(graph.order)
        graph.add(Node(cid, "const", None, (), tuple(arr.shape), arr.dtype,
                       attrs=None, value=arr))
        return cid

    def emit(lt: Any) -> int:
        cid = len(graph.order)
        canon[lt.uid] = cid
        if lt.value is not None:
            graph.add(Node(cid, "input", None, (), tuple(lt.shape), lt.dtype,
                           attrs=(tuple(lt.shape), str(jnp.dtype(lt.dtype))),
                           value=lt.value))
        else:
            dep_ids = tuple(canon[d.uid] if hasattr(d, "deps") else lift_raw(d)
                            for d in lt.deps)
            graph.add(Node(cid, lt.op, lt.fn, dep_ids, tuple(lt.shape),
                           lt.dtype, attrs=getattr(lt, "attrs", None)))
        sources[cid] = lt
        return cid

    def visit(root: Any) -> int:
        # iterative post-order: deep chains must not hit the recursion limit
        stack: list[tuple[Any, bool]] = [(root, False)]
        while stack:
            lt, expanded = stack.pop()
            if lt.uid in canon:
                continue
            if expanded or lt.value is not None:
                emit(lt)
                continue
            stack.append((lt, True))
            for d in lt.deps:
                if hasattr(d, "deps") and d.uid not in canon:
                    stack.append((d, False))
        return canon[root.uid]

    out_ids: list[int] = []
    for r in roots:
        if hasattr(r, "deps"):
            out_ids.append(visit(r))
        else:
            arr = jnp.asarray(r)
            cid = len(graph.order)
            graph.add(Node(cid, "const", None, (), tuple(arr.shape),
                           arr.dtype, attrs=None, value=arr))
            out_ids.append(cid)
    graph.outputs = tuple(out_ids)
    graph.inputs = tuple(uid for uid in graph.order
                         if graph.nodes[uid].op == "input")
    return graph, sources
