"""Compiler self-check: round-trip every pass over a canned graph corpus.

Run as ``python -m repro.compiler.selfcheck`` (CI does).  For each corpus
graph and each pass pipeline, this:

 1. snapshots reference outputs from the un-optimized graph;
 2. runs the pipeline one pass at a time, calling ``Graph.validate()``
    after every pass — failing on IR invariant violations (dangling deps,
    orphan outputs, broken alias chains, shape/dtype mismatches after a
    rewrite);
 3. lowers under every lowering mode and checks the executed outputs
    against the reference;
 4. checks the memory plan is sane: no duplicate alloc/free uids, every
    free paired with an alloc.

Exit status 0 = all clean; 1 = violations (printed).
"""

from __future__ import annotations

import sys
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import CompilerPolicy, session

from . import graph as graph_mod
from .lowering import lower, memory_plan, snapshot_logical
from .passes import PASS_REGISTRY, PassManager


def _lazy_backend() -> Any:
    from repro.core.tensor.lazy_backend import LazyBackend

    return LazyBackend()


# -- corpus ------------------------------------------------------------------
# each entry: name -> fn(ops, x) returning (roots, keep_outputs) where
# keep_outputs selects a subset of traced outputs (dropping some creates
# genuinely dead branches for DCE to collect)


def _chain(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    y = x
    for _ in range(6):
        y = ops.tanh(ops.mul(ops.add(y, y), ops.full_like(y, 0.5)))
    return [y], None


def _shared_subexpr(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    # the same subexpression built twice -> CSE must merge, frees must
    # still be emitted exactly once per surviving node
    a1 = ops.exp(ops.mul(x, x))
    a2 = ops.exp(ops.mul(x, x))
    return [ops.add(ops.tanh(a1), ops.sqrt(ops.abs(a2)))], None


def _dead_branch(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    live = ops.tanh(ops.add(x, x))
    dead = ops.exp(ops.mul(x, ops.full_like(x, 3.0)))
    return [live, ops.add(dead, dead)], (0,)


def _diamond(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    a = ops.add(x, ops.full_like(x, 1.0))
    left = ops.exp(a)
    right = ops.sum(a, axis=-1, keepdims=True)   # reduction joins the cluster
    return [ops.mul(left, ops.broadcast_to(right, left.shape))], None


def _reduce_matmul(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    w = ops.full((x.shape[-1], 4), 0.1)
    h = ops.relu(ops.matmul(x, w))
    return [ops.sum(ops.mul(h, h), axis=None, keepdims=False)], None


def _mixed_dtype(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    lo = ops.astype(x, jnp.bfloat16)
    y = ops.astype(ops.mul(lo, lo), jnp.float32)
    mask = ops.ge(x, ops.full_like(x, 0.0))
    return [ops.where(mask, y, ops.neg(y))], None


def _const_heavy(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    a = ops.mul(ops.full((4, 8), 2.0), ops.full((4, 8), 3.0))
    b = ops.add(a, ops.iota(jnp.float32, (4, 8), 1))
    return [ops.add(x, b)], None


def _random_opaque(ops: Any, x: Any) -> tuple[list, tuple[int, ...] | None]:
    key = jax.random.PRNGKey(0)
    noise = ops.random_uniform(key, x.shape, jnp.float32, 0.0, 1.0)
    return [ops.add(x, ops.mul(noise, noise))], None


def _softmax_attention(ops: Any, x: Any
                       ) -> tuple[list, tuple[int, ...] | None]:
    # plain-ops softmax(QK^T * scale)V on rank-2 operands — exercised by
    # the attention matcher (x is 4x8; q/k/v derive from it)
    q = ops.tanh(x)
    k = ops.mul(x, ops.full_like(x, 0.5))
    v = ops.add(x, ops.full_like(x, 1.0))
    s = ops.mul(ops.matmul(q, ops.transpose(k, (1, 0))),
                ops.full((4, 4), 0.3535))
    m = ops.max(s, axis=-1, keepdims=True)
    e = ops.exp(ops.sub(s, ops.stop_gradient(m)))
    p = ops.div(e, ops.sum(e, axis=-1, keepdims=True))
    return [ops.matmul(p, v)], None


def _sigmoid_attention(ops: Any, x: Any
                       ) -> tuple[list, tuple[int, ...] | None]:
    s = ops.matmul(x, ops.transpose(x, (1, 0)))
    ones = ops.full((4, 4), 1.0)
    p = ops.div(ones, ops.add(ones, ops.exp(ops.neg(s))))
    return [ops.matmul(p, ops.abs(x))], None


def _matmul_epilogue(ops: Any, x: Any
                     ) -> tuple[list, tuple[int, ...] | None]:
    # matmul + bias + gelu: the epilogue matcher folds the consumers
    w = ops.full((x.shape[-1], 8), 0.1)
    b = ops.iota(jnp.float32, (8,), 0)
    return [ops.gelu(ops.add(ops.matmul(x, w), b))], None


def _reduction_tail(ops: Any, x: Any
                    ) -> tuple[list, tuple[int, ...] | None]:
    # elementwise chain ending in a reduction plus epilogue (mean-style):
    # the fusion pass absorbs the whole thing into one reduction cluster
    t = ops.tanh(ops.mul(x, ops.full_like(x, 0.25)))
    s = ops.sum(t, axis=-1, keepdims=True)
    return [ops.mul(s, ops.full_like(s, 1.0 / 8.0))], None


CORPUS: dict[str, Callable] = {
    "chain": _chain,
    "shared_subexpr": _shared_subexpr,
    "dead_branch": _dead_branch,
    "diamond": _diamond,
    "reduce_matmul": _reduce_matmul,
    "mixed_dtype": _mixed_dtype,
    "const_heavy": _const_heavy,
    "random_opaque": _random_opaque,
    "softmax_attention": _softmax_attention,
    "sigmoid_attention": _sigmoid_attention,
    "matmul_epilogue": _matmul_epilogue,
    "reduction_tail": _reduction_tail,
}

PIPELINES: tuple[tuple[str, ...], ...] = (
    ("cse",), ("fold",), ("dce",), ("fuse",),
    ("attention", "fuse"),               # matcher alone + residual fusion
    ("epilogue", "fuse"),
    ("cse", "fold", "dce",
     "attention", "epilogue", "fuse"),   # the default
    ("cse", "fold", "dce", "fuse"),      # pre-matcher default
    ("fold", "cse", "dce", "fuse"),      # permuted
    ("fuse", "cse", "dce"),              # fusion first
    (),                                  # legacy / identity
)

LOWERINGS = ("eager", "jit", "auto")


def _build(name: str) -> tuple[graph_mod.Graph, dict[int, Any]]:
    from repro.core.tensor import ops

    lb = _lazy_backend()
    with session(backend=lb):
        x = lb._lift(jnp.linspace(-2.0, 2.0, 32).reshape(4, 8)
                     .astype(jnp.float32))
        roots, keep = CORPUS[name](ops, x)
    graph, sources = graph_mod.trace(roots)
    if keep is not None:
        graph.outputs = tuple(graph.outputs[i] for i in keep)
    return graph, sources


def run_corpus(verbose: bool = False,
               pipelines: tuple[tuple[str, ...], ...] | None = None
               ) -> list[str]:
    """All (graph, pipeline, lowering) round-trips; returns violations."""
    problems: list[str] = []
    for gname in CORPUS:
        for pipeline in (pipelines if pipelines is not None else PIPELINES):
            graph, _ = _build(gname)
            where = f"{gname} / {'+'.join(pipeline) or 'identity'}"
            pre = graph.validate()
            problems += [f"{where}: pre-pass: {p}" for p in pre]
            ref = [np.asarray(v) for v in graph.eval()]
            # fused low-precision regions may legally skip intermediate
            # rounding (XLA keeps f32 through a fused convert-op-convert)
            low_precision = any(
                jnp.dtype(graph.nodes[u].dtype).itemsize < 4
                and jnp.issubdtype(graph.nodes[u].dtype, jnp.floating)
                for u in graph.order)
            rtol, atol = (2e-2, 1e-2) if low_precision else (1e-5, 1e-6)
            snapshot = snapshot_logical(graph)
            policy = CompilerPolicy(pipeline=pipeline)
            pm = PassManager.from_policy(policy)
            for p in pm.passes:
                p.run(graph)
                problems += [f"{where}: after {p.name}: {v}"
                             for v in graph.validate()]
            plan = memory_plan(snapshot, graph)
            allocs = [a[0] for a in plan[0]]
            if len(allocs) != len(set(allocs)):
                problems.append(f"{where}: duplicate alloc uids")
            if len(plan[1]) != len(set(plan[1])):
                problems.append(f"{where}: duplicate free uids")
            if not set(plan[1]) <= set(allocs):
                problems.append(f"{where}: free without alloc")
            for mode in LOWERINGS:
                exe = lower(graph, policy.replace(lowering=mode), plan=plan)
                env = {cid: graph.nodes[cid].value for cid in exe.inputs}
                try:
                    out = exe.output_values(exe.run(env))
                except Exception as e:  # noqa: BLE001
                    problems.append(f"{where} [{mode}]: execution failed: {e}")
                    continue
                for i, (got, want) in enumerate(zip(out, ref)):
                    got = np.asarray(got)
                    if got.shape != want.shape or str(got.dtype) != str(
                            want.dtype):
                        problems.append(
                            f"{where} [{mode}]: output {i} type drift "
                            f"{got.dtype}{got.shape} vs "
                            f"{want.dtype}{want.shape}")
                    elif not np.allclose(got.astype(np.float64),
                                         want.astype(np.float64),
                                         rtol=rtol, atol=atol):
                        problems.append(
                            f"{where} [{mode}]: output {i} numerics diverge")
            if verbose:
                status = "ok" if not problems else "..."
                print(f"  {where:<44} {status}")
    return problems


def main() -> int:
    print(f"repro.compiler selfcheck: {len(CORPUS)} graphs x "
          f"{len(PIPELINES)} pipelines x {len(LOWERINGS)} lowerings "
          f"(passes: {sorted(PASS_REGISTRY)})")
    problems = run_corpus(verbose=True)
    if problems:
        print(f"\n{len(problems)} violation(s):")
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print("all invariants hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
