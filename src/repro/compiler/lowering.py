"""Lowering: an optimized :class:`Graph` becomes an :class:`Executable`.

Each fused elementwise cluster is lowered to a *generated* Pallas kernel
(``repro.kernels.cluster`` synthesizes the body from the cluster's ops;
``interpret=True`` off-TPU).  Clusters the Pallas tiling cannot take — or
any cluster under ``lowering="jit"`` — get a per-cluster ``jax.jit`` of
the same synthesized body.  Residual nodes (reductions, matmuls, shape
ops) stay single dispatches.  ``lowering="eager"`` skips compilation
entirely: clusters execute as plain Python loops (debugging / the legacy
path).

The Executable also carries the *memory plan* for the lazy backend's
allocation telemetry (paper §5.2.2): one alloc per surviving logical node
and at most one free per surviving interior node — computed here, after
CSE/DCE, so merged or dead nodes can never double-count events.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.kernels import cluster as cluster_kernels

from .graph import Graph
from .passes import PassStats


@dataclass
class OpStep:
    """A residual single-op dispatch."""

    uid: int
    inputs: tuple[int, ...]
    fn: Callable
    op: str


@dataclass
class ClusterStep:
    """One generated kernel covering a fused region."""

    fn: Callable                  # (*input arrays) -> tuple(outputs)
    inputs: tuple[int, ...]
    outputs: tuple[int, ...]
    kind: str                     # "pallas" | "jit" | "eager"
    n_ops: int = 0
    cluster_kind: str = "elementwise"   # Cluster.kind provenance


@dataclass
class Executable:
    """A lowered program: run ``steps`` over an env keyed by node uid."""

    steps: list[Any]
    consts: dict[int, Any]
    inputs: tuple[int, ...]
    outputs: tuple[int, ...]
    alias: dict[int, int]
    allocs: tuple[tuple[int, int, str], ...]   # (uid, nbytes, tag)
    frees: tuple[int, ...]
    report: list[PassStats] = field(default_factory=list)
    diagnostics: Any = None      # DiagnosticReport when analysis ran

    @property
    def n_dispatches(self) -> int:
        return len(self.steps)

    @property
    def n_kernels(self) -> int:
        return sum(1 for s in self.steps
                   if isinstance(s, ClusterStep) and s.kind == "pallas")

    def resolve(self, uid: int) -> int:
        while uid in self.alias:
            uid = self.alias[uid]
        return uid

    def run(self, env: dict[int, Any]) -> dict[int, Any]:
        """Execute into ``env`` (seeded with input values); returns the
        filled env — consts included, cluster intermediates omitted."""
        env.update(self.consts)
        for step in self.steps:
            if isinstance(step, OpStep):
                env[step.uid] = step.fn(*[env[d] for d in step.inputs])
            else:
                vals = step.fn(*[env[d] for d in step.inputs])
                for uid, v in zip(step.outputs, vals):
                    env[uid] = v
        return env

    def output_values(self, env: dict[int, Any]) -> list[Any]:
        return [env[self.resolve(o)] for o in self.outputs]

    def describe(self) -> dict:
        out = {"dispatches": self.n_dispatches,
               "pallas_kernels": self.n_kernels,
               "steps": [s.kind if isinstance(s, ClusterStep) else "op"
                         for s in self.steps],
               "clusters": [{"kind": s.cluster_kind, "lowering": s.kind,
                             "n_ops": s.n_ops}
                            for s in self.steps
                            if isinstance(s, ClusterStep)],
               "passes": [s.describe() for s in self.report]}
        if self.diagnostics is not None:
            out["diagnostics"] = self.diagnostics.counts()
        return out


def snapshot_logical(graph: Graph) -> list[tuple]:
    """Record the traced graph's logical structure *before* optimization,
    for the memory plan: ``(uid, inputs, nbytes, tag, is_input)``."""
    return [(uid, graph.nodes[uid].inputs, graph.nodes[uid].nbytes(),
             graph.nodes[uid].src_op, graph.nodes[uid].op == "input")
            for uid in graph.order]


def memory_plan(snapshot: list[tuple], graph: Graph
                ) -> tuple[tuple, tuple]:
    """Alloc/free schedule over *surviving* logical nodes.

    Computed from the pre-pass snapshot with the optimized graph's alias
    (CSE merges) and output liveness (DCE) applied — folding and fusion
    are execution strategies and must not change what the program
    logically allocates.  Exactly one alloc per surviving non-input node
    and at most one free per surviving node: a node is freed iff a *live*
    consumer uses it and it is not an output — so consumers merged by CSE
    or deleted by DCE can never double-count free events.
    """
    resolve = graph.resolve
    nodes: dict[int, tuple] = {}          # representative uid -> row
    inputs_of: dict[int, tuple[int, ...]] = {}
    order: list[int] = []
    for uid, inputs, nbytes, tag, is_input in snapshot:
        rep = resolve(uid)
        if rep in nodes:
            continue
        nodes[rep] = (nbytes, tag, is_input)
        inputs_of[rep] = tuple(resolve(d) for d in inputs)
        order.append(rep)
    out_set = {resolve(o) for o in graph.outputs}
    live: set[int] = set()
    stack = list(out_set)
    while stack:
        uid = stack.pop()
        if uid in live or uid not in nodes:
            continue
        live.add(uid)
        stack.extend(inputs_of[uid])
    consumed: set[int] = set()
    for uid in order:
        if uid in live:
            consumed.update(d for d in inputs_of[uid] if d != uid)
    allocs = []
    frees = []
    for uid in order:
        nbytes, tag, is_input = nodes[uid]
        if is_input or uid not in live:
            continue
        allocs.append((uid, nbytes, tag))
        if uid in consumed and uid not in out_set:
            frees.append(uid)
    return tuple(allocs), tuple(frees)


def lower(graph: Graph, policy: Any, report: list[PassStats] | None = None,
          interpret: bool | None = None,
          plan: tuple | None = None) -> Executable:
    """Lower an optimized graph under a ``CompilerPolicy``.

    ``plan`` is the ``memory_plan`` over the pre-pass snapshot; when
    absent (direct/testing use) it is derived from the optimized graph.
    """
    from repro import obs

    with obs.span("compiler.lower", "compiler",
                  nodes=len(graph.order)) as sp:
        exe = _lower(graph, policy, report, interpret=interpret, plan=plan)
        if sp is not None:
            sp.attrs.update({"dispatches": exe.n_dispatches,
                             "pallas_kernels": exe.n_kernels})
        return exe


def _lower(graph: Graph, policy: Any, report: list[PassStats] | None = None,
           interpret: bool | None = None,
           plan: tuple | None = None) -> Executable:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    consts = {uid: graph.nodes[uid].value for uid in graph.order
              if graph.nodes[uid].op == "const"}

    # schedule over the *condensed* graph (clusters contracted to one
    # unit): a cluster executes atomically, so it runs only once every
    # external input is available — member order in `graph.order` can
    # interleave with outside producers.  Fusion legality guarantees the
    # condensed graph is acyclic, so Kahn's algorithm always completes.
    unit_of: dict[int, tuple] = {}
    unit_order: list[tuple] = []
    seen_units: set[tuple] = set()
    for uid in graph.order:
        node = graph.nodes[uid]
        if node.op in ("input", "const"):
            continue
        unit = (("c", node.cluster) if node.cluster is not None
                else ("n", uid))
        unit_of[uid] = unit
        if unit not in seen_units:
            seen_units.add(unit)
            unit_order.append(unit)
    unit_deps: dict[tuple, set[tuple]] = {u: set() for u in unit_order}
    for uid, unit in unit_of.items():
        for d in graph.nodes[uid].inputs:
            dep_unit = unit_of.get(d)
            if dep_unit is not None and dep_unit != unit:
                unit_deps[unit].add(dep_unit)
    scheduled: set[tuple] = set()
    schedule: list[tuple] = []
    pending = list(unit_order)
    while pending:
        progress = False
        remaining = []
        for u in pending:
            if unit_deps[u] <= scheduled:
                schedule.append(u)
                scheduled.add(u)
                progress = True
            else:
                remaining.append(u)
        pending = remaining
        if pending and not progress:
            raise AssertionError(
                "cycle in condensed graph — illegal fusion partition")

    steps: list[Any] = []
    for kind_tag, ident in schedule:
        if kind_tag == "n":
            node = graph.nodes[ident]
            steps.append(OpStep(ident, node.inputs, node.fn, node.op))
            continue
        cl = graph.clusters[ident]
        members = [graph.nodes[m] for m in cl.node_ids]
        ins = [graph.nodes[i] for i in cl.inputs]
        outs = [graph.nodes[o] for o in cl.outputs]
        if policy.lowering == "eager":
            fn = cluster_kernels.make_body(members, cl.inputs, cl.outputs)
            kind = "eager"
        elif policy.lowering != "auto":
            fn = cluster_kernels.build_jit_cluster(members, ins, outs)
            kind = "jit"
        elif cl.kind == "attention":
            # templated flash-attention; per-cluster jit when the tile
            # contract doesn't hold
            if cluster_kernels.attention_supported(
                    ins, cl.meta, on_tpu=not interpret):
                fn = cluster_kernels.build_attention_cluster(
                    ins, outs, cl.meta, interpret=interpret)
                kind = "pallas"
            else:
                fn = cluster_kernels.build_jit_cluster(members, ins, outs)
                kind = "jit"
        elif cl.kind == "epilogue":
            # the matcher only claims cones whose tiling plan validated
            fn = cluster_kernels.build_epilogue_cluster(
                members, ins, outs, cl.meta, interpret=interpret)
            kind = "pallas"
        elif cluster_kernels.pallas_supported(
                members, ins, on_tpu=not interpret):
            fn = cluster_kernels.build_cluster_kernel(
                members, ins, outs, interpret=interpret)
            kind = "pallas"
        else:
            fn = cluster_kernels.build_jit_cluster(members, ins, outs)
            kind = "jit"
        steps.append(ClusterStep(fn, cl.inputs, cl.outputs, kind,
                                 n_ops=len(cl.node_ids),
                                 cluster_kind=cl.kind))
    allocs, frees = plan if plan is not None else memory_plan(
        snapshot_logical(graph), graph)
    return Executable(steps, consts, graph.inputs, graph.outputs,
                      dict(graph.alias), allocs, frees,
                      report=list(report or []))
