"""Public compiler API: ``repro.compile`` and the pipeline entry point.

``compile(fn)`` turns a function written against ``repro.core.tensor.ops``
into a compiled callable: the call is traced once per input signature
under a private lazy backend, optimized by the session's (or an explicit)
``CompilerPolicy`` pipeline, lowered to generated Pallas cluster kernels
(+ jit fallbacks), and cached — subsequent calls with the same shapes and
dtypes replay the compiled program directly.

    @repro.compile
    def f(x, y):
        return ops.tanh(ops.add(ops.mul(x, y), x))

    f(a, b)          # trace + optimize + lower
    f(a2, b2)        # cache hit: no tracing, reuses generated kernels

Concrete arrays that enter the graph mid-trace (closed-over ``jnp``
values, or results computed eagerly inside ``fn`` — ``ops.top_k``, a
nested ``materialize``) make the call *uncacheable*: it stays correct but
re-traces every time, because replaying such a value from the cache could
pin first-call results.  Constants built through ``ops`` (``ops.full``
etc.) trace as graph nodes and cache fine.  Graphs with opaque nodes
(e.g. random ops) likewise recompile on every call.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.runtime import CompilerPolicy, current_session, session

from . import graph as graph_mod
from . import lowering as lowering_mod
from .lowering import Executable, lower
from .passes import PassManager, PassStats


def optimize(graph: graph_mod.Graph, policy: CompilerPolicy
             ) -> list[PassStats]:
    """Run the policy's pass pipeline over ``graph`` in place."""
    return PassManager.from_policy(policy).run(graph)


def compile_graph(graph: graph_mod.Graph, policy: CompilerPolicy,
                  interpret: bool | None = None,
                  analysis: Any | None = None) -> Executable:
    """Optimize + lower a traced graph in one step.

    The telemetry memory plan is computed from the pre-pass logical
    structure (see :func:`repro.compiler.lowering.memory_plan`) so CSE/DCE
    shrink it but folding/fusion — execution strategies — do not.

    ``analysis`` (an :class:`~repro.runtime.AnalysisPolicy`) runs the
    static verifier over the result: at ``"strict"`` additionally between
    every pass and over the lowered step schedule + memory plan.  Findings
    at/above the policy's threshold raise
    :class:`~repro.analysis.AnalysisError`; the full report (including
    non-fatal lint) is attached as ``exe.diagnostics``.
    """
    from repro import obs

    with obs.span("compiler.compile", "compiler", nodes=len(graph.order)):
        snapshot = lowering_mod.snapshot_logical(graph)
        if analysis is not None and analysis.enabled:
            verify = analysis if analysis.strict else None
            report = PassManager.from_policy(policy).run(graph, verify=verify)
        else:
            report = optimize(graph, policy)
        plan = lowering_mod.memory_plan(snapshot, graph)
        exe = lower(graph, policy, report, interpret=interpret, plan=plan)
        if analysis is not None and analysis.enabled:
            from repro.analysis.suite import analyze_graph

            with obs.span("compiler.analyze", "compiler",
                          level=analysis.level):
                diags = analyze_graph(graph, analysis, exe=exe)
            exe.diagnostics = diags
            diags.raise_if_errors(analysis.error_threshold)
        return exe


def describe_report(report: list[PassStats], exe: Executable | None = None
                    ) -> dict:
    """JSON-able pipeline provenance (what ``Session.describe()`` embeds)."""
    out: dict[str, Any] = {"passes": [s.describe() for s in report]}
    if exe is not None:
        desc = exe.describe()
        out["dispatches"] = desc["dispatches"]
        out["pallas_kernels"] = desc["pallas_kernels"]
        out["clusters"] = desc["clusters"]
    return out


class CompiledFunction:
    """The callable ``repro.compile`` returns; one cache entry per input
    signature (shapes/dtypes of positional args + static kwargs)."""

    def __init__(self, fn: Callable, policy: CompilerPolicy | None = None,
                 check: str | None = None) -> None:
        self.fn = fn
        self.policy = policy
        self.check = check
        self._cache: dict[tuple, tuple] = {}
        self.trace_count = 0
        self.last_executable: Executable | None = None
        self.__name__ = getattr(fn, "__name__", "compiled")
        self.__doc__ = getattr(fn, "__doc__", None)
        if check is not None:
            # validate eagerly so a typo'd level fails at decoration time
            from repro.runtime import AnalysisPolicy

            AnalysisPolicy(level=check)

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def _policy(self) -> CompilerPolicy:
        return self.policy or current_session().compiler

    def _analysis(self) -> Any:
        base = current_session().analysis
        if self.check is None:
            return base
        return base.replace(level=self.check)

    def _key(self, args: tuple[Any, ...], kwargs: dict[str, Any]) -> tuple:
        sig = []
        for a in args:
            arr = jnp.asarray(a)
            sig.append((tuple(arr.shape), str(arr.dtype)))
        kw = tuple(sorted(kwargs.items()))
        try:
            hash(kw)
        except TypeError:
            raise TypeError(
                "repro.compile: keyword arguments must be hashable statics "
                "(they are part of the program cache key); pass arrays as "
                "positional arguments instead") from None
        # the analysis policy is part of the key: a program cached with
        # checks off must not satisfy a strict-session call unverified
        return (tuple(sig), kw, self._policy(), self._analysis())

    def _trace(self, args: tuple[Any, ...], kwargs: dict[str, Any],
               policy: CompilerPolicy, analysis: Any = None
               ) -> tuple[Executable, dict[int, int | None], dict[int, Any],
                          Any, bool]:
        from repro import obs
        from repro.core.tensor.lazy_backend import LazyBackend

        lb = LazyBackend()
        with obs.span("compiler.trace", "compiler",
                      fn=self.__name__), \
                session(backend=lb, compiler=policy):
            leaves = [lb._lift(jnp.asarray(a)) for a in args]
            # leaves minted from here on were created *during* the traced
            # call — if any of them ends up as a graph input, it is an
            # arg-dependent value computed eagerly mid-trace (ops.top_k,
            # a nested materialize, ...), and replaying it from the cache
            # would silently pin first-call results
            trace_watermark = lb._lift(jnp.zeros(())).uid
            out = self.fn(*leaves, **kwargs)
        out_flat, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: hasattr(x, "deps"))
        g, sources = graph_mod.trace(out_flat)
        self.trace_count += 1
        # map canonical input ids to arg positions (non-arg inputs are
        # captured constants: their trace-time value is replayed)
        by_lt_uid = {lt.uid: i for i, lt in enumerate(leaves)}
        arg_pos: dict[int, int | None] = {}
        captured: dict[int, Any] = {}
        mid_trace_capture = False
        for cid in g.inputs:
            src = sources[cid]
            pos = by_lt_uid.get(src.uid)
            arg_pos[cid] = pos
            if pos is None:
                captured[cid] = src.value
                mid_trace_capture |= src.uid > trace_watermark
        cacheable = (policy.cache_programs and not mid_trace_capture
                     and g.signature() is not None)
        exe = compile_graph(g, policy, analysis=analysis)
        return exe, arg_pos, captured, treedef, cacheable

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        from repro import obs

        tracer = obs.get_tracer()
        policy = self._policy()
        key = self._key(args, kwargs)
        entry = self._cache.get(key)
        if entry is None:
            if tracer is not None:
                tracer.metrics.counter("compiler.program_cache_miss").add()
            exe, arg_pos, captured, treedef, cacheable = self._trace(
                args, kwargs, policy, self._analysis())
            if cacheable:
                self._cache[key] = (exe, arg_pos, captured, treedef)
        else:
            exe, arg_pos, captured, treedef = entry
            if tracer is not None:
                tracer.metrics.counter("compiler.program_cache_hit").add()
        self.last_executable = exe
        env: dict[int, Any] = {}
        for cid in exe.inputs:
            pos = arg_pos.get(cid)
            env[cid] = (jnp.asarray(args[pos]) if pos is not None
                        else captured[cid])
        if tracer is None:
            outs = exe.output_values(exe.run(env))
        else:
            with tracer.span("compiler.execute", "compiler",
                             fn=self.__name__,
                             dispatches=exe.n_dispatches):
                outs = exe.output_values(exe.run(env))
        return jax.tree_util.tree_unflatten(treedef, outs)


def compile(fn: Callable | None = None, *,  # noqa: A001 - torch.compile idiom
            policy: CompilerPolicy | None = None,
            check: str | None = None
            ) -> "CompiledFunction | Callable[[Callable], CompiledFunction]":
    """Decorator: compile ``fn`` through the graph-IR pipeline.

    ``policy=None`` picks up the active session's ``CompilerPolicy`` at
    call time (so ``with repro.session(compiler=...)`` swaps the pipeline
    without retouching the function).

    ``check`` overrides the static-analysis level for this function only:
    ``"off"`` / ``"default"`` / ``"strict"`` (see
    :class:`repro.runtime.AnalysisPolicy`).  ``None`` inherits the active
    session's level; the session's other analysis knobs (VMEM budget)
    apply either way.
    """
    if fn is None:
        return lambda f: CompiledFunction(f, policy, check)
    return CompiledFunction(fn, policy, check)
