"""Graph rewrite passes + the :class:`PassManager` that sequences them.

Every pass reports node/edge deltas (:class:`PassStats`) so a pipeline run
is a provenance artifact: ``Session.describe()`` embeds the last report,
and ``benchmarks/bench_fusion.py`` charts per-pass reductions.

Built-in passes (registered in :data:`PASS_REGISTRY`):

``cse``       common-subexpression elimination — merges pure nodes with
              equal ``(op, attrs, inputs)``; merged uids land in
              ``graph.alias`` so live ``LazyTensor`` handles still resolve
              to the surviving value.
``fold``      constant folding — precomputes pure nodes whose inputs are
              all compile-time constants (creation ops like ``full``/
              ``iota`` qualify vacuously), bounded by ``fold_size_limit``.
``dce``       dead-code elimination — drops nodes unreachable from the
              outputs.  ``input`` nodes are kept: they are the program's
              calling convention.
``attention`` pattern matcher — recognizes ``act(scale·(q@kᵀ) + bias) @ v``
              subgraphs written in plain ops (softmax or sigmoid
              activation; optional uniform-const scale; optional additive
              mask/ALiBi bias) and claims them as ``attention`` clusters,
              lowered to the parameterized flash-attention template with a
              per-cluster ``jax.jit`` fallback when tile contracts fail.
``epilogue``  matmul epilogue fusion — folds elementwise / last-axis-
              reduction consumers of a 2-D matmul (bias add, activations,
              rmsnorm) into an ``epilogue`` cluster lowered as one fused
              matmul kernel.  Claims a cone only when the fused kernel's
              tiling contract holds; otherwise leaves the region to
              ``fuse``.
``fuse``      cluster fusion — partitions the *unclaimed* remainder into
              elementwise/reduction regions (``graph.clusters``) lowered
              to one generated kernel each; cycle-safety is checked with
              ancestor/descendant bitsets.

Matcher passes run before ``fuse``: they claim subgraphs by setting
``node.cluster``, and ``fuse`` only partitions nodes still unclaimed —
matcher clusters are preserved, never dissolved or merged.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from .graph import (Cluster, ELEMENTWISE_OPS, FUSABLE_OPS, Graph,
                    IMPURE_OPS, Node, REDUCTION_OPS)


@dataclass
class PassStats:
    """Node/edge deltas one pass produced, plus pass-specific extras."""

    name: str
    nodes_before: int
    nodes_after: int
    edges_before: int
    edges_after: int
    extra: dict[str, Any] = field(default_factory=dict)

    @property
    def nodes_removed(self) -> int:
        return self.nodes_before - self.nodes_after

    def describe(self) -> dict:
        return {"pass": self.name,
                "nodes": [self.nodes_before, self.nodes_after],
                "edges": [self.edges_before, self.edges_after],
                **self.extra}


class Pass:
    name = "pass"

    def run(self, graph: Graph) -> dict[str, Any]:
        """Rewrite ``graph`` in place; return pass-specific stats."""
        raise NotImplementedError


class CSEPass(Pass):
    """Merge structurally-identical pure nodes.

    Safe only for nodes whose behavior is fully captured by
    ``(op, attrs)``: opaque nodes (``attrs is None``), impure ops, and
    ``input`` nodes are never merged.  ``const`` nodes merge by their
    originating op+attrs (kept through folding), never by array content.
    """

    name = "cse"

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        seen: dict[tuple, int] = {}
        merged = 0
        for uid in list(graph.order):
            node = graph.nodes[uid]
            node.inputs = tuple(graph.resolve(d) for d in node.inputs)
            if (node.op == "input" or node.attrs is None
                    or node.src_op in IMPURE_OPS):
                continue
            key = (node.op, node.src_op, node.attrs, node.inputs)
            rep = seen.setdefault(key, uid)
            if rep != uid:
                graph.remove(uid, replacement=rep)
                merged += 1
        graph.outputs = tuple(graph.resolve(o) for o in graph.outputs)
        return {"merged": merged}


class ConstantFoldPass(Pass):
    """Precompute pure nodes over compile-time constants.

    A node folds when it is non-opaque, pure, every input is a ``const``,
    and its output is at most ``size_limit`` elements.  Folded nodes keep
    their original op in ``src_op`` (telemetry tags stay meaningful) and
    an attrs key derived from it (so CSE can still merge equal constants).
    """

    name = "fold"

    def __init__(self, size_limit: int = 1 << 16) -> None:
        self.size_limit = size_limit

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        folded = 0
        for uid in graph.order:
            node = graph.nodes[uid]
            if (node.op in ("input", "const") or node.attrs is None
                    or node.src_op in IMPURE_OPS
                    or node.size > self.size_limit):
                continue
            ins = [graph.nodes[d] for d in node.inputs]
            if not all(n.op == "const" and n.attrs is not None for n in ins):
                continue
            assert node.fn is not None
            node.value = node.fn(*[n.value for n in ins])
            node.attrs = (node.op, node.attrs,
                          tuple(n.attrs for n in ins))
            node.op, node.fn, node.inputs = "const", None, ()
            folded += 1
        return {"folded": folded}


class DCEPass(Pass):
    """Drop nodes unreachable from the outputs (inputs are kept — they
    are the program interface, and dropping them would renumber the
    caller's argument mapping)."""

    name = "dce"

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        live: set[int] = set(graph.inputs)
        stack = [graph.resolve(o) for o in graph.outputs]
        while stack:
            uid = stack.pop()
            if uid in live:
                continue
            live.add(uid)
            stack.extend(d for d in graph.nodes[uid].inputs if d not in live)
        removed = 0
        for uid in list(graph.order):
            if uid not in live:
                graph.remove(uid)
                removed += 1
        return {"removed": removed}


# -- matcher helpers ---------------------------------------------------------


def _uniform_scalar(node: Node) -> float | None:
    """The scalar a ``full`` / uniform ``const`` node carries, or None."""
    if node.op == "full" and node.attrs and len(node.attrs) >= 2:
        try:
            return float(node.attrs[1])
        except (TypeError, ValueError):
            return None
    if node.op == "const" and node.value is not None:
        v = np.asarray(node.value)
        if v.size == 0:
            return None
        flat = v.reshape(-1)
        if not bool((flat == flat[0]).all()):
            return None
        try:
            return float(flat[0])
        except (TypeError, ValueError):
            return None
    return None


def _last_axis_reduction(node: Node) -> bool:
    """True for a keepdims reduction over the last axis."""
    if node.op not in REDUCTION_OPS or not node.attrs:
        return False
    if len(node.attrs) != 2:
        return False
    axis, keepdims = node.attrs
    rank = len(node.shape)
    return (bool(keepdims) and axis is not None
            and isinstance(axis, int) and axis % max(rank, 1) == rank - 1)


def _cluster_kind_counts(graph: Graph) -> dict[str, int]:
    kinds: dict[str, int] = {}
    for cl in graph.clusters:
        kinds[cl.kind] = kinds.get(cl.kind, 0) + 1
    return kinds


def _claim_cluster(graph: Graph, members: set[int], outputs: tuple[int, ...],
                   kind: str, meta: dict[str, Any]) -> Cluster:
    """Append a matcher cluster: members in topo order, external inputs
    in first-use order, ``node.cluster`` stamped."""
    cid = len(graph.clusters)
    node_ids = tuple(u for u in graph.order if u in members)
    ext: list[int] = []
    for u in node_ids:
        for d in graph.nodes[u].inputs:
            if d not in members and d not in ext:
                ext.append(d)
    for u in node_ids:
        graph.nodes[u].cluster = cid
    cl = Cluster(cid, node_ids, tuple(ext), outputs, kind=kind, meta=meta)
    graph.clusters.append(cl)
    return cl


class AttentionMatchPass(Pass):
    """Recognize ``act(scale·(q@kᵀ) + bias) @ v`` subgraphs.

    Matched variants (all written in plain ops, see ``ops.softmax`` /
    ``ops.sigmoid`` for the compositions this walks):

    * softmax attention, with or without the max-subtraction shift;
    * sigmoid attention (``1 / (1 + exp(-s))`` over the scores);
    * an optional uniform-constant scale (``mul``/``div``) on the scores;
    * an optional additive bias — custom masks, ALiBi slopes — applied
      before or after the scale (the relative ordering is folded into a
      static ``bias_scale``);
    * ``q @ transpose(k)`` with the transpose absorbed, or a rhs already
      laid out ``[..., D, Sk]``.

    A match is claimed only when every interior node is consumed solely
    inside the pattern (the cluster is a sink-cone, so contracting it can
    never create a cycle).  The cluster's ``meta`` records the role of
    each external input (q/k/v/bias), the static scale(s), and the
    activation mode — everything the template lowering needs.
    """

    name = "attention"

    #: bound on scale/bias peeling, so a malformed chain cannot loop.
    _MAX_PEEL = 32

    def run(self, graph: Graph) -> dict[str, Any]:
        consumers = graph.consumers()
        out_set = {graph.resolve(o) for o in graph.outputs}
        matched = 0
        for uid in list(graph.order):
            node = graph.nodes[uid]
            if node.op != "matmul" or node.cluster is not None:
                continue
            found = self._match(graph, node, consumers, out_set)
            if found is None:
                continue
            members, meta = found
            self._absorb_consts(graph, members, meta, consumers, out_set)
            if any(graph.nodes[u].cluster is not None for u in members):
                continue
            _claim_cluster(graph, members, (uid,), "attention", meta)
            matched += 1
        return {"matched": matched,
                "cluster_kinds": _cluster_kind_counts(graph)}

    @staticmethod
    def _absorb_consts(graph: Graph, members: set[int], meta: dict[str, Any],
                       consumers: dict[int, list[int]],
                       out_set: set[int]) -> None:
        """Pull peeled uniform ``full`` constants into the cluster.

        The template ignores them (their scalar lives in ``meta`` as a
        static scale), and the jit fallback replays their zero-input
        ``fn`` inside the body — but left external, a score-shaped
        constant too large for the folder would keep a full-shape
        materialization dispatch alive just to feed an ignored operand.
        """
        roles = {meta["q"], meta["k"], meta["v"], meta["bias"]}
        ext = {d for u in members for d in graph.nodes[u].inputs
               if d not in members}
        for d in ext:
            dn = graph.nodes[d]
            if (dn.op == "full" and dn.cluster is None and d not in roles
                    and d not in out_set
                    and all(c in members for c in consumers[d])):
                members.add(d)

    # -- pattern walk -------------------------------------------------------

    def _match(self, graph: Graph, out_mm: Node,
               consumers: dict[int, list[int]], out_set: set[int]
               ) -> tuple[set[int], dict[str, Any]] | None:
        nodes = graph.nodes
        if len(out_mm.inputs) != 2:
            return None
        p_uid, v_uid = out_mm.inputs
        p = nodes[p_uid]
        members: set[int] = {out_mm.uid}
        meta: dict[str, Any] = {}

        scores_uid = self._match_activation(graph, p, members, meta)
        if scores_uid is None:
            return None

        peeled = self._peel_scores(graph, scores_uid, members)
        if peeled is None:
            return None
        qk_uid, scale, bias_uid, bias_scale = peeled
        members.add(qk_uid)
        qk = nodes[qk_uid]
        if len(qk.inputs) != 2:
            return None
        q_uid, kt_uid = qk.inputs

        # absorb a trailing last-two-axes transpose of k when it feeds
        # only this matmul; otherwise the rhs is taken as pre-transposed
        k_uid, k_layout = kt_uid, "kT"
        kt = nodes[kt_uid]
        if (kt.op == "transpose" and kt.cluster is None
                and kt_uid not in out_set
                and all(c == qk_uid for c in consumers[kt_uid])
                and self._is_last_two_swap(kt)):
            members.add(kt_uid)
            k_uid, k_layout = kt.inputs[0], "std"

        if not self._shapes_ok(graph, q_uid, k_uid, v_uid, bias_uid,
                               k_layout, qk, out_mm):
            return None
        # role inputs must stay external to the cluster
        if any(u in members for u in (q_uid, k_uid, v_uid)
               ) or (bias_uid is not None and bias_uid in members):
            return None
        # interior nodes must be consumed only inside the pattern, and the
        # sink must actually escape (else the region is dead code)
        for u in members:
            if u == out_mm.uid:
                continue
            if u in out_set or any(c not in members for c in consumers[u]):
                return None
        if not (out_mm.uid in out_set
                or any(c not in members for c in consumers[out_mm.uid])):
            return None

        meta.update(q=q_uid, k=k_uid, v=v_uid, bias=bias_uid,
                    scale=scale, bias_scale=bias_scale, k_layout=k_layout)
        return members, meta

    def _match_activation(self, graph: Graph, p: Node, members: set[int],
                          meta: dict[str, Any]) -> int | None:
        """Match softmax/sigmoid over the scores; returns the scores uid."""
        nodes = graph.nodes
        if p.op != "div" or len(p.inputs) != 2:
            return None
        a_uid, b_uid = p.inputs
        a, b = nodes[a_uid], nodes[b_uid]

        if a.op == "exp" and b.op == "sum":
            # softmax: div(exp(t), sum(exp(t), -1, keepdims=True))
            if b.inputs != (a_uid,) or not _last_axis_reduction(b):
                return None
            members |= {p.uid, a_uid, b_uid}
            t_uid = a.inputs[0]
            t = nodes[t_uid]
            shifted = False
            scores_uid = t_uid
            if t.op == "sub" and len(t.inputs) == 2:
                s_uid, r_uid = t.inputs
                r = nodes[r_uid]
                chain = [r_uid]
                if r.op == "stop_gradient" and len(r.inputs) == 1:
                    chain.append(r.inputs[0])
                    r = nodes[r.inputs[0]]
                if (r.op == "max" and _last_axis_reduction(r)
                        and r.inputs == (s_uid,)):
                    members |= {t_uid, *chain}
                    shifted, scores_uid = True, s_uid
            meta["mode"], meta["shifted"] = "softmax", shifted
            return scores_uid

        if b.op == "add" and len(b.inputs) == 2 \
                and _uniform_scalar(a) == 1.0:
            # sigmoid: div(1, add(1, exp(neg(s)))) — either add order
            c_uid, g_uid = b.inputs
            if _uniform_scalar(nodes[c_uid]) != 1.0:
                c_uid, g_uid = g_uid, c_uid
            g = nodes[g_uid]
            if _uniform_scalar(nodes[c_uid]) != 1.0 or g.op != "exp":
                return None
            ng = nodes[g.inputs[0]]
            if ng.op != "neg":
                return None
            members |= {p.uid, b.uid, g_uid, ng.uid}
            meta["mode"], meta["shifted"] = "sigmoid", False
            return ng.inputs[0]
        return None

    def _peel_scores(self, graph: Graph, scores_uid: int, members: set[int]
                     ) -> tuple[int, float, int | None, float] | None:
        """Walk scores → matmul through const scales and one bias add.

        Returns ``(qk_uid, scale, bias_uid, bias_scale)`` where the
        matched region computes ``scale·(q@kᵀ) + bias_scale·bias``.
        """
        nodes = graph.nodes
        memo: dict[int, bool] = {}

        def reaches(uid: int, depth: int = 0) -> bool:
            if uid in memo:
                return memo[uid]
            out = False
            n = nodes[uid]
            if depth > self._MAX_PEEL:
                out = False
            elif n.op == "matmul":
                out = True
            elif n.op in ("mul", "div") and len(n.inputs) == 2:
                x, y = n.inputs
                if _uniform_scalar(nodes[y]) is not None:
                    out = reaches(x, depth + 1)
                elif n.op == "mul" and _uniform_scalar(nodes[x]) is not None:
                    out = reaches(y, depth + 1)
            elif n.op == "add" and len(n.inputs) == 2:
                x, y = n.inputs
                # exactly one side may continue toward the matmul
                out = reaches(x, depth + 1) != reaches(y, depth + 1)
            memo[uid] = out
            return out

        outer = 1.0
        bias_uid: int | None = None
        bias_scale = 1.0
        cur = scores_uid
        for _ in range(self._MAX_PEEL):
            n = nodes[cur]
            if n.op == "matmul":
                return cur, outer, bias_uid, bias_scale
            if n.op in ("mul", "div") and len(n.inputs) == 2:
                x_uid, y_uid = n.inputs
                cy = _uniform_scalar(nodes[y_uid])
                cx = _uniform_scalar(nodes[x_uid])
                if cy is not None and n.op == "div":
                    if cy == 0.0 or not reaches(x_uid):
                        return None
                    outer /= cy
                    members.add(cur)
                    cur = x_uid
                    continue
                if cy is not None and reaches(x_uid):
                    outer *= cy
                    members.add(cur)
                    cur = x_uid
                    continue
                if n.op == "mul" and cx is not None and reaches(y_uid):
                    outer *= cx
                    members.add(cur)
                    cur = y_uid
                    continue
                return None
            if n.op == "add" and len(n.inputs) == 2 and bias_uid is None:
                x_uid, y_uid = n.inputs
                rx, ry = reaches(x_uid), reaches(y_uid)
                if rx == ry:            # neither, or ambiguous
                    return None
                chain, bias = (x_uid, y_uid) if rx else (y_uid, x_uid)
                bias_uid, bias_scale = bias, outer
                members.add(cur)
                cur = chain
                continue
            return None
        return None

    @staticmethod
    def _is_last_two_swap(t: Node) -> bool:
        rank = len(t.shape)
        if rank < 2 or not t.attrs:
            return False
        axes = t.attrs[0]
        if axes is None:
            return rank == 2
        want = tuple(range(rank - 2)) + (rank - 1, rank - 2)
        return tuple(axes) == want

    @staticmethod
    def _shapes_ok(graph: Graph, q_uid: int, k_uid: int, v_uid: int,
                   bias_uid: int | None, k_layout: str, qk: Node,
                   out_mm: Node) -> bool:
        nodes = graph.nodes
        q, k, v = nodes[q_uid], nodes[k_uid], nodes[v_uid]
        rank = len(q.shape)
        if rank < 2 or len(k.shape) != rank or len(v.shape) != rank:
            return False
        lead = q.shape[:-2]
        if k.shape[:-2] != lead or v.shape[:-2] != lead:
            return False
        sq, d = q.shape[-2], q.shape[-1]
        if k_layout == "std":
            sk, dk = k.shape[-2], k.shape[-1]
        else:
            dk, sk = k.shape[-2], k.shape[-1]
        sv, dv = v.shape[-2], v.shape[-1]
        if dk != d or sv != sk:
            return False
        if qk.shape != lead + (sq, sk):      # batched-broadcast matmul
            return False
        if out_mm.shape != lead + (sq, dv):
            return False
        for n in (q, k, v, out_mm):
            if not np.issubdtype(np.dtype(n.dtype), np.floating):
                return False
        if bias_uid is not None:
            bshape = nodes[bias_uid].shape
            if len(bshape) > rank:
                return False
            target = lead + (sq, sk)
            for bdim, tdim in zip(reversed(bshape), reversed(target)):
                if bdim != 1 and bdim != tdim:
                    return False
        return True


class EpilogueFusionPass(Pass):
    """Fold a 2-D matmul's consumer cone into an ``epilogue`` cluster.

    Grows a cone of elementwise / last-axis-keepdims-reduction consumers
    downstream of each unclaimed 2-D matmul (bias adds, activations,
    rmsnorm chains); every absorbed node's inputs must be inside the cone
    or independent of the matmul (not its descendants), which makes the
    region atomic by construction.  The cone is claimed only when the
    fused kernel's contract holds (single escaping sink of the matmul's
    shape, tileable operand shapes, reductions row-complete — checked by
    :func:`repro.kernels.matmul.plan_epilogue`); first with reductions
    included, then elementwise-only, else the region is left to ``fuse``.
    """

    name = "epilogue"

    #: ops an epilogue cone may absorb.  ``broadcast_to`` is excluded —
    #: its static target shape is per-array, not per-tile, so it would
    #: compute the wrong thing inside a tiled kernel.
    _EPILOGUE_OPS = (ELEMENTWISE_OPS | {"stop_gradient"})

    def run(self, graph: Graph) -> dict[str, Any]:
        import jax

        on_tpu = jax.default_backend() == "tpu"
        nodes = graph.nodes
        consumers = graph.consumers()
        out_set = {graph.resolve(o) for o in graph.outputs}
        fused = 0
        for uid in list(graph.order):
            mm = nodes[uid]
            if (mm.op != "matmul" or mm.cluster is not None
                    or len(mm.shape) != 2 or len(mm.inputs) != 2):
                continue
            if any(len(nodes[d].shape) != 2 for d in mm.inputs):
                continue
            desc = self._descendants(uid, consumers)
            for allow_reductions in (True, False):
                members = self._grow(graph, uid, desc, allow_reductions)
                if len(members) < 2:
                    break
                meta = self._plan(graph, uid, members, consumers, out_set,
                                  on_tpu)
                if meta is not None:
                    _claim_cluster(graph, members, (meta["sink"],),
                                   "epilogue", meta)
                    fused += 1
                    break
        return {"fused": fused,
                "cluster_kinds": _cluster_kind_counts(graph)}

    @staticmethod
    def _descendants(uid: int, consumers: dict[int, list[int]]) -> set[int]:
        desc: set[int] = set()
        stack = [uid]
        while stack:
            u = stack.pop()
            for c in consumers[u]:
                if c not in desc:
                    desc.add(c)
                    stack.append(c)
        return desc

    def _grow(self, graph: Graph, mm_uid: int, desc: set[int],
              allow_reductions: bool) -> set[int]:
        nodes = graph.nodes
        members = {mm_uid}
        changed = True
        while changed:
            changed = False
            for u in graph.order:
                if u in members:
                    continue
                n = nodes[u]
                if n.cluster is not None:
                    continue
                ok_op = n.op in self._EPILOGUE_OPS or (
                    allow_reductions and n.op in REDUCTION_OPS)
                if not ok_op:
                    continue
                if not any(d in members for d in n.inputs):
                    continue
                if any(d not in members and d in desc for d in n.inputs):
                    continue
                members.add(u)
                changed = True
        return members

    def _plan(self, graph: Graph, mm_uid: int, members: set[int],
              consumers: dict[int, list[int]], out_set: set[int],
              on_tpu: bool) -> dict[str, Any] | None:
        from repro.kernels.matmul import plan_epilogue

        nodes = graph.nodes
        escapes = [u for u in graph.order if u in members
                   and (u in out_set
                        or any(c not in members for c in consumers[u]))]
        if len(escapes) != 1 or escapes[0] == mm_uid:
            return None
        sink = escapes[0]
        mm = nodes[mm_uid]
        m, n = mm.shape
        if tuple(nodes[sink].shape) != (m, n):
            return None
        lhs_uid, rhs_uid = mm.inputs
        k = nodes[lhs_uid].shape[1]
        epi_ext: list[int] = []
        reductions: list[tuple[Any, bool, int]] = []
        for u in graph.order:
            if u not in members or u == mm_uid:
                continue
            node = nodes[u]
            for d in node.inputs:
                if d not in members and d != mm_uid and d not in epi_ext:
                    epi_ext.append(d)
            if node.op in REDUCTION_OPS:
                if not node.attrs or len(node.attrs) != 2:
                    return None
                axis, keepdims = node.attrs
                reductions.append((axis, bool(keepdims),
                                   len(nodes[node.inputs[0]].shape)))
        ext_shapes = [tuple(nodes[d].shape) for d in epi_ext]
        dtypes = [nodes[u].dtype for u in members] + \
                 [nodes[d].dtype for d in epi_ext] + \
                 [nodes[lhs_uid].dtype, nodes[rhs_uid].dtype]
        tiles = plan_epilogue(m=m, k=k, n=n, reductions=reductions,
                              extra_shapes=ext_shapes, dtypes=dtypes,
                              on_tpu=on_tpu)
        if tiles is None:
            return None
        bm, bn, bk = tiles
        return {"matmul": mm_uid, "lhs": lhs_uid, "rhs": rhs_uid,
                "sink": sink, "epi_ext": tuple(epi_ext),
                "bm": bm, "bn": bn, "bk": bk}


class FusionPass(Pass):
    """Partition unclaimed nodes into elementwise/reduction clusters.

    Greedy over topo order: each fusable node (elementwise ops, trailing
    reductions and their epilogues, ``stop_gradient``/``broadcast_to``)
    tries to join the union of its producers' clusters.  A merge is legal
    iff no path leaves the merged region and re-enters it (the region must
    execute atomically); checked with precomputed ancestor/descendant
    bitsets — ``bad = desc(region) & anc(region) & ~region``.  Clusters
    smaller than ``min_cluster_size`` are dissolved back to single
    dispatches.  Pre-existing matcher clusters (attention/epilogue) are
    preserved: their members are skipped, and the bitsets cover all nodes,
    so a region that would wrap around a matcher cluster is rejected.

    A cluster containing at least one reduction is tagged
    ``kind="reduction"``; pure elementwise regions stay ``elementwise``.
    """

    name = "fuse"

    def __init__(self, min_cluster_size: int = 2) -> None:
        self.min_cluster_size = min_cluster_size

    def run(self, graph: Graph) -> dict[str, Any]:
        order = graph.order
        idx = {uid: i for i, uid in enumerate(order)}
        consumers = graph.consumers()

        desc = {uid: 0 for uid in order}
        for uid in reversed(order):
            m = 0
            for c in consumers[uid]:
                m |= (1 << idx[c]) | desc[c]
            desc[uid] = m
        anc = {uid: 0 for uid in order}
        for uid in order:
            m = 0
            for d in graph.nodes[uid].inputs:
                m |= (1 << idx[d]) | anc[d]
            anc[uid] = m

        clusters: list[set[int]] = []
        cluster_of: dict[int, int] = {}

        def legal(members: set[int]) -> bool:
            mask = 0
            dm = 0
            am = 0
            for m in members:
                mask |= 1 << idx[m]
                dm |= desc[m]
                am |= anc[m]
            return (dm & am & ~mask) == 0

        for uid in order:
            node = graph.nodes[uid]
            if node.op not in FUSABLE_OPS or node.cluster is not None:
                continue
            cands = sorted({cluster_of[d] for d in node.inputs
                            if d in cluster_of})
            placed = False
            # try the full union first, then each producer cluster alone
            for group in ([cands] if len(cands) > 1 else []) + \
                         [[c] for c in cands]:
                members = {uid}
                for ci in group:
                    members |= clusters[ci]
                if legal(members):
                    tgt = group[0]
                    clusters[tgt] = members
                    for ci in group[1:]:
                        clusters[ci] = set()
                    for m in members:
                        cluster_of[m] = tgt
                    placed = True
                    break
            if not placed:
                cluster_of[uid] = len(clusters)
                clusters.append({uid})

        n_before = len(graph.clusters)
        out_set = set(graph.resolve(o) for o in graph.outputs)
        for members in clusters:
            if len(members) < self.min_cluster_size:
                continue
            cid = len(graph.clusters)
            node_ids = tuple(uid for uid in order if uid in members)
            ext_inputs: list[int] = []
            outputs: list[int] = []
            for uid in node_ids:
                graph.nodes[uid].cluster = cid
                for d in graph.nodes[uid].inputs:
                    if d not in members and d not in ext_inputs:
                        ext_inputs.append(d)
            for uid in node_ids:
                if (uid in out_set
                        or any(c not in members for c in consumers[uid])):
                    outputs.append(uid)
            kind = ("reduction"
                    if any(graph.nodes[u].op in REDUCTION_OPS
                           for u in node_ids) else "elementwise")
            graph.clusters.append(Cluster(cid, node_ids, tuple(ext_inputs),
                                          tuple(outputs), kind=kind))
        new = graph.clusters[n_before:]
        clustered = sum(len(c.node_ids) for c in new)
        return {"clusters": len(new),
                "clustered_nodes": clustered,
                "largest_cluster": max(
                    (len(c.node_ids) for c in new), default=0),
                "cluster_kinds": _cluster_kind_counts(graph)}


PASS_REGISTRY: dict[str, type[Pass]] = {
    "cse": CSEPass,
    "fold": ConstantFoldPass,
    "dce": DCEPass,
    "attention": AttentionMatchPass,
    "epilogue": EpilogueFusionPass,
    "fuse": FusionPass,
}


class PassManager:
    """Runs a pipeline of passes, collecting :class:`PassStats` per pass."""

    def __init__(self, passes: list[Pass]) -> None:
        self.passes = list(passes)

    @classmethod
    def from_policy(cls, policy: Any) -> "PassManager":
        passes: list[Pass] = []
        for name in policy.pipeline:
            if name not in PASS_REGISTRY:
                raise KeyError(f"unknown compiler pass {name!r}; "
                               f"known: {sorted(PASS_REGISTRY)}")
            if name == "fold":
                passes.append(ConstantFoldPass(policy.fold_size_limit))
            elif name == "fuse":
                passes.append(FusionPass(policy.min_cluster_size))
            else:
                passes.append(PASS_REGISTRY[name]())
        return cls(passes)

    def run(self, graph: Graph, *, verify: Any = None) -> list[PassStats]:
        """Run the pipeline; with ``verify`` (an
        :class:`~repro.runtime.AnalysisPolicy`) the structured IR
        verifier runs after every pass and raises
        :class:`~repro.analysis.AnalysisError` naming the pass that
        broke the invariant — a miscompile caught at the rewrite that
        introduced it, not at the numerics it corrupts."""
        from repro import obs

        tracer = obs.get_tracer()
        report: list[PassStats] = []
        for p in self.passes:
            nb, eb = len(graph.order), graph.n_edges()
            with obs.span(f"compiler.pass.{p.name}", "compiler") as sp:
                extra = p.run(graph)
                stats = PassStats(p.name, nb, len(graph.order),
                                  eb, graph.n_edges(), extra)
                if sp is not None:
                    sp.attrs.update(stats.describe())
            report.append(stats)
            if tracer is not None:
                tracer.metrics.counter("compiler.pass_runs").add()
            if verify is not None and verify.enabled:
                from repro.analysis.shapes import check_graph

                check_graph(graph, verify, where=f"after {p.name}") \
                    .raise_if_errors(verify.error_threshold,
                                     context=f"after pass {p.name!r}")
        return report
