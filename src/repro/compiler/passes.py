"""Graph rewrite passes + the :class:`PassManager` that sequences them.

Every pass reports node/edge deltas (:class:`PassStats`) so a pipeline run
is a provenance artifact: ``Session.describe()`` embeds the last report,
and ``benchmarks/bench_fusion.py`` charts per-pass reductions.

Built-in passes (registered in :data:`PASS_REGISTRY`):

``cse``   common-subexpression elimination — merges pure nodes with equal
          ``(op, attrs, inputs)``; merged uids land in ``graph.alias`` so
          live ``LazyTensor`` handles still resolve to the surviving value.
``fold``  constant folding — precomputes pure nodes whose inputs are all
          compile-time constants (creation ops like ``full``/``iota``
          qualify vacuously), bounded by ``fold_size_limit`` elements.
``dce``   dead-code elimination — drops nodes unreachable from the
          outputs (CSE leftovers, dead branches of traced functions).
          ``input`` nodes are kept: they are the program's calling
          convention.
``fuse``  elementwise-cluster fusion — partitions the graph into fusable
          regions (``graph.clusters``) lowered to one generated kernel
          each; cycle-safety is checked with ancestor/descendant bitsets.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from .graph import Cluster, ELEMENTWISE_OPS, Graph, IMPURE_OPS


@dataclass
class PassStats:
    """Node/edge deltas one pass produced, plus pass-specific extras."""

    name: str
    nodes_before: int
    nodes_after: int
    edges_before: int
    edges_after: int
    extra: dict[str, Any] = field(default_factory=dict)

    @property
    def nodes_removed(self) -> int:
        return self.nodes_before - self.nodes_after

    def describe(self) -> dict:
        return {"pass": self.name,
                "nodes": [self.nodes_before, self.nodes_after],
                "edges": [self.edges_before, self.edges_after],
                **self.extra}


class Pass:
    name = "pass"

    def run(self, graph: Graph) -> dict[str, Any]:
        """Rewrite ``graph`` in place; return pass-specific stats."""
        raise NotImplementedError


class CSEPass(Pass):
    """Merge structurally-identical pure nodes.

    Safe only for nodes whose behavior is fully captured by
    ``(op, attrs)``: opaque nodes (``attrs is None``), impure ops, and
    ``input`` nodes are never merged.  ``const`` nodes merge by their
    originating op+attrs (kept through folding), never by array content.
    """

    name = "cse"

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        seen: dict[tuple, int] = {}
        merged = 0
        for uid in list(graph.order):
            node = graph.nodes[uid]
            node.inputs = tuple(graph.resolve(d) for d in node.inputs)
            if (node.op == "input" or node.attrs is None
                    or node.src_op in IMPURE_OPS):
                continue
            key = (node.op, node.src_op, node.attrs, node.inputs)
            rep = seen.setdefault(key, uid)
            if rep != uid:
                graph.remove(uid, replacement=rep)
                merged += 1
        graph.outputs = tuple(graph.resolve(o) for o in graph.outputs)
        return {"merged": merged}


class ConstantFoldPass(Pass):
    """Precompute pure nodes over compile-time constants.

    A node folds when it is non-opaque, pure, every input is a ``const``,
    and its output is at most ``size_limit`` elements.  Folded nodes keep
    their original op in ``src_op`` (telemetry tags stay meaningful) and
    an attrs key derived from it (so CSE can still merge equal constants).
    """

    name = "fold"

    def __init__(self, size_limit: int = 1 << 16) -> None:
        self.size_limit = size_limit

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        folded = 0
        for uid in graph.order:
            node = graph.nodes[uid]
            if (node.op in ("input", "const") or node.attrs is None
                    or node.src_op in IMPURE_OPS
                    or node.size > self.size_limit):
                continue
            ins = [graph.nodes[d] for d in node.inputs]
            if not all(n.op == "const" and n.attrs is not None for n in ins):
                continue
            node.value = node.fn(*[n.value for n in ins])
            node.attrs = (node.op, node.attrs,
                          tuple(n.attrs for n in ins))
            node.op, node.fn, node.inputs = "const", None, ()
            folded += 1
        return {"folded": folded}


class DCEPass(Pass):
    """Drop nodes unreachable from the outputs (inputs are kept — they
    are the program interface, and dropping them would renumber the
    caller's argument mapping)."""

    name = "dce"

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        live: set[int] = set(graph.inputs)
        stack = [graph.resolve(o) for o in graph.outputs]
        while stack:
            uid = stack.pop()
            if uid in live:
                continue
            live.add(uid)
            stack.extend(d for d in graph.nodes[uid].inputs if d not in live)
        removed = 0
        for uid in list(graph.order):
            if uid not in live:
                graph.remove(uid)
                removed += 1
        return {"removed": removed}


class FusionPass(Pass):
    """Partition the graph into elementwise clusters.

    Greedy over topo order: each elementwise node tries to join the
    union of its producers' clusters.  A merge is legal iff no path
    leaves the merged region and re-enters it (the region must execute
    atomically); checked with precomputed ancestor/descendant bitsets —
    ``bad = desc(region) & anc(region) & ~region``.  Clusters smaller
    than ``min_cluster_size`` are dissolved back to single dispatches.
    """

    name = "fuse"

    def __init__(self, min_cluster_size: int = 2) -> None:
        self.min_cluster_size = min_cluster_size

    def run(self, graph: Graph) -> dict[str, Any]:
        graph.clear_clusters()
        order = graph.order
        idx = {uid: i for i, uid in enumerate(order)}
        consumers = graph.consumers()

        desc = {uid: 0 for uid in order}
        for uid in reversed(order):
            m = 0
            for c in consumers[uid]:
                m |= (1 << idx[c]) | desc[c]
            desc[uid] = m
        anc = {uid: 0 for uid in order}
        for uid in order:
            m = 0
            for d in graph.nodes[uid].inputs:
                m |= (1 << idx[d]) | anc[d]
            anc[uid] = m

        clusters: list[set[int]] = []
        cluster_of: dict[int, int] = {}

        def legal(members: set[int]) -> bool:
            mask = 0
            dm = 0
            am = 0
            for m in members:
                mask |= 1 << idx[m]
                dm |= desc[m]
                am |= anc[m]
            return (dm & am & ~mask) == 0

        for uid in order:
            node = graph.nodes[uid]
            if node.op not in ELEMENTWISE_OPS:
                continue
            cands = sorted({cluster_of[d] for d in node.inputs
                            if d in cluster_of})
            placed = False
            # try the full union first, then each producer cluster alone
            for group in ([cands] if len(cands) > 1 else []) + \
                         [[c] for c in cands]:
                members = {uid}
                for ci in group:
                    members |= clusters[ci]
                if legal(members):
                    tgt = group[0]
                    clusters[tgt] = members
                    for ci in group[1:]:
                        clusters[ci] = set()
                    for m in members:
                        cluster_of[m] = tgt
                    placed = True
                    break
            if not placed:
                cluster_of[uid] = len(clusters)
                clusters.append({uid})

        graph.clusters = []
        out_set = set(graph.resolve(o) for o in graph.outputs)
        for members in clusters:
            if len(members) < self.min_cluster_size:
                continue
            cid = len(graph.clusters)
            node_ids = tuple(uid for uid in order if uid in members)
            ext_inputs: list[int] = []
            outputs: list[int] = []
            for uid in node_ids:
                graph.nodes[uid].cluster = cid
                for d in graph.nodes[uid].inputs:
                    if d not in members and d not in ext_inputs:
                        ext_inputs.append(d)
            for uid in node_ids:
                if (uid in out_set
                        or any(c not in members for c in consumers[uid])):
                    outputs.append(uid)
            graph.clusters.append(Cluster(cid, node_ids, tuple(ext_inputs),
                                          tuple(outputs)))
        clustered = sum(len(c.node_ids) for c in graph.clusters)
        return {"clusters": len(graph.clusters),
                "clustered_nodes": clustered,
                "largest_cluster": max(
                    (len(c.node_ids) for c in graph.clusters), default=0)}


PASS_REGISTRY: dict[str, type[Pass]] = {
    "cse": CSEPass,
    "fold": ConstantFoldPass,
    "dce": DCEPass,
    "fuse": FusionPass,
}


class PassManager:
    """Runs a pipeline of passes, collecting :class:`PassStats` per pass."""

    def __init__(self, passes: list[Pass]) -> None:
        self.passes = list(passes)

    @classmethod
    def from_policy(cls, policy: Any) -> "PassManager":
        passes: list[Pass] = []
        for name in policy.pipeline:
            if name not in PASS_REGISTRY:
                raise KeyError(f"unknown compiler pass {name!r}; "
                               f"known: {sorted(PASS_REGISTRY)}")
            if name == "fold":
                passes.append(ConstantFoldPass(policy.fold_size_limit))
            elif name == "fuse":
                passes.append(FusionPass(policy.min_cluster_size))
            else:
                passes.append(PASS_REGISTRY[name]())
        return cls(passes)

    def run(self, graph: Graph, *, verify: Any = None) -> list[PassStats]:
        """Run the pipeline; with ``verify`` (an
        :class:`~repro.runtime.AnalysisPolicy`) the structured IR
        verifier runs after every pass and raises
        :class:`~repro.analysis.AnalysisError` naming the pass that
        broke the invariant — a miscompile caught at the rewrite that
        introduced it, not at the numerics it corrupts."""
        report: list[PassStats] = []
        for p in self.passes:
            nb, eb = len(graph.order), graph.n_edges()
            extra = p.run(graph)
            report.append(PassStats(p.name, nb, len(graph.order),
                                    eb, graph.n_edges(), extra))
            if verify is not None and verify.enabled:
                from repro.analysis.shapes import check_graph

                check_graph(graph, verify, where=f"after {p.name}") \
                    .raise_if_errors(verify.error_threshold,
                                     context=f"after pass {p.name!r}")
        return report
