"""repro.compiler — graph IR, pass manager, and Pallas cluster lowering.

The lazy backend's pending-op web, promoted to a first-class compiler
(paper §4.1.1's ArrayFire-JIT story as an open subsystem):

    trace()        LazyTensor stream  →  explicit SSA-style Graph
    PassManager    cse / fold / dce / attention / epilogue / fuse,
                   each reporting node deltas
    lower()        clusters by kind   →  generated Pallas kernels
                   (elementwise/reduction bodies, fused-epilogue matmul,
                   templated flash attention; interpret off-TPU,
                   per-cluster jit fallback)
    compile(fn)    the user-facing decorator over the whole pipeline

``repro.session(backend="lazy", compiler=CompilerPolicy(...))`` selects
the pipeline for every ``materialize``; ``python -m
repro.compiler.selfcheck`` round-trips the passes over a canned corpus
and fails on IR invariant violations.
"""

from repro.runtime import CompilerPolicy

from .api import CompiledFunction, compile, compile_graph, optimize
from .graph import (CLUSTER_KINDS, ELEMENTWISE_OPS, REDUCTION_OPS, Cluster,
                    Graph, Node, trace)
from .lowering import Executable, lower
from .passes import PASS_REGISTRY, PassManager, PassStats

__all__ = [
    "CompilerPolicy", "CompiledFunction", "compile", "compile_graph",
    "optimize", "Graph", "Node", "Cluster", "trace", "ELEMENTWISE_OPS",
    "REDUCTION_OPS", "CLUSTER_KINDS",
    "Executable", "lower", "PassManager", "PassStats", "PASS_REGISTRY",
]
