"""repro: Flashlight (ICML 2022) in JAX — open tensor/memory/distributed
interfaces, tape autograd, and a multi-pod production substrate.

Top-level API: the unified runtime Session —

    with repro.session(backend="pallas", mesh=mesh) as s:
        ...
"""

from repro.runtime import (KernelOverrides, PrecisionPolicy, ServingPolicy,
                           Session, current_session, default_session, session)

__all__ = [
    "Session", "KernelOverrides", "PrecisionPolicy", "ServingPolicy",
    "session", "current_session", "default_session",
]

__version__ = "0.2.0"
