"""repro: Flashlight (ICML 2022) in JAX — open tensor/memory/distributed
interfaces, tape autograd, and a multi-pod production substrate."""

__version__ = "0.1.0"
