"""repro: Flashlight (ICML 2022) in JAX — open tensor/memory/distributed
interfaces, tape autograd, and a multi-pod production substrate.

Top-level API: the unified runtime Session —

    with repro.session(backend="pallas", mesh=mesh) as s:
        ...
"""

from repro.runtime import (AnalysisPolicy, CompilerPolicy, KernelOverrides,
                           ObservabilityPolicy, PrecisionPolicy, PrefixPolicy,
                           ServingPolicy, Session, current_session,
                           default_session, session)

__all__ = [
    "Session", "KernelOverrides", "PrecisionPolicy", "ServingPolicy",
    "PrefixPolicy", "CompilerPolicy", "AnalysisPolicy",
    "ObservabilityPolicy",
    "session", "current_session", "default_session",
    "compile", "obs",
]

__version__ = "0.3.0"


def __getattr__(name):
    # `repro.compile` resolves lazily (PEP 562) so `import repro` stays
    # light — the compiler subsystem pulls in Pallas machinery.
    if name == "compile":
        from repro.compiler import compile as _compile

        return _compile
    if name == "obs":
        import repro.obs as _obs

        return _obs
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
