"""Allocation telemetry: record alloc/free traces from real model execution.

Paper §5.2.2: researchers "built highly-specialized telemetry that tied
individual tensor operations to specific allocations".  Here, the lazy
tensor backend (and the tape autograd, if asked) emit events tagged with
the producing op; traces are serializable and replayable against any
:class:`MemoryManagerAdapter` policy for fragmentation studies.

Events carry a monotonic timestamp (``repro.obs.now``; ``ts=0.0`` in
traces written before timestamps existed — ``load``/``replay`` accept
both).  When the ambient session has observability enabled
(``repro.session(obs=True)``), every alloc/free is additionally mirrored
into that tracer as a ``mem.alloc`` / ``mem.free`` instant — the bridge
that puts memory events on the same timeline as compiler and serving
spans, whether or not an :class:`AllocTrace` recording is active.
"""

from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field, asdict


@dataclass
class TraceEvent:
    kind: str          # "alloc" | "free"
    uid: int           # logical buffer id
    nbytes: int = 0
    tag: str = ""      # producing tensor op
    ts: float = 0.0    # monotonic seconds (repro.obs.now); 0.0 = untimed


@dataclass
class AllocTrace:
    events: list[TraceEvent] = field(default_factory=list)

    def append(self, ev: TraceEvent) -> None:
        self.events.append(ev)

    def __len__(self):
        return len(self.events)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump([asdict(e) for e in self.events], f)

    @classmethod
    def load(cls, path: str) -> "AllocTrace":
        # TraceEvent defaults keep this byte-compatible with traces
        # written before the ts field existed.
        with open(path) as f:
            return cls([TraceEvent(**e) for e in json.load(f)])

    def replay(self, manager) -> None:
        """Replay the trace against a memory-manager policy."""
        ptrs: dict[int, int] = {}
        for ev in self.events:
            if ev.kind == "alloc":
                ptrs[ev.uid] = manager.alloc(ev.nbytes)
            elif ev.kind == "free" and ev.uid in ptrs:
                manager.unlock(ptrs.pop(ev.uid))
        for ptr in ptrs.values():
            manager.unlock(ptr)


class _State(threading.local):
    def __init__(self):
        self.trace: AllocTrace | None = None
        self.live: dict[int, int] = {}


_STATE = _State()


def _obs_tracer():
    """The ambient session's tracer, or None — kept out of the common
    case with a cheap policy check before any obs import."""
    try:
        from repro.runtime import current_session
    except ImportError:  # pragma: no cover - partial-init edge
        return None
    policy = getattr(current_session(), "obs", None)
    if policy is None or not getattr(policy, "enabled", False):
        return None
    return policy.tracer()


def start_recording() -> AllocTrace:
    _STATE.trace = AllocTrace()
    _STATE.live = {}
    return _STATE.trace


def stop_recording() -> AllocTrace | None:
    t = _STATE.trace
    _STATE.trace = None
    return t


def record_alloc(uid: int, nbytes: int, tag: str = "") -> None:
    tracer = _obs_tracer()
    if _STATE.trace is None and tracer is None:
        return
    from repro.obs.clock import now
    ts = now()
    if _STATE.trace is not None:
        _STATE.trace.append(TraceEvent("alloc", uid, nbytes, tag, ts))
        _STATE.live[uid] = nbytes
    if tracer is not None:
        tracer.instant("mem.alloc", "memory", ts=ts,
                       uid=uid, nbytes=nbytes, tag=tag)


def record_free(uid: int) -> None:
    tracer = _obs_tracer()
    if _STATE.trace is None and tracer is None:
        return
    from repro.obs.clock import now
    ts = now()
    nbytes = 0
    if _STATE.trace is not None and uid in _STATE.live:
        nbytes = _STATE.live.pop(uid)
        _STATE.trace.append(TraceEvent("free", uid, nbytes, ts=ts))
    if tracer is not None:
        tracer.instant("mem.free", "memory", ts=ts, uid=uid, nbytes=nbytes)
