"""Allocation telemetry: record alloc/free traces from real model execution.

Paper §5.2.2: researchers "built highly-specialized telemetry that tied
individual tensor operations to specific allocations".  Here, the lazy
tensor backend (and the tape autograd, if asked) emit events tagged with
the producing op; traces are serializable and replayable against any
:class:`MemoryManagerAdapter` policy for fragmentation studies.
"""

from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field, asdict


@dataclass
class TraceEvent:
    kind: str          # "alloc" | "free"
    uid: int           # logical buffer id
    nbytes: int = 0
    tag: str = ""      # producing tensor op


@dataclass
class AllocTrace:
    events: list[TraceEvent] = field(default_factory=list)

    def append(self, ev: TraceEvent) -> None:
        self.events.append(ev)

    def __len__(self):
        return len(self.events)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump([asdict(e) for e in self.events], f)

    @classmethod
    def load(cls, path: str) -> "AllocTrace":
        with open(path) as f:
            return cls([TraceEvent(**e) for e in json.load(f)])

    def replay(self, manager) -> None:
        """Replay the trace against a memory-manager policy."""
        ptrs: dict[int, int] = {}
        for ev in self.events:
            if ev.kind == "alloc":
                ptrs[ev.uid] = manager.alloc(ev.nbytes)
            elif ev.kind == "free" and ev.uid in ptrs:
                manager.unlock(ptrs.pop(ev.uid))
        for ptr in ptrs.values():
            manager.unlock(ptr)


class _State(threading.local):
    def __init__(self):
        self.trace: AllocTrace | None = None
        self.live: dict[int, int] = {}


_STATE = _State()


def start_recording() -> AllocTrace:
    _STATE.trace = AllocTrace()
    _STATE.live = {}
    return _STATE.trace


def stop_recording() -> AllocTrace | None:
    t = _STATE.trace
    _STATE.trace = None
    return t


def record_alloc(uid: int, nbytes: int, tag: str = "") -> None:
    if _STATE.trace is not None:
        _STATE.trace.append(TraceEvent("alloc", uid, nbytes, tag))
        _STATE.live[uid] = nbytes


def record_free(uid: int) -> None:
    if _STATE.trace is not None and uid in _STATE.live:
        _STATE.trace.append(TraceEvent("free", uid, _STATE.live.pop(uid)))
