from .manager import (Block, BumpMemoryManager, CachingMemoryManager,
                      MemoryManagerAdapter, MemoryStats, OutOfMemory)
from . import telemetry

__all__ = ["Block", "BumpMemoryManager", "CachingMemoryManager",
           "MemoryManagerAdapter", "MemoryStats", "OutOfMemory", "telemetry"]
