"""Memory-management interface (paper §4.1.2, Listing 3).

On GPU, Flashlight's ``MemoryManagerAdapter`` interposes on raw device
allocation.  On TPU, XLA owns HBM, so the open interface is adapted:
managers run the framework's *host-side* buffer pool, and — crucially for
the paper's §5.2.2 study — replay recorded allocation traces from real
model steps, so allocator *policies* (bucketing, block splitting,
split-size thresholds) can be researched and compared exactly as the paper
describes.  They also serve a *live* workload: the paged KV-cache serving
runtime (``repro/serving/kv_cache.py``) delegates block allocation to
these managers, so the same policies drive admission/preemption behavior
under real serving traffic.

The arena model: a manager controls a contiguous arena of ``capacity``
bytes.  ``alloc`` returns an offset; ``free`` returns the block.  Internal
fragmentation = sum(block_size - requested); external fragmentation is
measured by the high-water mark vs live bytes.
"""

from __future__ import annotations

import abc
import bisect
from dataclasses import dataclass, field


class OutOfMemory(RuntimeError):
    pass


@dataclass
class Block:
    offset: int
    size: int            # allocated (rounded) size
    requested: int = 0   # user-requested size
    free: bool = True


@dataclass
class MemoryStats:
    capacity: int = 0
    live_requested: int = 0      # bytes the user asked for, currently live
    live_allocated: int = 0      # bytes actually reserved for live blocks
    peak_requested: int = 0
    peak_allocated: int = 0
    high_water: int = 0          # arena high-water mark (external frag proxy)
    n_allocs: int = 0
    n_frees: int = 0
    n_device_allocs: int = 0     # cache misses -> "cudaMalloc"-equivalents
    n_splits: int = 0

    @property
    def internal_fragmentation(self) -> float:
        """Wasted bytes inside live blocks / live allocated bytes."""
        if self.peak_allocated == 0:
            return 0.0
        return 1.0 - self.peak_requested / self.peak_allocated

    @property
    def external_fragmentation(self) -> float:
        """Arena footprint beyond what live data needed at the peak."""
        if self.high_water == 0:
            return 0.0
        return 1.0 - self.peak_allocated / self.high_water


class MemoryManagerAdapter(abc.ABC):
    """The open allocator API (paper Listing 3: ``alloc``/``unlock``)."""

    def __init__(self, capacity: int = 1 << 34):
        self.capacity = capacity
        self.stats = MemoryStats(capacity=capacity)

    @abc.abstractmethod
    def alloc(self, size: int, user_lock: bool = False) -> int:
        """Reserve ``size`` bytes; returns the arena offset."""

    @abc.abstractmethod
    def unlock(self, ptr: int, user_lock: bool = False) -> None:
        """Release the block at ``ptr`` (paper's ``unlock`` == free)."""

    def _on_alloc(self, requested: int, allocated: int, offset: int) -> None:
        s = self.stats
        s.n_allocs += 1
        s.live_requested += requested
        s.live_allocated += allocated
        s.peak_requested = max(s.peak_requested, s.live_requested)
        s.peak_allocated = max(s.peak_allocated, s.live_allocated)
        s.high_water = max(s.high_water, offset + allocated)

    def _on_free(self, requested: int, allocated: int) -> None:
        s = self.stats
        s.n_frees += 1
        s.live_requested -= requested
        s.live_allocated -= allocated


class BumpMemoryManager(MemoryManagerAdapter):
    """Trivial bump allocator: never reuses memory. Lower bound baseline."""

    def __init__(self, capacity: int = 1 << 34):
        super().__init__(capacity)
        self._cursor = 0
        self._blocks: dict[int, Block] = {}

    def alloc(self, size: int, user_lock: bool = False) -> int:
        if self._cursor + size > self.capacity:
            raise OutOfMemory(f"bump allocator exhausted at {self._cursor}")
        off = self._cursor
        self._cursor += size
        self._blocks[off] = Block(off, size, size, free=False)
        self.stats.n_device_allocs += 1
        self._on_alloc(size, size, off)
        return off

    def unlock(self, ptr: int, user_lock: bool = False) -> None:
        b = self._blocks.pop(ptr)
        self._on_free(b.requested, b.size)


class CachingMemoryManager(MemoryManagerAdapter):
    """Bucketed caching allocator with optional split-threshold policy.

    Reproduces the §5.2.2 case study: a caching allocator that buckets
    allocations by rounded size is subject to fragmentation; *restricting
    splitting of large cached blocks* (blocks beyond ``split_threshold``)
    reduced internal fragmentation "for most models by over 20%".

    Parameters
    ----------
    round_to: bucket granularity (rounded up to a multiple of this).
    split_large_blocks: if True, a cached block much larger than the request
        may be split; if False (or above threshold), it is handed out whole,
        inflating internal fragmentation.
    split_threshold: blocks larger than this are never split when
        ``restrict_large_splits`` policy is active.
    """

    def __init__(self, capacity: int = 1 << 34, round_to: int = 512,
                 split_large_blocks: bool = True,
                 split_threshold: int | None = None,
                 min_split_remainder: int = 512):
        super().__init__(capacity)
        self.round_to = round_to
        self.split_large_blocks = split_large_blocks
        self.split_threshold = split_threshold
        self.min_split_remainder = min_split_remainder
        self._cursor = 0
        self._live: dict[int, Block] = {}
        # free list sorted by size for best-fit
        self._free_sizes: list[int] = []
        self._free_blocks: list[Block] = []

    def _round(self, size: int) -> int:
        r = self.round_to
        return (size + r - 1) // r * r

    def _insert_free(self, block: Block) -> None:
        block.free = True
        i = bisect.bisect_left(self._free_sizes, block.size)
        self._free_sizes.insert(i, block.size)
        self._free_blocks.insert(i, block)

    def _pop_best_fit(self, size: int) -> Block | None:
        i = bisect.bisect_left(self._free_sizes, size)
        if i == len(self._free_sizes):
            return None
        self._free_sizes.pop(i)
        return self._free_blocks.pop(i)

    def alloc(self, size: int, user_lock: bool = False) -> int:
        rounded = self._round(size)
        block = self._pop_best_fit(rounded)
        if block is None:
            # cache miss: carve new memory from the arena ("cudaMalloc")
            if self._cursor + rounded > self.capacity:
                raise OutOfMemory(
                    f"arena exhausted: cursor={self._cursor} req={rounded}")
            block = Block(self._cursor, rounded)
            self._cursor += rounded
            self.stats.n_device_allocs += 1
        elif block.size > rounded:
            may_split = self.split_large_blocks and (
                self.split_threshold is None
                or block.size <= self.split_threshold)
            remainder = block.size - rounded
            if may_split and remainder >= self.min_split_remainder:
                tail = Block(block.offset + rounded, remainder)
                self._insert_free(tail)
                block = Block(block.offset, rounded)
                self.stats.n_splits += 1
            # else: hand out the whole cached block (internal fragmentation)
        block.free = False
        block.requested = size
        self._live[block.offset] = block
        self._on_alloc(size, block.size, block.offset)
        return block.offset

    def unlock(self, ptr: int, user_lock: bool = False) -> None:
        block = self._live.pop(ptr)
        self._on_free(block.requested, block.size)
        block.requested = 0
        self._insert_free(block)
