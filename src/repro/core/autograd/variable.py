"""Variable + dynamic-tape autograd (paper §4.2, Listing 4).

A :class:`Variable` wraps a backend tensor; operators record VJP closures
onto a dynamic tape (parent links), "in a design similar to Paszke et al.
[2017] while being lightweight enough to allow implementations of other
autograd paradigms".

Because the tape is ordinary Python built *at trace time* over primitive
tensor ops, ``loss.backward()`` composes with ``jax.jit``: tracing a
training step builds the tape symbolically and the backward walk emits the
gradient computation into the same XLA program.  Validated against
``jax.grad`` as an oracle in tests/test_autograd.py.

The §5.2.1 customization hooks are first-class:

* **graph pruning** — ``backward(prune=fn)`` stops gradient flow into
  subgraphs the predicate rejects (e.g. sparse beam-search lattices);
* **pre-fused gradients** — :func:`fused` records a *single* tape node
  (one VJP closure) for an arbitrary composite, collapsing common op
  sequences;
* **custom node lifetime** — ``free_on_use=True`` drops VJP residual
  references as soon as each node's backward has run, instead of keeping
  the whole graph alive until the walk finishes.
"""

from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Sequence

import jax

from ..tensor import ops

_uid = itertools.count()


class _GradState(threading.local):
    def __init__(self):
        self.enabled = True


_GRAD_STATE = _GradState()


class no_grad:
    """Context manager disabling tape recording."""

    def __enter__(self):
        self._prev = _GRAD_STATE.enabled
        _GRAD_STATE.enabled = False
        return self

    def __exit__(self, *exc):
        _GRAD_STATE.enabled = self._prev
        return False


def grad_enabled() -> bool:
    return _GRAD_STATE.enabled


class Node:
    """A tape node: VJP closure + parent links.

    ``Node`` count is the tape size — the §5.2.1 study manipulates graphs
    with millions of these, so the slots layout is deliberately minimal.
    """

    __slots__ = ("parents", "vjp", "name", "uid")

    def __init__(self, parents: Sequence["Variable"], vjp: Callable,
                 name: str):
        self.parents = tuple(parents)
        self.vjp = vjp
        self.name = name
        self.uid = next(_uid)


class Variable:
    """Tensor + optional grad + tape linkage (paper's VARIABLE)."""

    __slots__ = ("data", "requires_grad", "grad", "node", "__weakref__")

    def __init__(self, data, requires_grad: bool = False,
                 node: Node | None = None):
        self.data = data
        self.requires_grad = requires_grad
        self.grad = None
        self.node = node

    # -- paper API ---------------------------------------------------------
    def tensor(self):
        """Materialized underlying tensor (forces lazy backends)."""
        return ops.materialize(self.data)

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def ndim(self):
        return len(self.data.shape)

    def detach(self) -> "Variable":
        return Variable(self.data, requires_grad=False)

    def zero_grad(self) -> None:
        self.grad = None

    # -- backward ------------------------------------------------------------
    def backward(self, grad=None, *, prune: Callable[[Node], bool] | None = None,
                 free_on_use: bool = True, accumulate: bool = True) -> None:
        """Reverse-walk the tape from this variable.

        prune: optional predicate; when it returns True for a node, gradient
            flow into that node's subtree is cut (on-the-fly graph pruning).
        free_on_use: drop VJP closures/residuals as soon as consumed
            (custom node lifetime; trims peak memory on huge tapes).
        accumulate: add into existing ``.grad`` (else overwrite).
        """
        if grad is None:
            grad = ops.ones_like(self.data)
        order = _toposort(self)
        grads: dict[int, Any] = {}
        if self.node is not None:
            grads[self.node.uid] = grad
        elif self.requires_grad:
            _assign(self, grad, accumulate)
            return

        for node in order:  # already reverse-topological
            g = grads.pop(node.uid, None)
            if g is None:
                continue
            if prune is not None and prune(node):
                continue
            parent_grads = node.vjp(g)
            for parent, pg in zip(node.parents, parent_grads):
                if pg is None:
                    continue
                if parent.node is not None:
                    u = parent.node.uid
                    grads[u] = pg if u not in grads else ops.add(grads[u], pg)
                elif parent.requires_grad:
                    _assign(parent, pg, accumulate)
            if free_on_use:
                node.vjp = _consumed
        # leaves reached through recorded nodes
        return

    # -- operator sugar (delegates to functions.py) ---------------------------
    def __add__(self, other):
        from . import functions as F
        return F.add(self, _as_variable(other))

    __radd__ = __add__

    def __sub__(self, other):
        from . import functions as F
        return F.sub(self, _as_variable(other))

    def __rsub__(self, other):
        from . import functions as F
        return F.sub(_as_variable(other), self)

    def __mul__(self, other):
        from . import functions as F
        return F.mul(self, _as_variable(other))

    __rmul__ = __mul__

    def __truediv__(self, other):
        from . import functions as F
        return F.div(self, _as_variable(other))

    def __rtruediv__(self, other):
        from . import functions as F
        return F.div(_as_variable(other), self)

    def __neg__(self):
        from . import functions as F
        return F.neg(self)

    def __matmul__(self, other):
        from . import functions as F
        return F.matmul(self, _as_variable(other))

    def __getitem__(self, idx):
        from . import functions as F
        return F.getitem(self, idx)

    def reshape(self, shape):
        from . import functions as F
        return F.reshape(self, shape)

    def astype(self, dtype):
        from . import functions as F
        return F.astype(self, dtype)

    def sum(self, axis=None, keepdims=False):
        from . import functions as F
        return F.sum(self, axis=axis, keepdims=keepdims)

    def mean(self, axis=None, keepdims=False):
        from . import functions as F
        return F.mean(self, axis=axis, keepdims=keepdims)

    def __repr__(self):
        return (f"Variable(shape={tuple(self.shape)}, dtype={self.dtype}, "
                f"requires_grad={self.requires_grad}, "
                f"tape={'yes' if self.node else 'leaf'})")


def _consumed(_):
    raise RuntimeError(
        "tape node already consumed (free_on_use=True); re-run forward or "
        "call backward(free_on_use=False) to retain the graph")


def _assign(var: Variable, grad, accumulate: bool) -> None:
    if accumulate and var.grad is not None:
        var.grad = ops.add(var.grad, grad)
    else:
        var.grad = grad


def _as_variable(x) -> Variable:
    if isinstance(x, Variable):
        return x
    if not hasattr(x, "shape"):
        import jax.numpy as jnp

        x = jnp.asarray(x)
    return Variable(x)


def noGrad(tensor) -> Variable:  # noqa: N802 - paper-faithful name
    """Paper's ``noGrad``: wrap data as a constant Variable."""
    return Variable(tensor, requires_grad=False)


def _toposort(root: Variable) -> list[Node]:
    """Reverse-topological order of tape nodes reachable from root."""
    seen: set[int] = set()
    post: list[Node] = []
    if root.node is None:
        return post
    stack: list[tuple[Node, bool]] = [(root.node, False)]
    while stack:
        node, expanded = stack.pop()
        if expanded:
            post.append(node)
            continue
        if node.uid in seen:
            continue
        seen.add(node.uid)
        stack.append((node, True))
        for p in node.parents:
            if p.node is not None and p.node.uid not in seen:
                stack.append((p.node, False))
    post.reverse()
    return post


def tape_size(root: Variable) -> int:
    """Number of tape nodes reachable from ``root`` (benchmark metric)."""
    return len(_toposort(root))


def record(out_data, parents: Sequence[Variable], vjp: Callable,
           name: str) -> Variable:
    """Create an output Variable, recording a tape node if needed."""
    track = grad_enabled() and any(
        p.requires_grad or p.node is not None for p in parents)
    if not track:
        return Variable(out_data)
    return Variable(out_data, node=Node(parents, vjp, name))


def fused(fn: Callable, name: str = "fused") -> Callable:
    """Pre-fused gradient computation (§5.2.1).

    Wraps an arbitrary composite of tensor ops so that the *whole composite*
    is recorded as one tape node with a single VJP closure, instead of one
    node per primitive — collapsing "common sequences of gradient
    computation operations".
    """

    def wrapped(*variables: Variable) -> Variable:
        variables = tuple(_as_variable(v) for v in variables)
        datas = tuple(v.data for v in variables)
        out, vjp_fn = jax.vjp(fn, *datas)
        return record(out, variables, lambda g: vjp_fn(g), name=name)

    return wrapped
