"""Differentiable operators over Variables.

Each function runs its forward through the *active tensor backend* (so a
backend swap reaches gradients too) and records a VJP closure onto the
tape.  Hot/simple VJPs are hand-written (compact, inspectable — the paper's
Listing 4 style); anything long-tail lifts through ``jax.vjp`` via
:func:`lift`, keeping the implementation deliberately small.
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..tensor import ops
from .variable import Variable, _as_variable, record


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _unbroadcast(grad, shape):
    """Sum ``grad`` down to ``shape`` (reverse of numpy broadcasting)."""
    if tuple(grad.shape) == tuple(shape):
        return grad
    extra = len(grad.shape) - len(shape)
    if extra > 0:
        grad = ops.sum(grad, axis=tuple(range(extra)), keepdims=False)
    axes = tuple(i for i, (g, s) in enumerate(zip(grad.shape, shape))
                 if s == 1 and g != 1)
    if axes:
        grad = ops.sum(grad, axis=axes, keepdims=True)
    return ops.reshape(grad, shape)


def lift(fn: Callable, name: str | None = None) -> Callable:
    """Lift a tensor-level function into a Variable op via jax.vjp."""
    opname = name or getattr(fn, "__name__", "lifted")

    def wrapped(*args: Variable, **kwargs):
        vs = tuple(_as_variable(a) for a in args)
        datas = tuple(ops.materialize(v.data) for v in vs)
        out, vjp_fn = jax.vjp(lambda *xs: fn(*xs, **kwargs), *datas)
        return record(out, vs, vjp_fn, name=opname)

    wrapped.__name__ = opname
    return wrapped


# --------------------------------------------------------------------------
# arithmetic
# --------------------------------------------------------------------------

def add(a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    out = ops.add(a.data, b.data)

    def vjp(g):
        return (_unbroadcast(g, a.shape), _unbroadcast(g, b.shape))

    return record(out, (a, b), vjp, "add")


def sub(a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    out = ops.sub(a.data, b.data)

    def vjp(g):
        return (_unbroadcast(g, a.shape),
                _unbroadcast(ops.neg(g), b.shape))

    return record(out, (a, b), vjp, "sub")


def mul(a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    out = ops.mul(a.data, b.data)
    ad, bd = a.data, b.data

    def vjp(g):
        return (_unbroadcast(ops.mul(g, bd), a.shape),
                _unbroadcast(ops.mul(g, ad), b.shape))

    return record(out, (a, b), vjp, "mul")


def div(a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    out = ops.div(a.data, b.data)
    ad, bd = a.data, b.data

    def vjp(g):
        ga = ops.div(g, bd)
        gb = ops.neg(ops.div(ops.mul(g, ad), ops.mul(bd, bd)))
        return (_unbroadcast(ga, a.shape), _unbroadcast(gb, b.shape))

    return record(out, (a, b), vjp, "div")


def neg(a: Variable) -> Variable:
    a = _as_variable(a)
    return record(ops.neg(a.data), (a,), lambda g: (ops.neg(g),), "neg")


def exp(a: Variable) -> Variable:
    a = _as_variable(a)
    out = ops.exp(a.data)
    return record(out, (a,), lambda g: (ops.mul(g, out),), "exp")


def log(a: Variable) -> Variable:
    a = _as_variable(a)
    ad = a.data
    return record(ops.log(ad), (a,), lambda g: (ops.div(g, ad),), "log")


def tanh(a: Variable) -> Variable:
    a = _as_variable(a)
    out = ops.tanh(a.data)

    def vjp(g):
        return (ops.mul(g, ops.sub(ops.ones_like(out), ops.mul(out, out))),)

    return record(out, (a,), vjp, "tanh")


def sqrt(a: Variable) -> Variable:
    a = _as_variable(a)
    out = ops.sqrt(a.data)

    def vjp(g):
        return (ops.div(g, ops.mul(ops.full_like(out, 2.0), out)),)

    return record(out, (a,), vjp, "sqrt")


def maximum(a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    ad, bd = a.data, b.data
    out = ops.maximum(ad, bd)

    def vjp(g):
        mask = ops.astype(ops.ge(ad, bd), g.dtype)
        return (_unbroadcast(ops.mul(g, mask), a.shape),
                _unbroadcast(ops.mul(g, ops.sub(ops.ones_like(mask), mask)),
                             b.shape))

    return record(out, (a, b), vjp, "maximum")


def relu(a: Variable) -> Variable:
    """Paper's composition example, differentiable form."""
    a = _as_variable(a)
    ad = a.data
    out = ops.maximum(ad, ops.zeros_like(ad))

    def vjp(g):
        return (ops.mul(g, ops.astype(ops.gt(ad, ops.zeros_like(ad)),
                                      g.dtype)),)

    return record(out, (a,), vjp, "relu")


def matmul(a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    ad, bd = a.data, b.data
    out = ops.matmul(ad, bd)

    def _mT(x):
        perm = list(range(len(x.shape)))
        perm[-1], perm[-2] = perm[-2], perm[-1]
        return ops.transpose(x, tuple(perm))

    def vjp(g):
        ga = ops.matmul(g, _mT(bd))
        gb = ops.matmul(_mT(ad), g)
        return (_unbroadcast(ga, a.shape), _unbroadcast(gb, b.shape))

    return record(out, (a, b), vjp, "matmul")


# --------------------------------------------------------------------------
# reductions / shape
# --------------------------------------------------------------------------

def sum(a: Variable, axis=None, keepdims=False) -> Variable:  # noqa: A001
    a = _as_variable(a)
    out = ops.sum(a.data, axis=axis, keepdims=keepdims)
    in_shape = a.shape

    def vjp(g):
        if axis is None:
            return (ops.broadcast_to(ops.reshape(g, (1,) * len(in_shape)),
                                     in_shape),)
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        axes = tuple(ax % len(in_shape) for ax in axes)
        if not keepdims:
            shape = list(in_shape)
            for ax in axes:
                shape[ax] = 1
            g = ops.reshape(g, tuple(shape))
        return (ops.broadcast_to(g, in_shape),)

    return record(out, (a,), vjp, "sum")


def mean(a: Variable, axis=None, keepdims=False) -> Variable:
    a = _as_variable(a)
    if axis is None:
        n = math.prod(a.shape) if a.shape else 1
    elif isinstance(axis, int):
        n = a.shape[axis]
    else:
        n = math.prod(a.shape[ax] for ax in axis)
    s = sum(a, axis=axis, keepdims=keepdims)
    return mul(s, Variable(ops.full_like(s.data, 1.0 / n)))


def max(a: Variable, axis=None, keepdims=False) -> Variable:  # noqa: A001
    a = _as_variable(a)
    ad = a.data
    out = ops.max(ad, axis=axis, keepdims=keepdims)

    def vjp(g):
        o = out
        if axis is not None and not keepdims:
            axes = (axis,) if isinstance(axis, int) else tuple(axis)
            shape = list(ad.shape)
            for ax in axes:
                shape[ax % len(shape)] = 1
            o = ops.reshape(out, tuple(shape))
            g = ops.reshape(g, tuple(shape))
        elif axis is None:
            o = ops.reshape(out, (1,) * len(ad.shape))
            g = ops.reshape(g, (1,) * len(ad.shape))
        mask = ops.astype(ops.eq(ad, o), g.dtype)
        denom = ops.sum(mask, axis=axis, keepdims=True)
        return (ops.div(ops.mul(mask, ops.broadcast_to(g, ad.shape)), denom),)

    return record(out, (a,), vjp, "max")


def reshape(a: Variable, shape) -> Variable:
    a = _as_variable(a)
    in_shape = a.shape
    out = ops.reshape(a.data, shape)
    return record(out, (a,), lambda g: (ops.reshape(g, in_shape),), "reshape")


def transpose(a: Variable, axes=None) -> Variable:
    a = _as_variable(a)
    out = ops.transpose(a.data, axes)
    if axes is None:
        inv = None
    else:
        inv = tuple(sorted(range(len(axes)), key=lambda i: axes[i]))
    return record(out, (a,), lambda g: (ops.transpose(g, inv),), "transpose")


def broadcast_to(a: Variable, shape) -> Variable:
    a = _as_variable(a)
    in_shape = a.shape
    out = ops.broadcast_to(a.data, shape)
    return record(out, (a,), lambda g: (_unbroadcast(g, in_shape),),
                  "broadcast_to")


def concatenate(vs, axis=0) -> Variable:
    vs = [_as_variable(v) for v in vs]
    out = ops.concatenate([v.data for v in vs], axis=axis)
    sizes = [v.shape[axis] for v in vs]

    def vjp(g):
        grads, start = [], 0
        for sz in sizes:
            starts = [0] * len(g.shape)
            limits = list(g.shape)
            starts[axis], limits[axis] = start, start + sz
            grads.append(ops.slice(g, starts, limits))
            start += sz
        return tuple(grads)

    return record(out, tuple(vs), vjp, "concatenate")


def getitem(a: Variable, idx) -> Variable:
    return lift(lambda x: x[idx], name="getitem")(a)


def take(a: Variable, indices, axis=0) -> Variable:
    """Embedding-style gather with scatter-add backward."""
    a = _as_variable(a)
    idx = indices.data if isinstance(indices, Variable) else indices
    out = ops.take(a.data, idx, axis=axis)
    in_shape = a.shape

    def vjp(g):
        zero = ops.zeros(in_shape, g.dtype)
        flat_idx = ops.reshape(idx, (-1,))
        lead = math.prod(g.shape[:len(idx.shape)]) if len(idx.shape) else 1
        g2 = ops.reshape(g, (lead,) + tuple(in_shape[axis + 1:])
                         if axis == 0 else g.shape)
        if axis != 0:
            raise NotImplementedError("take backward: axis != 0")
        return (ops.scatter_add(zero, flat_idx, g2, axis=0),)

    return record(out, (a,), vjp, "take")


def where(cond, a: Variable, b: Variable) -> Variable:
    a, b = _as_variable(a), _as_variable(b)
    c = cond.data if isinstance(cond, Variable) else cond
    out = ops.where(c, a.data, b.data)

    def vjp(g):
        z = ops.zeros_like(g)
        return (_unbroadcast(ops.where(c, g, z), a.shape),
                _unbroadcast(ops.where(c, z, g), b.shape))

    return record(out, (a, b), vjp, "where")


def astype(a: Variable, dtype) -> Variable:
    a = _as_variable(a)
    in_dtype = a.dtype
    out = ops.astype(a.data, dtype)
    return record(out, (a,), lambda g: (ops.astype(g, in_dtype),), "astype")


def stop_gradient(a: Variable) -> Variable:
    a = _as_variable(a)
    return Variable(ops.stop_gradient(a.data))


# --------------------------------------------------------------------------
# composite / NN ops (compositions stay differentiable automatically;
# heavy ones are lifted whole for single-node tapes)
# --------------------------------------------------------------------------

def sigmoid(a: Variable) -> Variable:
    a = _as_variable(a)
    out = ops.sigmoid(a.data)

    def vjp(g):
        return (ops.mul(g, ops.mul(out, ops.sub(ops.ones_like(out), out))),)

    return record(out, (a,), vjp, "sigmoid")


def gelu(a: Variable) -> Variable:
    return lift(ops.gelu, name="gelu")(a)


def silu(a: Variable) -> Variable:
    return lift(ops.silu, name="silu")(a)


def softmax(a: Variable, axis=-1) -> Variable:
    a = _as_variable(a)
    out = ops.softmax(a.data, axis=axis)

    def vjp(g):
        inner = ops.sum(ops.mul(g, out), axis=axis, keepdims=True)
        return (ops.mul(out, ops.sub(g, inner)),)

    return record(out, (a,), vjp, "softmax")


def log_softmax(a: Variable, axis=-1) -> Variable:
    a = _as_variable(a)
    out = ops.log_softmax(a.data, axis=axis)

    def vjp(g):
        sm = ops.exp(out)
        return (ops.sub(g, ops.mul(sm, ops.sum(g, axis=axis, keepdims=True))),)

    return record(out, (a,), vjp, "log_softmax")


def layer_norm(x: Variable, weight: Variable, bias: Variable,
               eps: float = 1e-5) -> Variable:
    return lift(lambda xx, w, b: ops.layer_norm(xx, w, b, eps),
                name="layer_norm")(x, weight, bias)


def rms_norm(x: Variable, weight: Variable, eps: float = 1e-6) -> Variable:
    return lift(lambda xx, w: ops.rms_norm(xx, w, eps), name="rms_norm")(x, weight)


def conv2d(x: Variable, w: Variable, stride=(1, 1), padding="SAME") -> Variable:
    return lift(lambda xx, ww: ops.conv2d(xx, ww, stride, padding),
                name="conv2d")(x, w)


def dot_general(a: Variable, b: Variable, dimension_numbers,
                preferred_element_type=None) -> Variable:
    return lift(lambda x, y: ops.dot_general(x, y, dimension_numbers,
                                             preferred_element_type),
                name="dot_general")(a, b)


def dropout(x: Variable, rate: float, key) -> Variable:
    if rate <= 0.0:
        return x
    x = _as_variable(x)
    mask = ops.dropout_mask(key, x.shape, rate, x.dtype)
    return mul(x, Variable(mask))


def embedding(table: Variable, token_ids) -> Variable:
    return take(table, _as_variable(token_ids), axis=0)


def cross_entropy(logits: Variable, labels, axis=-1) -> Variable:
    """Mean token cross-entropy; ``labels`` are integer ids."""
    lsm = log_softmax(logits, axis=axis)
    lab = labels.data if isinstance(labels, Variable) else labels
    nclass = logits.shape[-1]
    onehot = ops.one_hot(ops.reshape(lab, (-1,)), nclass, lsm.dtype)
    flat = reshape(lsm, (-1, nclass))
    nll = neg(sum(mul(flat, Variable(onehot))))
    n = math.prod(lab.shape) if hasattr(lab, "shape") else 1
    return mul(nll, Variable(ops.full_like(nll.data, 1.0 / float(n))))
