"""Functional bridge: tape autograd over parameter pytrees.

``value_and_grad(fn)`` mirrors ``jax.value_and_grad`` but differentiates
with the framework's own tape — wrapping every pytree leaf in a
:class:`Variable`, running ``fn``, walking the tape, and re-assembling the
gradient pytree.  Because the tape builds at trace time, the result is
jit-compatible, which is how we A/B the tape against ``jax.grad`` in both
tests and the overhead benchmarks.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

from .variable import Variable


def value_and_grad(fn: Callable, *, prune=None,
                   free_on_use: bool = True) -> Callable:
    """Tape-autograd analog of jax.value_and_grad over the first argument."""

    def wrapped(params, *args, **kwargs):
        leaves, treedef = jax.tree.flatten(params)
        var_leaves = [Variable(leaf, requires_grad=True) for leaf in leaves]
        var_params = jax.tree.unflatten(treedef, var_leaves)
        loss = fn(var_params, *args, **kwargs)
        if not isinstance(loss, Variable):
            raise TypeError("fn must return a Variable loss")
        loss.backward(prune=prune, free_on_use=free_on_use)
        grads = [v.grad if v.grad is not None
                 else jax.numpy.zeros_like(v.data) for v in var_leaves]
        return loss.data, jax.tree.unflatten(treedef, grads)

    return wrapped


def grad(fn: Callable, **kw) -> Callable:
    vag = value_and_grad(fn, **kw)

    def wrapped(params, *args, **kwargs):
        return vag(params, *args, **kwargs)[1]

    return wrapped
