from .variable import (Variable, Node, no_grad, noGrad, record, fused,
                       tape_size, grad_enabled)
from . import functions
from .functional import value_and_grad, grad

__all__ = ["Variable", "Node", "no_grad", "noGrad", "record", "fused",
           "tape_size", "grad_enabled", "functions", "value_and_grad", "grad"]
