"""DistributedInterface (paper §4.1.3, A.4.1, Listing 5).

The open API for distributed-computation primitives.  Backends:

* :class:`EmulatedBackend` — in-process world for tests/CI;
* :class:`ShardMapBackend` — ``jax.lax`` collectives bound to a named mesh
  axis, for use *inside* ``shard_map``-traced training steps (explicit SPMD);
* the implicit GSPMD path (pjit shardings) lives in ``repro.launch`` and
  needs no instance of this interface — XLA inserts the collectives.

Unlike NCCL-style APIs, calls here are traceable JAX ops, so "async"
becomes overlap in the XLA schedule: ``allReduce(..., async_op=True)``
returns a handle whose ``.wait()`` is a scheduling barrier, letting
callers express compute/comm overlap (used by the bucketed gradient
synchronizer with compression in ``grad_sync.py``).
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp


@dataclass
class Work:
    """Handle for an asynchronous collective (paper: ``async`` flag)."""

    _result: Any
    _finalize: Callable[[Any], Any] | None = None

    def wait(self) -> Any:
        out = self._result
        if self._finalize is not None:
            out = self._finalize(out)
            self._finalize = None
            self._result = out
        return out


class DistributedInterface(abc.ABC):
    """Paper Listing 5, adapted: tensors in/out, sync or async."""

    # -- metadata --------------------------------------------------------
    @abc.abstractmethod
    def getWorldRank(self) -> Any: ...  # noqa: N802 - paper-faithful names

    @abc.abstractmethod
    def getWorldSize(self) -> int: ...

    # -- collectives -----------------------------------------------------
    @abc.abstractmethod
    def allReduce(self, x, scale: float = 1.0, async_op: bool = False): ...

    def allReduceMultiple(self, xs: Sequence[Any], scale: float = 1.0,
                          async_op: bool = False):
        outs = [self.allReduce(x, scale, async_op) for x in xs]
        return outs

    @abc.abstractmethod
    def allGather(self, x, axis: int = 0): ...

    @abc.abstractmethod
    def reduceScatter(self, x, axis: int = 0): ...

    @abc.abstractmethod
    def allToAll(self, x, split_axis: int, concat_axis: int): ...

    @abc.abstractmethod
    def broadcast(self, x, root: int = 0): ...

    @abc.abstractmethod
    def permute(self, x, perm: Sequence[tuple[int, int]]): ...

    # -- synchronization ---------------------------------------------------
    def syncDistributed(self) -> None:  # noqa: N802
        """Flush pending async work (no-op where XLA schedules)."""

    def barrier(self) -> None:
        """Rendezvous; on a traced backend this is a data dependency."""


class EmulatedBackend(DistributedInterface):
    """Single-process world of size 1 (loopback) — CI/rendezvous default."""

    def __init__(self, rank: int = 0, world: int = 1):
        self._rank, self._world = rank, world

    def getWorldRank(self):
        return self._rank

    def getWorldSize(self):
        return self._world

    def allReduce(self, x, scale: float = 1.0, async_op: bool = False):
        out = x * scale * self._world if scale != 1.0 else x
        return Work(out) if async_op else out

    def allGather(self, x, axis: int = 0):
        return jnp.concatenate([x] * self._world, axis=axis)

    def reduceScatter(self, x, axis: int = 0):
        n = x.shape[axis] // self._world
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(self._rank * n, (self._rank + 1) * n)
        return (x * self._world)[tuple(idx)]

    def allToAll(self, x, split_axis: int, concat_axis: int):
        return x

    def broadcast(self, x, root: int = 0):
        return x

    def permute(self, x, perm):
        return x


class ShardMapBackend(DistributedInterface):
    """jax.lax collectives over a named mesh axis (inside shard_map)."""

    def __init__(self, axis_name: str = "data"):
        self.axis_name = axis_name

    def getWorldRank(self):
        return jax.lax.axis_index(self.axis_name)

    def getWorldSize(self):
        if hasattr(jax.lax, "axis_size"):
            return jax.lax.axis_size(self.axis_name)
        return jax.lax.psum(1, self.axis_name)  # pre-0.6 jax

    def allReduce(self, x, scale: float = 1.0, async_op: bool = False):
        def run(v):
            out = jax.lax.psum(v, self.axis_name)
            return out * scale if scale != 1.0 else out

        if async_op:
            # Defer the collective: XLA's latency-hiding scheduler overlaps
            # it with compute issued before .wait().
            return Work(x, run)
        return run(x)

    def allGather(self, x, axis: int = 0):
        return jax.lax.all_gather(x, self.axis_name, axis=axis, tiled=True)

    def reduceScatter(self, x, axis: int = 0):
        return jax.lax.psum_scatter(x, self.axis_name, scatter_dimension=axis,
                                    tiled=True)

    def allToAll(self, x, split_axis: int, concat_axis: int):
        return jax.lax.all_to_all(x, self.axis_name, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)

    def broadcast(self, x, root: int = 0):
        src = jax.lax.axis_index(self.axis_name) == root
        return jax.lax.psum(jnp.where(src, x, jnp.zeros_like(x)),
                            self.axis_name)

    def permute(self, x, perm):
        return jax.lax.ppermute(x, self.axis_name, perm)


_ACTIVE: DistributedInterface | None = None


def init_distributed(backend: DistributedInterface | str = "emulated",
                     **kw) -> DistributedInterface:
    """Rendezvous entry point (paper: 'specialized rendezvous schemes')."""
    global _ACTIVE
    if isinstance(backend, str):
        backend = {"emulated": EmulatedBackend,
                   "shard_map": ShardMapBackend}[backend](**kw)
    _ACTIVE = backend
    return backend


def get_distributed() -> DistributedInterface:
    global _ACTIVE
    if _ACTIVE is None:
        _ACTIVE = EmulatedBackend()
    return _ACTIVE
