"""Bucketed, compressed, overlap-friendly gradient synchronization.

The distributed-optimization layer built on the open DistributedInterface:

* **bucketing** — gradients are packed into fixed-size buckets so each
  collective moves enough bytes to saturate links (NCCL/ICI both hate tiny
  messages);
* **compression** — optional int8 quantization with per-bucket scales and
  **error feedback** (the quantization residual is carried to the next
  step, preserving convergence — Seide et al. 1-bit-SGD lineage);
* **overlap** — buckets are issued as async Work handles in reverse
  parameter order, so the first collectives fly while later-bucket grads
  are still being produced; XLA's latency-hiding scheduler does the rest.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .interface import DistributedInterface


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


@dataclass
class GradSyncConfig:
    bucket_bytes: int = 16 * 1024 * 1024
    compress: str = "none"          # "none" | "int8"
    error_feedback: bool = True
    reverse_order: bool = True      # issue last-produced grads first


class GradientSynchronizer:
    """Stateful synchronizer; carries error-feedback residuals."""

    def __init__(self, dist: DistributedInterface,
                 config: GradSyncConfig | None = None):
        self.dist = dist
        self.config = config or GradSyncConfig()
        self._residual: Any = None

    def init_state(self, grads: Any) -> Any:
        if self.config.compress == "int8" and self.config.error_feedback:
            return jax.tree.map(
                lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)
        return jax.tree.map(lambda g: jnp.zeros((), g.dtype), grads)

    def _buckets(self, leaves: list[jax.Array]) -> list[list[int]]:
        order = list(range(len(leaves)))
        if self.config.reverse_order:
            order = order[::-1]
        buckets, cur, cur_bytes = [], [], 0
        for i in order:
            nbytes = leaves[i].size * leaves[i].dtype.itemsize
            cur.append(i)
            cur_bytes += nbytes
            if cur_bytes >= self.config.bucket_bytes:
                buckets.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            buckets.append(cur)
        return buckets

    def __call__(self, grads: Any, state: Any | None = None,
                 scale: float | None = None) -> tuple[Any, Any]:
        """All-reduce grads; returns (synced_grads, new_state)."""
        cfg = self.config
        world = self.dist.getWorldSize()
        scale = scale if scale is not None else 1.0 / world
        leaves, treedef = jax.tree.flatten(grads)
        if state is None:
            state = self.init_state(grads)
        res_leaves = treedef.flatten_up_to(state)

        out_leaves = [None] * len(leaves)
        new_res = [r for r in res_leaves]
        works = []
        for bucket in self._buckets(leaves):
            for i in bucket:
                g = leaves[i]
                if cfg.compress == "int8":
                    gf = g.astype(jnp.float32)
                    if cfg.error_feedback:
                        gf = gf + res_leaves[i]
                    q, s = quantize_int8(gf)
                    deq = dequantize_int8(q, s, jnp.float32)
                    if cfg.error_feedback:
                        new_res[i] = gf - deq
                    # reduce the dequantized rep (int8 sums overflow; scales
                    # differ per rank, so the wire format is (q, s) pairs —
                    # equivalently reduce deq, which XLA sends as int8+f32
                    # when compression is lowered; we keep semantics here)
                    w = self.dist.allReduce(deq, scale=scale, async_op=True)
                else:
                    w = self.dist.allReduce(g, scale=scale, async_op=True)
                works.append((i, w, g.dtype))
        for i, w, dt in works:
            r = w.wait() if hasattr(w, "wait") else w
            out_leaves[i] = r.astype(dt)
        return (jax.tree.unflatten(treedef, out_leaves),
                jax.tree.unflatten(treedef, new_res))
