from .interface import (DistributedInterface, EmulatedBackend,
                        ShardMapBackend, Work, get_distributed,
                        init_distributed)
from .grad_sync import (GradientSynchronizer, GradSyncConfig, quantize_int8,
                        dequantize_int8)

__all__ = ["DistributedInterface", "EmulatedBackend", "ShardMapBackend",
           "Work", "get_distributed", "init_distributed",
           "GradientSynchronizer", "GradSyncConfig", "quantize_int8",
           "dequantize_int8"]
