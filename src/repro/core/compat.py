"""Small jax version-compat shims.

The container pins whatever jax the image baked in; these helpers let the
same source run on the explicit-sharding era API (``jax.shard_map``,
``check_vma``) and on older releases (``jax.experimental.shard_map``,
``check_rep``) without sprinkling try/except at call sites.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` when present, else the experimental spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict: newer jax returns the
    dict directly, pre-0.6 returns a per-device list of dicts."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    return cost
