"""Eager XLA backend: primitives implemented directly on jax.numpy.

This is the reference backend (paper §4.1.1: "deliberately-compact default
implementations").  Every primitive is a thin call into jnp/lax, so the
backend is fully jit/pjit/shard_map/scan-traceable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .backend import TensorBackend


class JnpBackend(TensorBackend):
    name = "jnp"

    # creation
    def full(self, shape, fill_value, dtype):
        return jnp.full(shape, fill_value, dtype=dtype)

    def arange(self, start, stop, step, dtype):
        return jnp.arange(start, stop, step, dtype=dtype)

    def iota(self, dtype, shape, dimension):
        return lax.broadcasted_iota(dtype, tuple(shape), dimension)

    def random_uniform(self, key, shape, dtype, minval, maxval):
        return jax.random.uniform(key, shape, dtype, minval, maxval)

    def random_normal(self, key, shape, dtype):
        return jax.random.normal(key, shape, dtype)

    # unary
    def neg(self, x):
        return jnp.negative(x)

    def exp(self, x):
        return jnp.exp(x)

    def log(self, x):
        return jnp.log(x)

    def sin(self, x):
        return jnp.sin(x)

    def cos(self, x):
        return jnp.cos(x)

    def tanh(self, x):
        return jnp.tanh(x)

    def sqrt(self, x):
        return jnp.sqrt(x)

    def rsqrt(self, x):
        return lax.rsqrt(x)

    def abs(self, x):
        return jnp.abs(x)

    def sign(self, x):
        return jnp.sign(x)

    def floor(self, x):
        return jnp.floor(x)

    def erf(self, x):
        return lax.erf(x)

    def logical_not(self, x):
        return jnp.logical_not(x)

    def isnan(self, x):
        return jnp.isnan(x)

    # binary
    def add(self, lhs, rhs):
        return jnp.add(lhs, rhs)

    def sub(self, lhs, rhs):
        return jnp.subtract(lhs, rhs)

    def mul(self, lhs, rhs):
        return jnp.multiply(lhs, rhs)

    def div(self, lhs, rhs):
        return jnp.divide(lhs, rhs)

    def pow(self, lhs, rhs):
        return jnp.power(lhs, rhs)

    def maximum(self, lhs, rhs):
        return jnp.maximum(lhs, rhs)

    def minimum(self, lhs, rhs):
        return jnp.minimum(lhs, rhs)

    def mod(self, lhs, rhs):
        return jnp.mod(lhs, rhs)

    def eq(self, lhs, rhs):
        return jnp.equal(lhs, rhs)

    def ne(self, lhs, rhs):
        return jnp.not_equal(lhs, rhs)

    def lt(self, lhs, rhs):
        return jnp.less(lhs, rhs)

    def le(self, lhs, rhs):
        return jnp.less_equal(lhs, rhs)

    def gt(self, lhs, rhs):
        return jnp.greater(lhs, rhs)

    def ge(self, lhs, rhs):
        return jnp.greater_equal(lhs, rhs)

    def logical_and(self, lhs, rhs):
        return jnp.logical_and(lhs, rhs)

    def logical_or(self, lhs, rhs):
        return jnp.logical_or(lhs, rhs)

    # reductions
    def sum(self, x, axis, keepdims):
        return jnp.sum(x, axis=axis, keepdims=keepdims)

    def max(self, x, axis, keepdims):
        return jnp.max(x, axis=axis, keepdims=keepdims)

    def min(self, x, axis, keepdims):
        return jnp.min(x, axis=axis, keepdims=keepdims)

    def prod(self, x, axis, keepdims):
        return jnp.prod(x, axis=axis, keepdims=keepdims)

    def argmax(self, x, axis):
        return jnp.argmax(x, axis=axis)

    def cumsum(self, x, axis):
        return jnp.cumsum(x, axis=axis)

    # shape / data movement
    def reshape(self, x, shape):
        return jnp.reshape(x, shape)

    def transpose(self, x, axes):
        return jnp.transpose(x, axes)

    def broadcast_to(self, x, shape):
        return jnp.broadcast_to(x, shape)

    def concatenate(self, xs, axis):
        return jnp.concatenate(xs, axis=axis)

    def slice(self, x, start, limit):
        return lax.slice(x, start, limit)

    def dynamic_slice(self, x, start_indices, slice_sizes):
        return lax.dynamic_slice(x, start_indices, slice_sizes)

    def dynamic_update_slice(self, x, update, start_indices):
        return lax.dynamic_update_slice(x, update, start_indices)

    def pad(self, x, pad_width, value):
        return jnp.pad(x, pad_width, constant_values=value)

    def where(self, cond, x, y):
        return jnp.where(cond, x, y)

    def take(self, x, indices, axis):
        return jnp.take(x, indices, axis=axis)

    def take_along_axis(self, x, indices, axis):
        return jnp.take_along_axis(x, indices, axis=axis)

    def scatter_add(self, x, indices, updates, axis):
        return x.at[(slice(None),) * axis + (indices,)].add(updates)

    def flip(self, x, axis):
        return jnp.flip(x, axis=axis)

    def sort(self, x, axis):
        return jnp.sort(x, axis=axis)

    def top_k(self, x, k):
        return lax.top_k(x, k)

    def astype(self, x, dtype):
        return x.astype(dtype)

    def stop_gradient(self, x):
        return lax.stop_gradient(x)

    # linear algebra
    def matmul(self, lhs, rhs):
        return jnp.matmul(lhs, rhs)

    def dot_general(self, lhs, rhs, dimension_numbers, preferred_element_type):
        return lax.dot_general(
            lhs, rhs, dimension_numbers,
            preferred_element_type=preferred_element_type)

    def conv2d(self, x, w, stride, padding):
        return lax.conv_general_dilated(
            x, w, window_strides=stride, padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
