"""Kernel-injected backend: hot primitives lowered to hand-written Pallas
TPU kernels; everything else inherits the eager XLA implementations.

This is the §5.2.4 demonstration at production scale: subclass the default
backend, override ``matmul``, and every matmul in the framework — core NN
stack, tape autograd, and the whole ``repro.models`` zoo — dispatches to
the custom kernel with zero call-site changes.

On CPU hosts the kernels run in ``interpret=True`` mode (Python emulation
of the kernel body) so the swap is *testable* off-TPU; on TPU they compile
to Mosaic.  Shapes not aligned to the MXU tiling fall back to the parent
implementation (recorded in ``fallback_calls``) rather than failing —
kernels are an optimization, not a correctness constraint.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .jnp_backend import JnpBackend


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class PallasBackend(JnpBackend):
    name = "pallas"

    def __init__(self, tile: int = 128):
        self.tile = tile
        self.kernel_calls = 0
        self.fallback_calls = 0
        self._interpret = not _on_tpu()

    def matmul(self, lhs, rhs):
        from repro.kernels import matmul as mm

        t = self.tile
        # kernel path: 2-D or batched-by-reshape, MXU-aligned shapes
        if (lhs.ndim == 2 and rhs.ndim == 2
                and lhs.shape[0] % t == 0 and lhs.shape[1] % t == 0
                and rhs.shape[1] % t == 0
                and lhs.dtype in (jnp.float32, jnp.bfloat16)
                and rhs.dtype in (jnp.float32, jnp.bfloat16)):
            self.kernel_calls += 1
            return mm.matmul(lhs, rhs, bm=t, bn=t, bk=t,
                             interpret=self._interpret)
        if (lhs.ndim == 3 and rhs.ndim == 2
                and lhs.shape[1] % 1 == 0
                and (lhs.shape[0] * lhs.shape[1]) % t == 0
                and lhs.shape[2] % t == 0 and rhs.shape[1] % t == 0
                and lhs.dtype in (jnp.float32, jnp.bfloat16)):
            b, s, k = lhs.shape
            self.kernel_calls += 1
            out = mm.matmul(lhs.reshape(b * s, k), rhs, bm=self.tile,
                            bn=self.tile, bk=self.tile,
                            interpret=self._interpret)
            return out.reshape(b, s, rhs.shape[1])
        self.fallback_calls += 1
        return super().matmul(lhs, rhs)

    def rms_norm_fused(self, x, weight, eps: float = 1e-6):
        """Extended (non-primitive) hook: fused RMSNorm kernel.

        Derived ops may *probe* the active backend for fused implementations
        — mirroring Flashlight's hybrid mode of "offloading computation to
        highly-optimized vendor libraries when advantageous".
        """
        from repro.kernels import ops as kops

        return kops.rms_norm(x, weight, eps=eps, interpret=self._interpret)
