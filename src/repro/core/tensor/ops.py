"""Public tensor API: primitive dispatch + composed (derived) operators.

Mirrors numpy at a high level (paper §4.1.1) while routing every primitive
through the active :class:`TensorBackend`.  Derived ops are *compositions*
of primitives — e.g. ``relu`` is literally ``maximum(x, 0)`` as in the paper
— so a backend needs to implement only the small primitive surface.
"""

from __future__ import annotations

import math
from functools import partial

import jax.numpy as jnp

from repro.runtime import current_session

from .dispatch import current_backend

# --------------------------------------------------------------------------
# primitive dispatchers
# --------------------------------------------------------------------------


def full(shape, fill_value, dtype=jnp.float32):
    return current_backend().full(shape, fill_value, dtype)


def zeros(shape, dtype=jnp.float32):
    return current_backend().full(shape, 0, dtype)


def ones(shape, dtype=jnp.float32):
    return current_backend().full(shape, 1, dtype)


def arange(start, stop=None, step=1, dtype=jnp.int32):
    if stop is None:
        start, stop = 0, start
    return current_backend().arange(start, stop, step, dtype)


def iota(dtype, shape, dimension):
    return current_backend().iota(dtype, shape, dimension)


def random_uniform(key, shape, dtype=jnp.float32, minval=0.0, maxval=1.0):
    return current_backend().random_uniform(key, shape, dtype, minval, maxval)


def random_normal(key, shape, dtype=jnp.float32):
    return current_backend().random_normal(key, shape, dtype)


def _unary(name):
    def op(x):
        return getattr(current_backend(), name)(x)
    op.__name__ = name
    return op


def _binary(name):
    def op(lhs, rhs):
        return getattr(current_backend(), name)(lhs, rhs)
    op.__name__ = name
    return op


neg = _unary("neg")
exp = _unary("exp")
log = _unary("log")
sin = _unary("sin")
cos = _unary("cos")
tanh = _unary("tanh")
sqrt = _unary("sqrt")
rsqrt = _unary("rsqrt")
abs = _unary("abs")  # noqa: A001 - numpy-mirroring API
sign = _unary("sign")
floor = _unary("floor")
erf = _unary("erf")
logical_not = _unary("logical_not")
isnan = _unary("isnan")

add = _binary("add")
sub = _binary("sub")
mul = _binary("mul")
div = _binary("div")
pow = _binary("pow")  # noqa: A001
maximum = _binary("maximum")
minimum = _binary("minimum")
mod = _binary("mod")
eq = _binary("eq")
ne = _binary("ne")
lt = _binary("lt")
le = _binary("le")
gt = _binary("gt")
ge = _binary("ge")
logical_and = _binary("logical_and")
logical_or = _binary("logical_or")


def matmul(lhs, rhs):
    """Session kernel-override point: ``session(kernels={"matmul": fn})``
    injects a custom contraction ahead of backend dispatch."""
    fn = current_session().kernels.matmul
    if fn is not None:
        return fn(lhs, rhs)
    return current_backend().matmul(lhs, rhs)


def sum(x, axis=None, keepdims=False):  # noqa: A001
    return current_backend().sum(x, axis, keepdims)


def max(x, axis=None, keepdims=False):  # noqa: A001
    return current_backend().max(x, axis, keepdims)


def min(x, axis=None, keepdims=False):  # noqa: A001
    return current_backend().min(x, axis, keepdims)


def prod(x, axis=None, keepdims=False):
    return current_backend().prod(x, axis, keepdims)


def argmax(x, axis=None):
    return current_backend().argmax(x, axis)


def cumsum(x, axis=-1):
    return current_backend().cumsum(x, axis)


def reshape(x, shape):
    return current_backend().reshape(x, shape)


def transpose(x, axes=None):
    return current_backend().transpose(x, axes)


def broadcast_to(x, shape):
    return current_backend().broadcast_to(x, shape)


def concatenate(xs, axis=0):
    return current_backend().concatenate(xs, axis)


def slice(x, start, limit):  # noqa: A001
    return current_backend().slice(x, start, limit)


def dynamic_slice(x, start_indices, slice_sizes):
    return current_backend().dynamic_slice(x, start_indices, slice_sizes)


def dynamic_update_slice(x, update, start_indices):
    return current_backend().dynamic_update_slice(x, update, start_indices)


def pad(x, pad_width, value=0.0):
    return current_backend().pad(x, pad_width, value)


def where(cond, x, y):
    return current_backend().where(cond, x, y)


def take(x, indices, axis=0):
    return current_backend().take(x, indices, axis)


def take_along_axis(x, indices, axis):
    return current_backend().take_along_axis(x, indices, axis)


def scatter_add(x, indices, updates, axis=0):
    return current_backend().scatter_add(x, indices, updates, axis)


def flip(x, axis):
    return current_backend().flip(x, axis)


def sort(x, axis=-1):
    return current_backend().sort(x, axis)


def top_k(x, k):
    return current_backend().top_k(x, k)


def astype(x, dtype):
    return current_backend().astype(x, dtype)


def stop_gradient(x):
    return current_backend().stop_gradient(x)


def dot_general(lhs, rhs, dimension_numbers, preferred_element_type=None):
    return current_backend().dot_general(
        lhs, rhs, dimension_numbers, preferred_element_type)


def conv2d(x, w, stride=(1, 1), padding="SAME"):
    return current_backend().conv2d(x, w, stride, padding)


def materialize(x):
    """Force deferred value(s).  A list/tuple materializes *jointly*: on
    backends that support it (lazy), the whole multi-output subgraph is
    compiled as one program, so shared subexpressions run once."""
    backend = current_backend()
    if isinstance(x, (list, tuple)):
        many = getattr(backend, "materialize_many", None)
        vals = many(x) if many is not None \
            else [backend.materialize(v) for v in x]
        if hasattr(x, "_fields"):         # namedtuple: positional fields
            return type(x)(*vals)
        return type(x)(vals)
    return backend.materialize(x)


# --------------------------------------------------------------------------
# derived operators (composition only — no new backend requirements)
# --------------------------------------------------------------------------


def relu(x):
    """The paper's canonical composition example: relu = max(x, 0)."""
    return maximum(x, zeros_like(x))


def zeros_like(x):
    return full(x.shape, 0, x.dtype)


def ones_like(x):
    return full(x.shape, 1, x.dtype)


def full_like(x, v):
    return full(x.shape, v, x.dtype)


def sigmoid(x):
    return div(ones_like(x), add(ones_like(x), exp(neg(x))))


def silu(x):
    return mul(x, sigmoid(x))


def gelu(x):
    # exact gelu via erf
    half = full_like(x, 0.5)
    one = ones_like(x)
    inv_sqrt2 = full_like(x, 1.0 / math.sqrt(2.0))
    return mul(mul(half, x), add(one, erf(mul(x, inv_sqrt2))))


def softplus(x):
    return log(add(ones_like(x), exp(neg(abs(x))))) + maximum(x, zeros_like(x))


def mean(x, axis=None, keepdims=False):
    total = sum(x, axis=axis, keepdims=keepdims)
    if axis is None:
        n = math.prod(x.shape) if x.shape else 1
    elif isinstance(axis, int):
        n = x.shape[axis]
    else:
        n = math.prod(x.shape[a] for a in axis)
    return div(total, full_like(total, n))


def var(x, axis=None, keepdims=False):
    mu = mean(x, axis=axis, keepdims=True)
    d = sub(x, mu)
    v = mean(mul(d, d), axis=axis, keepdims=keepdims)
    return v


def softmax(x, axis=-1):
    m = max(x, axis=axis, keepdims=True)
    e = exp(sub(x, stop_gradient(m)))
    return div(e, sum(e, axis=axis, keepdims=True))


def log_softmax(x, axis=-1):
    m = stop_gradient(max(x, axis=axis, keepdims=True))
    shifted = sub(x, m)
    lse = log(sum(exp(shifted), axis=axis, keepdims=True))
    return sub(shifted, lse)


def logsumexp(x, axis=-1, keepdims=False):
    m = stop_gradient(max(x, axis=axis, keepdims=True))
    out = add(log(sum(exp(sub(x, m)), axis=axis, keepdims=keepdims)),
              m if keepdims else reshape(m, max(x, axis=axis, keepdims=keepdims).shape))
    return out


def one_hot(indices, num_classes, dtype=jnp.float32):
    iota_ = iota(jnp.int32, tuple(indices.shape) + (num_classes,),
                 len(indices.shape))
    idx = broadcast_to(reshape(indices, tuple(indices.shape) + (1,)),
                       tuple(indices.shape) + (num_classes,))
    return astype(eq(iota_, idx), dtype)


def rms_norm(x, weight, eps=1e-6):
    ms = mean(mul(x, x), axis=-1, keepdims=True)
    inv = rsqrt(add(ms, full_like(ms, eps)))
    return mul(mul(x, inv), weight)


def layer_norm(x, weight, bias, eps=1e-5):
    mu = mean(x, axis=-1, keepdims=True)
    v = var(x, axis=-1, keepdims=True)
    xhat = mul(sub(x, mu), rsqrt(add(v, full_like(v, eps))))
    return add(mul(xhat, weight), bias)


def dropout_mask(key, shape, rate, dtype=jnp.float32):
    keep = random_uniform(key, shape, jnp.float32, 0.0, 1.0)
    keep = astype(ge(keep, full(shape, rate, jnp.float32)), dtype)
    return div(keep, full(shape, 1.0 - rate, dtype))


def clip(x, lo, hi):
    return minimum(maximum(x, full_like(x, lo)), full_like(x, hi))


def square(x):
    return mul(x, x)
