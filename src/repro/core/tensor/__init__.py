from .backend import TensorBackend
from .dispatch import (available_backends, current_backend, get_backend,
                       register_backend, set_backend, use_backend)
from .jnp_backend import JnpBackend
from . import ops

__all__ = [
    "TensorBackend", "JnpBackend", "ops",
    "available_backends", "current_backend", "get_backend",
    "register_backend", "set_backend", "use_backend",
]
