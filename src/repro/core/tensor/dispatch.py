"""Backend registry + dispatch: the single source of truth for tensor ops.

Paper §5.2.4: "an implementer can simply subclass or swap out the existing
implementation of the add function ... All add operations in Flashlight
dispatch to that operator, so existing baselines and operations will run
with the new implementation without any additional code changes."

``use_backend`` swaps the active backend for a scope; everything layered on
:mod:`repro.core.tensor.ops` — the core NN stack *and* the production model
zoo — picks up the swap with zero call-site changes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Callable

from .backend import TensorBackend
from .jnp_backend import JnpBackend

_REGISTRY: dict[str, Callable[[], TensorBackend]] = {}
_INSTANCES: dict[str, TensorBackend] = {}


class _State(threading.local):
    def __init__(self):
        self.backend: TensorBackend | None = None


_STATE = _State()


def register_backend(name: str, factory: Callable[[], TensorBackend]) -> None:
    _REGISTRY[name] = factory


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def get_backend(name: str) -> TensorBackend:
    if name not in _INSTANCES:
        if name not in _REGISTRY:
            raise KeyError(
                f"unknown tensor backend {name!r}; available: {available_backends()}")
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def current_backend() -> TensorBackend:
    if _STATE.backend is None:
        _STATE.backend = get_backend("jnp")
    return _STATE.backend


def set_backend(backend: TensorBackend | str) -> None:
    if isinstance(backend, str):
        backend = get_backend(backend)
    _STATE.backend = backend


@contextlib.contextmanager
def use_backend(backend: TensorBackend | str):
    """Scoped backend swap — the paper's headline customization point."""
    prev = _STATE.backend
    set_backend(backend)
    try:
        yield current_backend()
    finally:
        _STATE.backend = prev


register_backend("jnp", JnpBackend)


def _register_builtin_lazily():
    # Imported on demand to keep `import repro.core.tensor` light; both
    # modules self-register when imported directly as well.
    def _lazy():
        from .lazy_backend import LazyBackend
        return LazyBackend()

    def _pallas():
        from .pallas_backend import PallasBackend
        return PallasBackend()

    register_backend("lazy", _lazy)
    register_backend("pallas", _pallas)


_register_builtin_lazily()
