"""Backend registry + dispatch: the single source of truth for tensor ops.

Paper §5.2.4: "an implementer can simply subclass or swap out the existing
implementation of the add function ... All add operations in Flashlight
dispatch to that operator, so existing baselines and operations will run
with the new implementation without any additional code changes."

The *registry* (name -> backend factory) lives here; the *active* backend
is a field of the unified :class:`repro.runtime.Session` and scoped swaps
go through ``repro.session(backend=...)``.  The historical entry points
``use_backend`` / ``set_backend`` remain as deprecated shims over the
session stack so pre-Session code keeps working unchanged.
"""

from __future__ import annotations

import contextlib
import warnings
from typing import Callable

from repro.runtime import stack as _rt

from .backend import TensorBackend
from .jnp_backend import JnpBackend

_REGISTRY: dict[str, Callable[[], TensorBackend]] = {}
_INSTANCES: dict[str, TensorBackend] = {}


def register_backend(name: str, factory: Callable[[], TensorBackend]) -> None:
    _REGISTRY[name] = factory


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def get_backend(name: str) -> TensorBackend:
    if name not in _INSTANCES:
        if name not in _REGISTRY:
            raise KeyError(
                f"unknown tensor backend {name!r}; available: {available_backends()}")
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def current_backend() -> TensorBackend:
    """The session's backend — what every ``ops.*`` primitive dispatches to."""
    return _rt.current_session().backend_instance()


def set_backend(backend: TensorBackend | str) -> None:
    """Deprecated: use ``repro.session(backend=...)`` for scoped swaps."""
    warnings.warn(
        "set_backend() is deprecated; use repro.session(backend=...) "
        "(or Session.replace) instead", DeprecationWarning, stacklevel=2)
    _rt.mutate_current(backend=backend)


@contextlib.contextmanager
def use_backend(backend: TensorBackend | str):
    """Deprecated shim for the paper's headline customization point.

    Equivalent to ``with repro.session(backend=backend): ...`` — the swap
    still reaches every dispatch call site; it simply rides the unified
    session stack now.
    """
    warnings.warn(
        "use_backend() is deprecated; use repro.session(backend=...) "
        "instead", DeprecationWarning, stacklevel=3)
    with _rt.session(backend=backend):
        yield current_backend()


register_backend("jnp", JnpBackend)


def _register_builtin_lazily():
    # Imported on demand to keep `import repro.core.tensor` light; both
    # modules self-register when imported directly as well.
    def _lazy():
        from .lazy_backend import LazyBackend
        return LazyBackend()

    def _pallas():
        from .pallas_backend import PallasBackend
        return PallasBackend()

    register_backend("lazy", _lazy)
    register_backend("pallas", _pallas)


_register_builtin_lazily()
