"""Deferred/fusing backend — the ArrayFire-JIT analog (paper Fig. 2, §4.1.1).

Ops build an expression graph of :class:`LazyTensor` nodes instead of
executing.  Values are materialized only on user request (paper: "Tensor
values need only be materialized upon user request").  Materialization
routes the pending subgraph through the ``repro.compiler`` pipeline:

    trace → passes (cse / fold / dce / fuse) → lowering → execute

so fused elementwise clusters run as *generated* Pallas kernels (one
dispatch per cluster instead of one per op), and the whole run is
inspectable — the captured :class:`~repro.compiler.Graph`, per-pass node
deltas, and the lowered step list all surface through
``Session.describe()``.  The active :class:`~repro.runtime.CompilerPolicy`
selects the pipeline; an empty pipeline (``CompilerPolicy.legacy()``) is
the pre-compiler path — unrewritten node-at-a-time evaluation.

Compiled programs are cached by graph *signature* (op/attrs/edge
structure), so steady-state workloads skip pass+lowering work and reuse
the generated kernels (hitting jax's compilation cache).

The backend is also the framework's allocation-telemetry source (paper
§5.2.2): each materialization emits one alloc event per *surviving*
logical node and at most one free event per surviving interior node —
the alloc/free plan is computed after CSE/DCE, so merged or dead nodes
can never double-count.  Those traces drive the fragmentation-reduction
study in ``benchmarks/bench_fragmentation.py``.
"""

from __future__ import annotations

import itertools
from contextlib import nullcontext as _nullcontext
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from .backend import TensorBackend
from .jnp_backend import JnpBackend

_ELEMENTWISE = {
    "neg", "exp", "log", "sin", "cos", "tanh", "sqrt", "rsqrt", "abs", "sign",
    "floor", "erf", "logical_not", "isnan", "add", "sub", "mul", "div", "pow",
    "maximum", "minimum", "mod", "eq", "ne", "lt", "le", "gt", "ge",
    "logical_and", "logical_or", "where", "astype",
}

_ids = itertools.count()


def _freeze(x):
    if isinstance(x, (list, tuple)):
        return tuple(_freeze(v) for v in x)
    return x


def _attrs(*items) -> tuple | None:
    """Static op parameters as a hashable tuple, or ``None`` (opaque —
    excluded from CSE/folding/program-caching) if anything unhashable
    (e.g. a traced index array) was captured."""
    items = tuple(_freeze(i) for i in items)
    try:
        hash(items)
    except TypeError:
        return None
    return items


class LazyTensor:
    """A deferred tensor: op + deps + (shape, dtype) metadata.

    This is the lazy backend's ``TensorAdapter`` (paper Listing 1): the
    per-tensor state a backend attaches to each tensor instance.
    ``attrs`` mirrors the op's static parameters for the compiler (see
    :func:`_attrs`); ``trace()`` lifts these nodes into the explicit IR.
    """

    __slots__ = ("op", "fn", "deps", "shape", "dtype", "value", "uid",
                 "attrs", "n_consumers", "__weakref__")

    def __init__(self, op: str, fn: Callable, deps: Sequence[Any],
                 shape, dtype, attrs: tuple | None = ()):
        self.op = op
        self.fn = fn
        self.deps = tuple(deps)
        self.shape = tuple(shape)
        self.dtype = dtype
        self.value = None
        self.uid = next(_ids)
        self.attrs = attrs
        self.n_consumers = 0
        for d in deps:
            if isinstance(d, LazyTensor):
                d.n_consumers += 1

    @property
    def ndim(self):
        return len(self.shape)

    @property
    def size(self):
        out = 1
        for s in self.shape:
            out *= s
        return out

    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    def __repr__(self):
        return (f"LazyTensor(op={self.op!r}, shape={self.shape}, "
                f"dtype={jnp.dtype(self.dtype).name}, "
                f"materialized={self.value is not None})")


class LazyBackend(TensorBackend):
    """Graph-building backend; materialization compiles the pending
    subgraph through ``repro.compiler`` under the session's policy."""

    name = "lazy"

    def __init__(self):
        self._eager = JnpBackend()
        # stats for the fusion benchmark / tests
        self.nodes_built = 0
        self.materialize_calls = 0
        self.ops_fused = 0
        self.kernels_generated = 0     # pallas cluster kernels built
        self.program_cache_hits = 0
        self.last_compile_report: dict | None = None
        self.last_compile_policy = None    # the policy that produced it
        self.last_analysis = None          # DiagnosticReport of last compile
        self._programs: dict[tuple, Any] = {}

    # -- graph construction ------------------------------------------------
    def _node(self, op: str, fn: Callable, deps: Sequence[Any],
              attrs: tuple | None = ()):
        struct_deps = [
            jax.ShapeDtypeStruct(d.shape, d.dtype) if isinstance(d, LazyTensor)
            else d
            for d in deps
        ]
        out = jax.eval_shape(fn, *struct_deps)
        self.nodes_built += 1
        return LazyTensor(op, fn, deps, out.shape, out.dtype, attrs=attrs)

    def _lift(self, x):
        """Wrap a concrete array as a leaf node."""
        if isinstance(x, LazyTensor):
            return x
        arr = jnp.asarray(x)
        leaf = LazyTensor("leaf", lambda: arr, (), arr.shape, arr.dtype,
                          attrs=None)
        leaf.value = arr
        return leaf

    # -- materialization: compile + execute --------------------------------
    def materialize(self, x):
        if not isinstance(x, LazyTensor):
            return jnp.asarray(x)
        if x.value is not None:
            return x.value
        self.materialize_calls += 1
        self._materialize([x])
        return x.value

    def materialize_many(self, xs):
        """Materialize several tensors as one jointly-compiled program
        (shared subexpressions are computed once)."""
        roots = [self._lift(x) for x in xs]
        pending = [r for r in roots if r.value is None]
        if pending:
            self.materialize_calls += 1
            self._materialize(pending)
        return [r.value for r in roots]

    def _materialize(self, roots: list[LazyTensor]) -> None:
        from repro import obs
        from repro.compiler import api as _api
        from repro.compiler import graph as _graph
        from repro.runtime import current_session

        from ..memory import telemetry

        sess = current_session()
        policy = sess.compiler
        analysis = sess.analysis
        tracer = obs.get_tracer(sess)
        cm = (tracer.span("compiler.materialize", "compiler",
                          roots=len(roots))
              if tracer is not None else _nullcontext())
        with cm:
            graph, sources = _graph.trace(roots)
            self.ops_fused += sum(1 for uid in graph.order
                                  if graph.nodes[uid].op in _ELEMENTWISE)

            exe = None
            key = None
            if policy.cache_programs:
                sig = graph.signature()
                if sig is not None:
                    # analysis level is part of the key: a program cached
                    # with checks off must not satisfy a strict session
                    key = (sig, policy, analysis)
                    exe = self._programs.get(key)
            if exe is not None:
                self.program_cache_hits += 1
                if tracer is not None:
                    tracer.metrics.counter(
                        "compiler.program_cache_hit").add()
            else:
                if tracer is not None:
                    tracer.metrics.counter(
                        "compiler.program_cache_miss").add()
                exe = _api.compile_graph(graph, policy, analysis=analysis)
                self.kernels_generated += exe.n_kernels
                if key is not None:
                    if len(self._programs) >= 256:  # bounded, FIFO eviction
                        self._programs.pop(next(iter(self._programs)))
                    self._programs[key] = exe
            self.last_compile_report = _api.describe_report(exe.report, exe)
            self.last_compile_policy = policy
            self.last_analysis = exe.diagnostics

            env = {cid: sources[cid].value for cid in exe.inputs}
            if tracer is None:
                env = exe.run(env)
            else:
                with tracer.span("compiler.execute", "compiler",
                                 dispatches=exe.n_dispatches):
                    env = exe.run(env)

        # allocation telemetry over surviving logical nodes; uids are the
        # LazyTensor uids so events stay unique across materializations
        for cid, nbytes, tag in exe.allocs:
            lt = sources.get(cid)
            if lt is not None:
                telemetry.record_alloc(lt.uid, nbytes, tag=tag)
        for cid in exe.frees:
            lt = sources.get(cid)
            if lt is not None:
                telemetry.record_free(lt.uid)

        # write results back to every live handle (CSE-merged tensors
        # resolve to their surviving representative; cluster-internal
        # intermediates stay deferred and recompute on demand)
        for cid, lt in sources.items():
            if lt.value is None:
                rid = exe.resolve(cid)
                if rid in env:
                    lt.value = env[rid]

    # primitive ops are attached below, generated from the op tables


def _make_deferred_method(opname: str, arity: str):
    eager = JnpBackend()

    if arity == "unary":
        def method(self, x):
            x = self._lift(x)
            fn = getattr(eager, opname)
            return self._node(opname, fn, [x])
    elif arity == "binary":
        def method(self, lhs, rhs):
            lhs, rhs = self._lift(lhs), self._lift(rhs)
            fn = getattr(eager, opname)
            return self._node(opname, fn, [lhs, rhs])
    else:
        raise ValueError(arity)
    method.__name__ = opname
    return method


for _op in ["neg", "exp", "log", "sin", "cos", "tanh", "sqrt", "rsqrt", "abs",
            "sign", "floor", "erf", "logical_not", "isnan"]:
    setattr(LazyBackend, _op, _make_deferred_method(_op, "unary"))

for _op in ["add", "sub", "mul", "div", "pow", "maximum", "minimum", "mod",
            "eq", "ne", "lt", "le", "gt", "ge", "logical_and", "logical_or",
            "matmul"]:
    setattr(LazyBackend, _op, _make_deferred_method(_op, "binary"))


def _add_structured_methods():
    eager = JnpBackend()

    def full(self, shape, fill_value, dtype):
        return self._node("full", lambda: eager.full(shape, fill_value, dtype),
                          [], attrs=_attrs(shape, fill_value, jnp.dtype(dtype)))

    def arange(self, start, stop, step, dtype):
        return self._node("arange",
                          lambda: eager.arange(start, stop, step, dtype),
                          [], attrs=_attrs(start, stop, step, jnp.dtype(dtype)))

    def iota(self, dtype, shape, dimension):
        return self._node("iota", lambda: eager.iota(dtype, shape, dimension),
                          [], attrs=_attrs(jnp.dtype(dtype), shape, dimension))

    def random_uniform(self, key, shape, dtype, minval, maxval):
        return self._node(
            "random_uniform",
            lambda: eager.random_uniform(key, shape, dtype, minval, maxval),
            [], attrs=None)

    def random_normal(self, key, shape, dtype):
        return self._node(
            "random_normal", lambda: eager.random_normal(key, shape, dtype),
            [], attrs=None)

    def sum(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("sum", lambda v: eager.sum(v, axis, keepdims), [x],
                          attrs=_attrs(axis, keepdims))

    def max(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("max", lambda v: eager.max(v, axis, keepdims), [x],
                          attrs=_attrs(axis, keepdims))

    def min(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("min", lambda v: eager.min(v, axis, keepdims), [x],
                          attrs=_attrs(axis, keepdims))

    def prod(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("prod", lambda v: eager.prod(v, axis, keepdims), [x],
                          attrs=_attrs(axis, keepdims))

    def argmax(self, x, axis):
        x = self._lift(x)
        return self._node("argmax", lambda v: eager.argmax(v, axis), [x],
                          attrs=_attrs(axis))

    def cumsum(self, x, axis):
        x = self._lift(x)
        return self._node("cumsum", lambda v: eager.cumsum(v, axis), [x],
                          attrs=_attrs(axis))

    def reshape(self, x, shape):
        x = self._lift(x)
        return self._node("reshape", lambda v: eager.reshape(v, shape), [x],
                          attrs=_attrs(shape))

    def transpose(self, x, axes):
        x = self._lift(x)
        return self._node("transpose", lambda v: eager.transpose(v, axes), [x],
                          attrs=_attrs(axes))

    def broadcast_to(self, x, shape):
        x = self._lift(x)
        return self._node("broadcast_to",
                          lambda v: eager.broadcast_to(v, shape), [x],
                          attrs=_attrs(shape))

    def concatenate(self, xs, axis):
        xs = [self._lift(x) for x in xs]
        return self._node("concatenate",
                          lambda *vs: eager.concatenate(vs, axis), xs,
                          attrs=_attrs(axis))

    def slice(self, x, start, limit):
        x = self._lift(x)
        return self._node("slice", lambda v: eager.slice(v, start, limit), [x],
                          attrs=_attrs(start, limit))

    def dynamic_slice(self, x, start_indices, slice_sizes):
        x = self._lift(x)
        return self._node(
            "dynamic_slice",
            lambda v: eager.dynamic_slice(v, start_indices, slice_sizes), [x],
            attrs=_attrs(start_indices, slice_sizes))

    def dynamic_update_slice(self, x, update, start_indices):
        x, update = self._lift(x), self._lift(update)
        return self._node(
            "dynamic_update_slice",
            lambda v, u: eager.dynamic_update_slice(v, u, start_indices),
            [x, update], attrs=_attrs(start_indices))

    def pad(self, x, pad_width, value):
        x = self._lift(x)
        return self._node("pad", lambda v: eager.pad(v, pad_width, value), [x],
                          attrs=_attrs(pad_width, value))

    def where(self, cond, x, y):
        cond, x, y = self._lift(cond), self._lift(x), self._lift(y)
        return self._node("where", lambda c, a, b: eager.where(c, a, b),
                          [cond, x, y])

    def take(self, x, indices, axis):
        x, indices = self._lift(x), self._lift(indices)
        return self._node("take", lambda v, i: eager.take(v, i, axis),
                          [x, indices], attrs=_attrs(axis))

    def take_along_axis(self, x, indices, axis):
        x, indices = self._lift(x), self._lift(indices)
        return self._node(
            "take_along_axis",
            lambda v, i: eager.take_along_axis(v, i, axis), [x, indices],
            attrs=_attrs(axis))

    def scatter_add(self, x, indices, updates, axis):
        x, indices, updates = map(self._lift, (x, indices, updates))
        return self._node(
            "scatter_add",
            lambda v, i, u: eager.scatter_add(v, i, u, axis),
            [x, indices, updates], attrs=_attrs(axis))

    def flip(self, x, axis):
        x = self._lift(x)
        return self._node("flip", lambda v: eager.flip(v, axis), [x],
                          attrs=_attrs(axis))

    def sort(self, x, axis):
        x = self._lift(x)
        return self._node("sort", lambda v: eager.sort(v, axis), [x],
                          attrs=_attrs(axis))

    def top_k(self, x, k):
        # top_k returns a pair; materialize eagerly for simplicity
        v = self.materialize(self._lift(x))
        return eager.top_k(v, k)

    def astype(self, x, dtype):
        x = self._lift(x)
        return self._node("astype", lambda v: eager.astype(v, dtype), [x],
                          attrs=_attrs(jnp.dtype(dtype)))

    def stop_gradient(self, x):
        x = self._lift(x)
        return self._node("stop_gradient", lambda v: eager.stop_gradient(v),
                          [x])

    def dot_general(self, lhs, rhs, dimension_numbers, preferred_element_type):
        lhs, rhs = self._lift(lhs), self._lift(rhs)
        return self._node(
            "dot_general",
            lambda a, b: eager.dot_general(a, b, dimension_numbers,
                                           preferred_element_type),
            [lhs, rhs],
            attrs=_attrs(dimension_numbers, preferred_element_type))

    def conv2d(self, x, w, stride, padding):
        x, w = self._lift(x), self._lift(w)
        return self._node("conv2d",
                          lambda a, b: eager.conv2d(a, b, stride, padding),
                          [x, w], attrs=_attrs(stride, padding))

    for fname, f in list(locals().items()):
        if callable(f) and not fname.startswith("_"):
            setattr(LazyBackend, fname, f)


_add_structured_methods()

# Methods are attached post-hoc (generated from the primitive table), so the
# ABC machinery must be told the surface is now complete.
LazyBackend.__abstractmethods__ = frozenset()
