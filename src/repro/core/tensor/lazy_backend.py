"""Deferred/fusing backend — the ArrayFire-JIT analog (paper Fig. 2, §4.1.1).

Ops build an expression graph of :class:`LazyTensor` nodes instead of
executing.  Values are materialized only on user request (paper: "Tensor
values need only be materialized upon user request").  At materialization,
the pending subgraph is evaluated as a *single* fused ``jax.jit`` program —
increasing kernel arithmetic intensity exactly as the paper describes for
the ArrayFire JIT — instead of one dispatch per op in eager mode.

The backend is also the framework's allocation-telemetry source (paper
§5.2.2): every node evaluation emits alloc events to the active
:class:`~repro.core.memory.manager.MemoryManagerAdapter`, and free events
are emitted when a node's last consumer has used it.  Those traces drive
the fragmentation-reduction study in ``benchmarks/bench_fragmentation.py``.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from .backend import TensorBackend
from .jnp_backend import JnpBackend

_ELEMENTWISE = {
    "neg", "exp", "log", "sin", "cos", "tanh", "sqrt", "rsqrt", "abs", "sign",
    "floor", "erf", "logical_not", "isnan", "add", "sub", "mul", "div", "pow",
    "maximum", "minimum", "mod", "eq", "ne", "lt", "le", "gt", "ge",
    "logical_and", "logical_or", "where", "astype",
}

_ids = itertools.count()


class LazyTensor:
    """A deferred tensor: op + deps + (shape, dtype) metadata.

    This is the lazy backend's ``TensorAdapter`` (paper Listing 1): the
    per-tensor state a backend attaches to each tensor instance.
    """

    __slots__ = ("op", "fn", "deps", "shape", "dtype", "value", "uid",
                 "n_consumers", "__weakref__")

    def __init__(self, op: str, fn: Callable, deps: Sequence[Any],
                 shape, dtype):
        self.op = op
        self.fn = fn
        self.deps = tuple(deps)
        self.shape = tuple(shape)
        self.dtype = dtype
        self.value = None
        self.uid = next(_ids)
        self.n_consumers = 0
        for d in deps:
            if isinstance(d, LazyTensor):
                d.n_consumers += 1

    @property
    def ndim(self):
        return len(self.shape)

    @property
    def size(self):
        out = 1
        for s in self.shape:
            out *= s
        return out

    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    def __repr__(self):
        return (f"LazyTensor(op={self.op!r}, shape={self.shape}, "
                f"dtype={jnp.dtype(self.dtype).name}, "
                f"materialized={self.value is not None})")


class LazyBackend(TensorBackend):
    """Graph-building backend with whole-subgraph fusion at materialize()."""

    name = "lazy"

    def __init__(self):
        self._eager = JnpBackend()
        # stats for the fusion benchmark
        self.nodes_built = 0
        self.materialize_calls = 0
        self.ops_fused = 0

    # -- graph construction ------------------------------------------------
    def _node(self, op: str, fn: Callable, deps: Sequence[Any]):
        struct_deps = [
            jax.ShapeDtypeStruct(d.shape, d.dtype) if isinstance(d, LazyTensor)
            else d
            for d in deps
        ]
        out = jax.eval_shape(fn, *struct_deps)
        self.nodes_built += 1
        return LazyTensor(op, fn, deps, out.shape, out.dtype)

    def _lift(self, x):
        """Wrap a concrete array as a leaf node."""
        if isinstance(x, LazyTensor):
            return x
        arr = jnp.asarray(x)
        leaf = LazyTensor("leaf", lambda: arr, (), arr.shape, arr.dtype)
        leaf.value = arr
        return leaf

    # -- materialization: fused evaluation ---------------------------------
    def materialize(self, x):
        if not isinstance(x, LazyTensor):
            return jnp.asarray(x)
        if x.value is not None:
            return x.value
        self.materialize_calls += 1
        order = self._toposort(x)
        self.ops_fused += len([n for n in order if n.op in _ELEMENTWISE])
        self._evaluate(order)
        return x.value

    def _toposort(self, root: LazyTensor) -> list[LazyTensor]:
        seen: set[int] = set()
        order: list[LazyTensor] = []
        stack: list[tuple[LazyTensor, bool]] = [(root, False)]
        while stack:
            node, expanded = stack.pop()
            if node.uid in seen:
                continue
            if expanded:
                seen.add(node.uid)
                order.append(node)
                continue
            stack.append((node, True))
            for d in node.deps:
                if isinstance(d, LazyTensor) and d.uid not in seen \
                        and d.value is None:
                    stack.append((d, False))
        return order

    def _evaluate(self, order: list[LazyTensor]) -> None:
        """Evaluate the pending subgraph as one fused jit program.

        Allocation telemetry: each produced intermediate emits an alloc
        event; a free event fires once its consumers are done (a
        conservative liveness model matching caching-allocator behavior).
        """
        from ..memory import telemetry

        pending = [n for n in order if n.value is None]
        if not pending:
            return
        remaining = {n.uid: 0 for n in pending}
        for n in pending:
            for d in n.deps:
                if isinstance(d, LazyTensor) and d.uid in remaining:
                    remaining[d.uid] += 1

        env: dict[int, Any] = {}

        def run_graph(leaf_vals):
            for node in pending:
                args = []
                for d in node.deps:
                    if isinstance(d, LazyTensor):
                        args.append(env[d.uid] if d.uid in env
                                    else leaf_vals[d.uid])
                    else:
                        args.append(d)
                env[node.uid] = node.fn(*args)
            return env[pending[-1].uid]

        leaf_vals = {}
        for n in pending:
            for d in n.deps:
                if isinstance(d, LazyTensor) and d.value is not None:
                    leaf_vals[d.uid] = d.value

        # one fused dispatch for the whole pending subgraph
        result = run_graph(leaf_vals)
        for node in pending:
            telemetry.record_alloc(node.uid, node.nbytes(), tag=node.op)
        # assign values; free intermediates whose consumers are internal
        for node in pending:
            node.value = env[node.uid]
        for node in pending:
            if remaining[node.uid] > 0 and node is not pending[-1]:
                # consumed internally only -> buffer returns to the pool
                telemetry.record_free(node.uid)
        del result

    # primitive ops are attached below, generated from the op tables


def _make_deferred_method(opname: str, arity: str):
    eager = JnpBackend()

    if arity == "unary":
        def method(self, x):
            x = self._lift(x)
            fn = getattr(eager, opname)
            return self._node(opname, fn, [x])
    elif arity == "binary":
        def method(self, lhs, rhs):
            lhs, rhs = self._lift(lhs), self._lift(rhs)
            fn = getattr(eager, opname)
            return self._node(opname, fn, [lhs, rhs])
    else:
        raise ValueError(arity)
    method.__name__ = opname
    return method


for _op in ["neg", "exp", "log", "sin", "cos", "tanh", "sqrt", "rsqrt", "abs",
            "sign", "floor", "erf", "logical_not", "isnan"]:
    setattr(LazyBackend, _op, _make_deferred_method(_op, "unary"))

for _op in ["add", "sub", "mul", "div", "pow", "maximum", "minimum", "mod",
            "eq", "ne", "lt", "le", "gt", "ge", "logical_and", "logical_or",
            "matmul"]:
    setattr(LazyBackend, _op, _make_deferred_method(_op, "binary"))


def _add_structured_methods():
    eager = JnpBackend()

    def full(self, shape, fill_value, dtype):
        return self._node("full", lambda: eager.full(shape, fill_value, dtype), [])

    def arange(self, start, stop, step, dtype):
        return self._node("arange", lambda: eager.arange(start, stop, step, dtype), [])

    def iota(self, dtype, shape, dimension):
        return self._node("iota", lambda: eager.iota(dtype, shape, dimension), [])

    def random_uniform(self, key, shape, dtype, minval, maxval):
        return self._node(
            "random_uniform",
            lambda: eager.random_uniform(key, shape, dtype, minval, maxval), [])

    def random_normal(self, key, shape, dtype):
        return self._node(
            "random_normal", lambda: eager.random_normal(key, shape, dtype), [])

    def sum(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("sum", lambda v: eager.sum(v, axis, keepdims), [x])

    def max(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("max", lambda v: eager.max(v, axis, keepdims), [x])

    def min(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("min", lambda v: eager.min(v, axis, keepdims), [x])

    def prod(self, x, axis, keepdims):
        x = self._lift(x)
        return self._node("prod", lambda v: eager.prod(v, axis, keepdims), [x])

    def argmax(self, x, axis):
        x = self._lift(x)
        return self._node("argmax", lambda v: eager.argmax(v, axis), [x])

    def cumsum(self, x, axis):
        x = self._lift(x)
        return self._node("cumsum", lambda v: eager.cumsum(v, axis), [x])

    def reshape(self, x, shape):
        x = self._lift(x)
        return self._node("reshape", lambda v: eager.reshape(v, shape), [x])

    def transpose(self, x, axes):
        x = self._lift(x)
        return self._node("transpose", lambda v: eager.transpose(v, axes), [x])

    def broadcast_to(self, x, shape):
        x = self._lift(x)
        return self._node("broadcast_to", lambda v: eager.broadcast_to(v, shape), [x])

    def concatenate(self, xs, axis):
        xs = [self._lift(x) for x in xs]
        return self._node("concatenate", lambda *vs: eager.concatenate(vs, axis), xs)

    def slice(self, x, start, limit):
        x = self._lift(x)
        return self._node("slice", lambda v: eager.slice(v, start, limit), [x])

    def dynamic_slice(self, x, start_indices, slice_sizes):
        x = self._lift(x)
        return self._node(
            "dynamic_slice",
            lambda v: eager.dynamic_slice(v, start_indices, slice_sizes), [x])

    def dynamic_update_slice(self, x, update, start_indices):
        x, update = self._lift(x), self._lift(update)
        return self._node(
            "dynamic_update_slice",
            lambda v, u: eager.dynamic_update_slice(v, u, start_indices),
            [x, update])

    def pad(self, x, pad_width, value):
        x = self._lift(x)
        return self._node("pad", lambda v: eager.pad(v, pad_width, value), [x])

    def where(self, cond, x, y):
        cond, x, y = self._lift(cond), self._lift(x), self._lift(y)
        return self._node("where", lambda c, a, b: eager.where(c, a, b),
                          [cond, x, y])

    def take(self, x, indices, axis):
        x, indices = self._lift(x), self._lift(indices)
        return self._node("take", lambda v, i: eager.take(v, i, axis),
                          [x, indices])

    def take_along_axis(self, x, indices, axis):
        x, indices = self._lift(x), self._lift(indices)
        return self._node(
            "take_along_axis",
            lambda v, i: eager.take_along_axis(v, i, axis), [x, indices])

    def scatter_add(self, x, indices, updates, axis):
        x, indices, updates = map(self._lift, (x, indices, updates))
        return self._node(
            "scatter_add",
            lambda v, i, u: eager.scatter_add(v, i, u, axis),
            [x, indices, updates])

    def flip(self, x, axis):
        x = self._lift(x)
        return self._node("flip", lambda v: eager.flip(v, axis), [x])

    def sort(self, x, axis):
        x = self._lift(x)
        return self._node("sort", lambda v: eager.sort(v, axis), [x])

    def top_k(self, x, k):
        # top_k returns a pair; materialize eagerly for simplicity
        v = self.materialize(self._lift(x))
        return eager.top_k(v, k)

    def astype(self, x, dtype):
        x = self._lift(x)
        return self._node("astype", lambda v: eager.astype(v, dtype), [x])

    def stop_gradient(self, x):
        x = self._lift(x)
        return self._node("stop_gradient", lambda v: eager.stop_gradient(v), [x])

    def dot_general(self, lhs, rhs, dimension_numbers, preferred_element_type):
        lhs, rhs = self._lift(lhs), self._lift(rhs)
        return self._node(
            "dot_general",
            lambda a, b: eager.dot_general(a, b, dimension_numbers,
                                           preferred_element_type),
            [lhs, rhs])

    def conv2d(self, x, w, stride, padding):
        x, w = self._lift(x), self._lift(w)
        return self._node("conv2d",
                          lambda a, b: eager.conv2d(a, b, stride, padding),
                          [x, w])

    for fname, f in list(locals().items()):
        if callable(f) and not fname.startswith("_"):
            setattr(LazyBackend, fname, f)


_add_structured_methods()

# Methods are attached post-hoc (generated from the primitive table), so the
# ABC machinery must be told the surface is now complete.
LazyBackend.__abstractmethods__ = frozenset()
