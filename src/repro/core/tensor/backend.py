"""TensorBackend: the primitive-op surface of the framework.

This is the JAX adaptation of Flashlight's ``TensorBackend`` interface
(paper §4.1.1, Listing 2): a deliberately *small* set of primitive tensor
operations.  Every other operator in the framework — activations, norms,
losses, attention, whole model zoos — is derived from these by composition
(paper: "the ReLU activation is implemented by leveraging the MAX operator").

Swapping a backend swaps the source of truth for these ops *everywhere*
(paper §5.2.4): the production models in ``repro.models`` and the core NN
stack in ``repro.core.nn`` both route through :mod:`repro.core.tensor.ops`,
which dispatches to the active backend at trace time.

Backends are free to follow any computation mode (paper Fig. 2): eager
(:class:`~repro.core.tensor.jnp_backend.JnpBackend`), deferred/fusing
(:class:`~repro.core.tensor.lazy_backend.LazyBackend`), or kernel-injected
(:class:`~repro.core.tensor.pallas_backend.PallasBackend`).
"""

from __future__ import annotations

import abc
import inspect
from typing import Any, Sequence

Tensor = Any  # backend-native handle: jax.Array for eager, LazyTensor for lazy.


class TensorBackend(abc.ABC):
    """Abstract primitive-op surface (~60 ops, mirroring the paper's Table 1).

    Implementations may store global state (compute streams, compiler state,
    expression graphs) as instance attributes, per Listing 2 of the paper.
    """

    name: str = "abstract"

    # -- lifecycle -------------------------------------------------------
    def materialize(self, x: Tensor) -> Tensor:
        """Force computation of ``x`` and return a concrete array.

        Paper §4.1.1: "Tensor values need only be materialized upon user
        request". Eager backends return ``x`` unchanged.
        """
        return x

    # -- creation --------------------------------------------------------
    @abc.abstractmethod
    def full(self, shape: Sequence[int], fill_value, dtype) -> Tensor: ...

    @abc.abstractmethod
    def arange(self, start, stop, step, dtype) -> Tensor: ...

    @abc.abstractmethod
    def iota(self, dtype, shape, dimension: int) -> Tensor: ...

    @abc.abstractmethod
    def random_uniform(self, key, shape, dtype, minval, maxval) -> Tensor: ...

    @abc.abstractmethod
    def random_normal(self, key, shape, dtype) -> Tensor: ...

    # -- unary -----------------------------------------------------------
    @abc.abstractmethod
    def neg(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def exp(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def log(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def sin(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def cos(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def tanh(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def sqrt(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def rsqrt(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def abs(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def sign(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def floor(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def erf(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def logical_not(self, x: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def isnan(self, x: Tensor) -> Tensor: ...

    # -- binary ----------------------------------------------------------
    @abc.abstractmethod
    def add(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def sub(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def mul(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def div(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def pow(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def maximum(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def minimum(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def mod(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def eq(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def ne(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def lt(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def le(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def gt(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def ge(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def logical_and(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def logical_or(self, lhs: Tensor, rhs: Tensor) -> Tensor: ...

    # -- reductions ------------------------------------------------------
    @abc.abstractmethod
    def sum(self, x: Tensor, axis, keepdims: bool) -> Tensor: ...

    @abc.abstractmethod
    def max(self, x: Tensor, axis, keepdims: bool) -> Tensor: ...

    @abc.abstractmethod
    def min(self, x: Tensor, axis, keepdims: bool) -> Tensor: ...

    @abc.abstractmethod
    def prod(self, x: Tensor, axis, keepdims: bool) -> Tensor: ...

    @abc.abstractmethod
    def argmax(self, x: Tensor, axis) -> Tensor: ...

    @abc.abstractmethod
    def cumsum(self, x: Tensor, axis) -> Tensor: ...

    # -- shape / data movement --------------------------------------------
    @abc.abstractmethod
    def reshape(self, x: Tensor, shape) -> Tensor: ...

    @abc.abstractmethod
    def transpose(self, x: Tensor, axes) -> Tensor: ...

    @abc.abstractmethod
    def broadcast_to(self, x: Tensor, shape) -> Tensor: ...

    @abc.abstractmethod
    def concatenate(self, xs: Sequence[Tensor], axis: int) -> Tensor: ...

    @abc.abstractmethod
    def slice(self, x: Tensor, start: Sequence[int], limit: Sequence[int]) -> Tensor: ...

    @abc.abstractmethod
    def dynamic_slice(self, x: Tensor, start_indices, slice_sizes) -> Tensor: ...

    @abc.abstractmethod
    def dynamic_update_slice(self, x: Tensor, update: Tensor, start_indices) -> Tensor: ...

    @abc.abstractmethod
    def pad(self, x: Tensor, pad_width, value) -> Tensor: ...

    @abc.abstractmethod
    def where(self, cond: Tensor, x: Tensor, y: Tensor) -> Tensor: ...

    @abc.abstractmethod
    def take(self, x: Tensor, indices: Tensor, axis: int) -> Tensor: ...

    @abc.abstractmethod
    def take_along_axis(self, x: Tensor, indices: Tensor, axis: int) -> Tensor: ...

    @abc.abstractmethod
    def scatter_add(self, x: Tensor, indices: Tensor, updates: Tensor, axis: int) -> Tensor: ...

    @abc.abstractmethod
    def flip(self, x: Tensor, axis) -> Tensor: ...

    @abc.abstractmethod
    def sort(self, x: Tensor, axis) -> Tensor: ...

    @abc.abstractmethod
    def top_k(self, x: Tensor, k: int) -> tuple[Tensor, Tensor]: ...

    @abc.abstractmethod
    def astype(self, x: Tensor, dtype) -> Tensor: ...

    @abc.abstractmethod
    def stop_gradient(self, x: Tensor) -> Tensor: ...

    # -- linear algebra / structured compute -------------------------------
    @abc.abstractmethod
    def matmul(self, lhs: Tensor, rhs: Tensor) -> Tensor:
        """Batched matrix multiply (the MXU-bound primitive)."""

    @abc.abstractmethod
    def dot_general(self, lhs: Tensor, rhs: Tensor, dimension_numbers,
                    preferred_element_type) -> Tensor: ...

    @abc.abstractmethod
    def conv2d(self, x: Tensor, w: Tensor, stride, padding) -> Tensor:
        """NHWC conv with HWIO weights (Flashlight lists conv as a primitive)."""

    # -- introspection -----------------------------------------------------
    @classmethod
    def primitive_ops(cls) -> list[str]:
        """Names of the abstract primitive ops — the op *surface* of the
        framework, reported in the paper-Table-1 complexity benchmark."""
        ops = []
        for name, member in inspect.getmembers(TensorBackend):
            if getattr(member, "__isabstractmethod__", False):
                ops.append(name)
        return sorted(ops)
