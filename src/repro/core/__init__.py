from . import autograd, data, distributed, memory, nn, optim, tensor

__all__ = ["autograd", "data", "distributed", "memory", "nn", "optim",
           "tensor"]
