"""DATASET abstractions (paper §4.2): a sample is a tensor or vector of
tensors; datasets compose trivially into transform/resample/parallelize
pipelines.
"""

from __future__ import annotations

import abc
import queue
import threading
from typing import Any, Callable, Sequence

import numpy as np


class Dataset(abc.ABC):
    @abc.abstractmethod
    def __len__(self) -> int: ...

    @abc.abstractmethod
    def __getitem__(self, idx: int) -> Any: ...

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]


class TensorDataset(Dataset):
    """Wraps a list of equal-length arrays; sample i is a tuple of rows."""

    def __init__(self, tensors: Sequence[np.ndarray]):
        self.tensors = [np.asarray(t) for t in tensors]
        n = len(self.tensors[0])
        assert all(len(t) == n for t in self.tensors)

    def __len__(self):
        return len(self.tensors[0])

    def __getitem__(self, idx):
        return tuple(t[idx] for t in self.tensors)


class BatchDataset(Dataset):
    """Paper Listing 7: batches an underlying dataset."""

    def __init__(self, dataset: Dataset, batch_size: int,
                 drop_last: bool = True):
        self.dataset = dataset
        self.batch_size = batch_size
        self.drop_last = drop_last

    def __len__(self):
        n = len(self.dataset)
        if self.drop_last:
            return n // self.batch_size
        return (n + self.batch_size - 1) // self.batch_size

    def __getitem__(self, idx):
        start = idx * self.batch_size
        stop = min(start + self.batch_size, len(self.dataset))
        samples = [self.dataset[i] for i in range(start, stop)]
        first = samples[0]
        if isinstance(first, tuple):
            return tuple(np.stack([s[j] for s in samples])
                         for j in range(len(first)))
        return np.stack(samples)


class MapDataset(Dataset):
    def __init__(self, dataset: Dataset, fn: Callable):
        self.dataset, self.fn = dataset, fn

    def __len__(self):
        return len(self.dataset)

    def __getitem__(self, idx):
        return self.fn(self.dataset[idx])


class ShuffleDataset(Dataset):
    """Deterministic reshuffle per epoch via ``reshuffle(epoch)``."""

    def __init__(self, dataset: Dataset, seed: int = 0):
        self.dataset, self.seed = dataset, seed
        self._perm = np.random.default_rng(seed).permutation(len(dataset))

    def reshuffle(self, epoch: int) -> None:
        self._perm = np.random.default_rng(
            self.seed + epoch).permutation(len(self.dataset))

    def __len__(self):
        return len(self.dataset)

    def __getitem__(self, idx):
        return self.dataset[int(self._perm[idx])]


class ConcatDataset(Dataset):
    def __init__(self, datasets: Sequence[Dataset]):
        self.datasets = list(datasets)
        self._offsets = np.cumsum([0] + [len(d) for d in self.datasets])

    def __len__(self):
        return int(self._offsets[-1])

    def __getitem__(self, idx):
        d = int(np.searchsorted(self._offsets, idx, side="right") - 1)
        return self.datasets[d][idx - int(self._offsets[d])]


class ShardDataset(Dataset):
    """Per-host sharding for data-parallel input pipelines."""

    def __init__(self, dataset: Dataset, shard: int, num_shards: int):
        assert 0 <= shard < num_shards
        self.dataset, self.shard, self.num_shards = dataset, shard, num_shards

    def __len__(self):
        return len(self.dataset) // self.num_shards

    def __getitem__(self, idx):
        return self.dataset[idx * self.num_shards + self.shard]


class PrefetchDataset(Dataset):
    """Background-thread prefetch (paper: parallelize via native threads)."""

    def __init__(self, dataset: Dataset, buffer_size: int = 4,
                 num_threads: int = 2):
        self.dataset = dataset
        self.buffer_size = buffer_size
        self.num_threads = num_threads

    def __len__(self):
        return len(self.dataset)

    def __getitem__(self, idx):
        return self.dataset[idx]

    def __iter__(self):
        n = len(self.dataset)
        out_q: "queue.Queue[tuple[int, Any]]" = queue.Queue(self.buffer_size)
        idx_q: "queue.Queue[int]" = queue.Queue()
        for i in range(n):
            idx_q.put(i)

        def worker():
            while True:
                try:
                    i = idx_q.get_nowait()
                except queue.Empty:
                    return
                out_q.put((i, self.dataset[i]))

        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(self.num_threads)]
        for t in threads:
            t.start()
        pending: dict[int, Any] = {}
        nxt = 0
        got = 0
        while got < n:
            while nxt not in pending:
                i, s = out_q.get()
                pending[i] = s
                got += 1
                if got == n:
                    break
            while nxt in pending:
                yield pending.pop(nxt)
                nxt += 1
