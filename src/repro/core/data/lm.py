"""Text/LM data pipeline: tokenization, packing, batching (paper §4.3 Text).

Ships a byte-level tokenizer (no external vocab files — everything built
in-repo) and a synthetic corpus generator so training examples are fully
reproducible offline.
"""

from __future__ import annotations

import numpy as np

from .dataset import Dataset


class ByteTokenizer:
    """Byte-level tokenizer with special tokens."""

    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str, bos: bool = True, eos: bool = True) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8",
                                                       errors="replace")


def synthetic_corpus(n_docs: int = 256, seed: int = 0,
                     min_len: int = 64, max_len: int = 512) -> list[str]:
    """Markov-ish synthetic text with learnable structure (not uniform noise:
    losses must visibly decrease in the end-to-end example)."""
    rng = np.random.default_rng(seed)
    words = ["the", "tensor", "backend", "swaps", "kernel", "graph", "tape",
             "memory", "pod", "mesh", "shard", "flash", "light", "scan",
             "expert", "router", "cache", "decode", "fuse", "block"]
    trans = rng.dirichlet(np.ones(len(words)) * 0.3, size=len(words))
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(min_len, max_len))
        w = int(rng.integers(len(words)))
        toks = []
        for _ in range(n):
            toks.append(words[w])
            w = int(rng.choice(len(words), p=trans[w]))
        docs.append(" ".join(toks))
    return docs


class PackedLMDataset(Dataset):
    """Greedy document packing into fixed-length token sequences.

    Sample = (tokens[seq_len], labels[seq_len]) with next-token labels;
    cross-document attention is allowed (standard packed pretraining).
    """

    def __init__(self, docs: list[str], seq_len: int,
                 tokenizer: ByteTokenizer | None = None):
        self.tokenizer = tokenizer or ByteTokenizer()
        self.seq_len = seq_len
        stream: list[int] = []
        for d in docs:
            stream.extend(self.tokenizer.encode(d))
        n = (len(stream) - 1) // seq_len
        tok = np.asarray(stream[: n * seq_len + 1], dtype=np.int32)
        self._tokens = tok[:-1].reshape(n, seq_len)
        self._labels = tok[1:].reshape(n, seq_len)

    def __len__(self):
        return len(self._tokens)

    def __getitem__(self, idx):
        return self._tokens[idx], self._labels[idx]


class SyntheticTokenDataset(Dataset):
    """Deterministic random tokens for benchmarks (paper §5.1.2 uses random
    in-memory data for BERT-like models 'to ensure fairness')."""

    def __init__(self, n: int, seq_len: int, vocab_size: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self._tokens = rng.integers(0, vocab_size, (n, seq_len),
                                    dtype=np.int32)

    def __len__(self):
        return len(self._tokens)

    def __getitem__(self, idx):
        t = self._tokens[idx]
        return t, np.roll(t, -1)
