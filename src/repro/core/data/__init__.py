from .dataset import (BatchDataset, ConcatDataset, Dataset, MapDataset,
                      PrefetchDataset, ShardDataset, ShuffleDataset,
                      TensorDataset)
from .lm import (ByteTokenizer, PackedLMDataset, SyntheticTokenDataset,
                 synthetic_corpus)

__all__ = ["BatchDataset", "ConcatDataset", "Dataset", "MapDataset",
           "PrefetchDataset", "ShardDataset", "ShuffleDataset",
           "TensorDataset", "ByteTokenizer", "PackedLMDataset",
           "SyntheticTokenDataset", "synthetic_corpus"]
