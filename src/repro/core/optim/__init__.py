from .optimizer import (Optimizer, SGD, SGDOptimizer, Adam, AdamW, Adafactor,
                        clip_by_global_norm, cosine_schedule, linear_schedule)

__all__ = ["Optimizer", "SGD", "SGDOptimizer", "Adam", "AdamW", "Adafactor",
           "clip_by_global_norm", "cosine_schedule", "linear_schedule"]
